"""Paper Table 4: optimization cost, break-even docs, total cost @ 1M docs."""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import OptimizationCost, break_even_docs
from repro.core.simulation import WORKLOADS, make_workload

from .common import ALL_WORKLOADS, fmt_table, run_variant


def run(quick: bool = False):
    workloads = ALL_WORKLOADS[:3] if quick else ALL_WORKLOADS
    n_docs = 400 if quick else 1000
    rows = []
    data = {}
    for w in workloads:
        spec = WORKLOADS[w]
        avg_tokens = spec.avg_words / 0.75
        n_dev = 150 if w == "legal" else 200
        oc_tc = OptimizationCost(n_dev, avg_tokens, spec.op_tokens,
                                 (0.1, 0.25, 0.5, 1.0))
        oc_lite = OptimizationCost(n_dev, avg_tokens, spec.op_tokens,
                                   (0.1, 0.25, 0.5, 1.0), lite=True)
        c_tc, c_lite = oc_tc.total(), oc_lite.total()
        c_mc = oc_tc.model_cascade_cost()

        r_or = run_variant("oracle_only", w, n_docs=n_docs)
        r_mc = run_variant("model_cascade", w, n_docs=n_docs)
        r_tc = run_variant("task_cascades", w, n_docs=n_docs)
        r_li = run_variant("lite", w, n_docs=n_docs)
        n_test = n_docs - 200
        per = {k: r["total_cost"] / n_test
               for k, r in [("or", r_or), ("mc", r_mc), ("tc", r_tc),
                            ("li", r_li)]}
        be = {k: break_even_docs(c, per[k], per["or"])
              for k, c in [("tc", c_tc), ("li", c_lite), ("mc", c_mc)]}
        m = 1_000_000
        tot = {k: c + per[k2] * m
               for k, c, k2 in [("tc", c_tc, "tc"), ("li", c_lite, "li"),
                                ("mc", c_mc, "mc")]}
        data[w] = {"opt": (c_tc, c_lite, c_mc), "break_even": be,
                   "at_1m": tot}
        rows.append([
            w, f"${c_tc:.2f}", f"${c_lite:.2f}", f"${c_mc:.2f}",
            f"{be['tc']:.0f}", f"{be['li']:.0f}", f"{be['mc']:.0f}",
            f"${tot['tc']:.0f} ({tot['tc']/tot['mc']:.2f}x)",
            f"${tot['li']:.0f} ({tot['li']/tot['mc']:.2f}x)",
            f"${tot['mc']:.0f}",
        ])
    table = fmt_table(
        ["workload", "opt TC", "opt Lite", "opt 2MC",
         "break-even TC", "BE Lite", "BE 2MC",
         "@1M TC", "@1M Lite", "@1M 2MC"], rows)
    print(table)
    bes = [data[w]["break_even"]["tc"] for w in workloads
           if np.isfinite(data[w]["break_even"]["tc"])]
    print(f"\nmean TC break-even: {np.mean(bes):.0f} docs "
          f"(paper: 2,986)")
    return {"table": table, "data": data}


if __name__ == "__main__":
    run()
