"""Paper Figure 7 / §D: parameter sensitivity (n_s, n_a, fraction sets)."""
from __future__ import annotations

import numpy as np

from repro.core.pipeline import BuildConfig, build_task_cascade, \
    evaluate_on, model_cascade
from repro.core.simulation import make_workload

from .common import fmt_table, split


def run(quick: bool = False):
    workloads = ("enron", "games")
    n_docs = 400 if quick else 1000
    settings = (
        [("n_s", dict(n_s=v)) for v in ((3, 5) if quick else (3, 5, 10))] +
        [("n_a", dict(n_a=v)) for v in ((1,) if quick else (1, 2))] +
        [("F", dict(fractions=f)) for f in
         ((0.25, 1.0), (0.25, 0.5, 1.0))]
    )
    rows = []
    data = {}
    for w in workloads:
        wl = make_workload(w, n_docs)
        dev, test = split(wl)
        base = evaluate_on(test, model_cascade(dev, 0.9))
        for label, kw in settings:
            wl2 = make_workload(w, n_docs)
            dev2, test2 = split(wl2)
            r = evaluate_on(test2, build_task_cascade(
                dev2, BuildConfig(alpha=0.9, seed=0, **kw)))
            ratio = r["total_cost"] / max(base["total_cost"], 1e-9)
            data[(w, label, str(kw))] = (r["accuracy"], ratio)
            rows.append([w, f"{label}={list(kw.values())[0]}",
                         f"{r['accuracy']:.1%}", f"{ratio:.2f}x"])
    table = fmt_table(["workload", "setting", "accuracy",
                       "cost vs 2MC"], rows)
    print(table)
    ratios = [v[1] for v in data.values()]
    print(f"\nspread across settings: min {min(ratios):.2f}x "
          f"max {max(ratios):.2f}x (robustness claim: all beat or match)")
    return {"table": table}


if __name__ == "__main__":
    run()
