"""Paper Table 3: cost + accuracy at alpha=0.9, all methods & variants.

Main methods average 3 trials (as in the paper); ablation variants are
single-trial.  Costs are reported as multiples of the matching 2-Model
Cascade variant, mirroring the paper's table layout.
"""
from __future__ import annotations

import numpy as np

from .common import ALL_WORKLOADS, fmt_table, run_variant

MAIN = ["oracle_only", "model_cascade", "model_cascade_g",
        "task_cascades", "task_cascades_g", "lite"]
VARIANTS = ["no_surrogates", "single_iteration", "no_filtering",
            "naive_rag", "selectivity", "restructure_top25", "rag_nosur"]

PAPER_AVG = {"task_cascades": 0.59, "task_cascades_g": 0.52, "lite": 0.62,
             "no_surrogates": 1.21, "single_iteration": 0.66,
             "no_filtering": 1.55, "naive_rag": 0.65, "selectivity": 4.44,
             "restructure_top25": 1.81, "rag_nosur": 1.16}


def run(trials: int = 3, quick: bool = False):
    workloads = ALL_WORKLOADS[:3] if quick else ALL_WORKLOADS
    n_docs = 400 if quick else 1000
    results = {}
    for method in MAIN + VARIANTS:
        per_w = {}
        t = 1 if (method in VARIANTS or quick) else trials
        for w in workloads:
            accs, costs = [], []
            for s in range(t):
                r = run_variant(method, w, seed=s, n_docs=n_docs)
                accs.append(r["accuracy"])
                costs.append(r["total_cost"])
            per_w[w] = (float(np.mean(accs)), float(np.mean(costs)))
        results[method] = per_w

    rows = []
    for method in MAIN + VARIANTS:
        row = [method]
        base = "model_cascade_g" if method.endswith("_g") else "model_cascade"
        ratios = []
        for w in workloads:
            acc, cost = results[method][w]
            if method == "oracle_only":
                row.append(f"${cost:.2f}")
                continue
            if method.startswith("model_cascade"):
                row.append(f"{acc:.1%} ${cost:.2f}")
                continue
            ref_cost = results[base][w][1]
            ratio = cost / max(ref_cost, 1e-9)
            ratios.append(ratio)
            row.append(f"{acc:.1%} {ratio:.2f}x")
        if ratios:
            avg = float(np.mean(ratios))
            paper = PAPER_AVG.get(method)
            row.append(f"{avg:.2f}x" + (f" (paper {paper:.2f}x)" if paper
                                        else ""))
        else:
            row.append("-")
        rows.append(row)
    table = fmt_table(["method"] + list(workloads) + ["avg ratio"], rows)
    print(table)
    return {"table": table, "results": {
        m: {w: results[m][w] for w in workloads} for m in results}}


if __name__ == "__main__":
    run()
