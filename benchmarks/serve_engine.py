"""Serving-engine benchmark: static data-plane comparison + streaming
(Poisson-arrival) workload.

Static section (PR 1): the same task cascade over the same corpus through

  * the SEED engine (``serving.legacy_engine``): per-doc dict cache,
    per-stage ``_stack_states``/``_slice_states`` pytree rebuilds, eager
    model dispatch, whole-batch re-prefill on mixed cached lengths;
  * the ARENA engine (``serving.engine``): persistent slot-based KV
    arenas, jitted per-(bucket, cached_len) stage steps, gather/scatter
    survivor compaction, kv_len-masked op suffixes.

Streaming section (PR 2): documents arrive as a Poisson process and three
control planes serve the stream —

  * ``request_loop``: the continuous-batching loop (``submit``/``step``)
    admits each document the moment it arrives, packing cross-stage
    launches; veterans keep their KV caches, arrivals never force a
    re-prefill;
  * ``stage_sync``: the arena data plane driven stage-synchronously in
    WAVES — arrivals buffer while a whole cascade runs, then the next
    wave starts (the PR-1 control plane under streaming load);
  * ``legacy``: the seed engine driven in the same waves.

Multi-tenant section (PR 4): N concurrent queries with DISTINCT cascades
(overlapping launch signatures) served two ways —

  * ``shared``: one ``CascadeServer``; every query registered on it,
    documents from different queries merging into cross-query launches
    over one shared arena pool;
  * ``isolated``: N independent ``CascadeEngine``s, each with its own
    backends (own KV arenas), each serving only its own query.

A deterministic batch pass (same admission order both ways) checks exact
per-query $-parity + matching predictions and measures batch occupancy
(docs per launch) — the shared server packs partial per-query groups into
fuller launches, so occupancy rises and launch count falls.  A wall-clock
pass then streams N concurrent Poisson feeds for per-query p50/p99.

Paged section (PR 5): the paged data plane vs the PR-1 gather/scatter
stage step.  Copy traffic is STRUCTURAL (computed exactly from state
shapes): the gather step materializes a [B, s_alloc] row copy of every
state leaf per launch — decode-only launches included — while the paged
step reads the arena in place through slot ids in scalar-prefetch SMEM
(0 arena-copy bytes; only the O(B * op_len) op-suffix undo log moves).
Decode-only launch latency is A/B-measured on both planes, and a
pallas_interpret mini-engine asserts the two planes are bitwise-identical
(preds/confs/per-doc $).

Chaos section (PR 6): seeded fault injection (``serving.faults``) over a
two-tenant workload — launch failures, NaN confidences, latency spikes,
one arena-loss event, one expired deadline — asserting the
fault-tolerance invariants: every submitted document reaches a terminal
state (RESOLVED/FAILED/TIMED_OUT), per-query and per-document
$-accounting replay the billing ledger EXACTLY, and a mid-flight crash
warm-restarts from the write-ahead journal with resolved documents
restored verbatim.  ``--chaos-seed`` picks the schedule; ``--chaos-only``
runs just this section (fast CI job).  Injection runs on separate
backends after the fault-free metrics, so the fault-free smoke summary
stays byte-identical to the committed baseline.

Reports p50/p99 per-document latency (scheduled arrival -> resolution),
docs/sec, cache-hit rate, and $-cost per control plane.  Engines are
compile-warmed on the same corpus before the timed pass.

    PYTHONPATH=src python benchmarks/serve_engine.py --docs 512 \
        --stream-docs 96 --out BENCH_serve_engine.json

``--smoke`` runs a tiny CPU workload (including a 2-query multi-tenant
case, so CI exercises mixed-query launches), asserts non-empty stats, and
writes a MACHINE-READABLE deterministic summary (fixed workload
constants; timing-free metrics only: token counts, $, launch counts,
occupancy, copy bytes, parity flags) to ``--out`` (default
``BENCH_smoke.json``).  ``benchmarks/check_regression.py`` diffs that
summary against the ``"smoke"`` section committed in
``BENCH_serve_engine.json`` and fails CI on drift.  Full runs embed the
identical gate section (same fixed constants), so regenerating the
baseline is just re-running this benchmark.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.launch.serve import (drive_request_loop, drive_server,
                                poisson_arrivals, warm_arena)
from repro.models.model import LM
from repro.models.runtime import CPU_TEST, Runtime
from repro.serving.engine import (CascadeEngine, CascadeServer, LMBackend,
                                  RequestJournal)
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.legacy_engine import DictCacheLMBackend, SeedCascadeEngine
from repro.serving.scheduler import TERMINAL_STATES, TIMED_OUT, RetryPolicy

OPS = {
    "o_orig": "does this opinion overturn a lower court decision",
    "sur_1": "is any lower court mentioned",
}


def _model(seed: int):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    m = LM(resolve(cfg, tp=1), CPU_TEST)
    return m, m.init(jax.random.PRNGKey(seed))


# Extra LMBackend kwargs applied to EVERY arena backend the benchmark
# builds (set from ``--kv-dtype``); explicit per-call kwargs win, so the
# capacity section's fixed arms are immune to the CLI flag.
_ARENA_KW: dict = {}

# Dispatch-window depth for every ``CascadeServer`` the benchmark builds
# (set from ``--inflight``).  Overlapped dispatch is bitwise inert on the
# fault-free plane — preds/confs/per-doc $ and launch schedules are
# identical at any depth — so the SAME committed gate baseline serves
# the ``--inflight 4`` CI legs; the telemetry trace probe pins its own
# depth (its chaos RNG interleaving, and so its exactly-gated structural
# counts, depend on dispatch/completion order).
_INFLIGHT: int = 1


def make_backends(kind: str, tokz, models, **kw):
    cls = {"seed": DictCacheLMBackend, "arena": LMBackend}[kind]
    rates = {"proxy": 0.06, "oracle": 1.0}
    if kind == "arena":
        kw = {**_ARENA_KW, **kw}
    else:
        kw = {}            # the seed engine has no arena to compress
    return {
        name: cls(name=name, model=m, params=p, tokenizer=tokz,
                  rate_per_token=rates[name], s_alloc=512, **kw)
        for name, (m, p) in models.items()
    }


def make_engine(kind: str, tokz, models, batch_size: int, **kw):
    backends = make_backends(kind, tokz, models, **kw)
    cls = {"seed": SeedCascadeEngine, "arena": CascadeEngine}[kind]
    return cls(backends, OPS, n_classes=2, batch_size=batch_size), backends


def forced_ladder():
    """Impossible thresholds: every doc walks the whole ladder, so every
    control plane does IDENTICAL token work and the comparison isolates
    scheduling + data plane."""
    thr = {0: 2.0, 1: 2.0}
    return Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])


# ---------------------------------------------------------------------------
# Static (PR-1) section: seed vs arena, same corpus, batch semantics
# ---------------------------------------------------------------------------

def run_static(kind: str, cascade, docs, tokz, models, batch_size: int):
    eng, backends = make_engine(kind, tokz, models, batch_size)
    result = {}
    for run in ("cold", "warm"):
        t0 = time.perf_counter()
        out = eng.run(cascade, docs)
        wall = time.perf_counter() - t0
        stats = out[2] if kind == "seed" else out.stats
        cost = out[1] if kind == "seed" else out.cost
        host = sum(be.host_overhead_s for be in backends.values())
        result[run] = {
            "wall_s": round(wall, 4),
            "docs_per_s": round(len(docs) / wall, 3),
            "host_overhead_s": round(host, 4),
            "host_overhead_per_batch_ms":
                round(1e3 * host / max(stats.batches, 1), 4),
            "batches": stats.batches,
            "cache_hit_rate": round(stats.cache_hit_rate(), 4),
            "new_tokens": stats.total_new_tokens(),
            "cached_tokens": stats.total_cached_tokens(),
            "cost": round(cost, 4),
            "stage_cost": [round(c, 4) for c in stats.stage_cost],
        }
    return result


# ---------------------------------------------------------------------------
# Streaming section: Poisson arrivals, three control planes
# ---------------------------------------------------------------------------

def _stream_report(n_docs, wall, latencies, new_tok, cached_tok, cost,
                   batches, evictions=None):
    tot = new_tok + cached_tok
    lat = np.asarray(latencies) if latencies else np.zeros(1)
    rep = {
        "wall_s": round(wall, 4),
        "docs_per_s": round(n_docs / max(wall, 1e-9), 3),
        "latency_p50_ms": round(1e3 * float(np.quantile(lat, 0.5)), 1),
        "latency_p99_ms": round(1e3 * float(np.quantile(lat, 0.99)), 1),
        "batches": batches,
        "cache_hit_rate": round(cached_tok / tot if tot else 0.0, 4),
        "new_tokens": int(new_tok),
        "cached_tokens": int(cached_tok),
        "cost": round(cost, 4),
    }
    if evictions is not None:
        rep["evictions"] = evictions
    return rep


def stream_request_loop(cascade, docs, arrivals, tokz, models,
                        batch_size: int):
    eng, _ = make_engine("arena", tokz, models, batch_size)
    warm_arena(eng, cascade, docs, batch_size)
    res, wall = drive_request_loop(eng, cascade, docs, arrivals)
    assert set(res.pred) == set(docs)
    st = res.stats
    return _stream_report(
        len(docs), wall, st.latencies, st.total_new_tokens(),
        st.total_cached_tokens(), res.cost, st.batches,
        evictions=st.evictions)


def stream_waves(kind: str, cascade, docs, arrivals, tokz, models,
                 batch_size: int):
    """Stage-synchronous streaming baseline: arrivals buffer during each
    whole-cascade ``run()`` wave and are only admitted at the next wave."""
    eng, _ = make_engine(kind, tokz, models, batch_size)
    if kind == "seed":
        eng.run(cascade, docs)                   # eager: one warm pass
    else:
        warm_arena(eng, cascade, docs, batch_size)
    order = sorted(docs, key=lambda d: (arrivals[d], d))
    t0 = time.perf_counter()
    i = 0
    latencies = []
    new_tok = cached_tok = batches = 0
    cost = 0.0
    resolved = 0
    while i < len(order):
        now = time.perf_counter() - t0
        wave = []
        while i < len(order) and arrivals[order[i]] <= now:
            wave.append(order[i])
            i += 1
        if not wave:
            time.sleep(min(arrivals[order[i]] - now, 0.05))
            continue
        out = eng.run(cascade, {d: docs[d] for d in wave})
        stats = out[2] if kind == "seed" else out.stats
        cost += out[1] if kind == "seed" else out.cost
        end = time.perf_counter() - t0
        latencies += [end - arrivals[d] for d in wave]
        new_tok += stats.total_new_tokens()
        cached_tok += stats.total_cached_tokens()
        batches += stats.batches
        resolved += len(wave)
    wall = time.perf_counter() - t0
    assert resolved == len(docs)
    return _stream_report(len(docs), wall, latencies, new_tok, cached_tok,
                          cost, batches)


# ---------------------------------------------------------------------------
# Multi-tenant section: N concurrent queries, shared server vs isolated
# ---------------------------------------------------------------------------

def tenant_cascades(n_tenants: int):
    """Distinct per-tenant cascades with OVERLAPPING signatures: every
    tenant opens with the same cheap screen (stage-0 launches merge) and
    shares the oracle fall-through; stage 1 alternates between the
    original and the surrogate operation.  Impossible thresholds keep the
    token work deterministic, so occupancy/parity isolate scheduling."""
    thr = {0: 2.0, 1: 2.0}
    variants = [
        Cascade([Task(TaskConfig("proxy", "sur_1", 0.25), thr),
                 Task(TaskConfig("proxy", "o_orig", 1.0), thr)]),
        Cascade([Task(TaskConfig("proxy", "sur_1", 0.25), thr),
                 Task(TaskConfig("proxy", "sur_1", 1.0), thr)]),
    ]
    return [variants[k % len(variants)] for k in range(n_tenants)]


def _tenant_split(docs, n_tenants: int):
    ids = sorted(docs)
    tdocs = [{d: docs[d] for d in ids[k::n_tenants]}
             for k in range(n_tenants)]
    return tdocs, [sorted(t) for t in tdocs]


def interactive_replay(eng, cascades, tdocs, order, batch_size: int):
    """Deterministic isolated-vs-shared replay (no wall clock): one
    document per tenant per tick, served to idle between ticks — the
    interactive regime where requests trickle in.  An ISOLATED engine can
    never batch across queries (every launch is width 1); the shared
    server merges same-tick arrivals and survivors whose static
    signatures agree.  Shared by the multi-tenant section and the CI
    smoke gate, so the gate baseline measures exactly the benchmark's
    replay semantics.  Returns (iso_results, shared_results, server).
    """
    n_tenants = len(cascades)
    iso = []
    for k in range(n_tenants):
        eng.start(cascades[k])
        for j, d in enumerate(order[k]):
            eng.submit(d, tdocs[k][d], arrival=float(j))
            while eng.pending():               # serve this tick to idle
                eng.step()
        iso.append(eng.result())
    # shared: every query registered on ONE server over the SAME backends
    # (compile caches carry over; arenas reset per session); the k-th
    # tenant's j-th document arrives at tick j for every tenant
    server = CascadeServer(eng.backends, OPS, n_classes=2,
                           batch_size=batch_size, inflight=_INFLIGHT)
    server.reset()
    handles = [server.register(c) for c in cascades]
    for j in range(max(len(o) for o in order)):
        for k in range(n_tenants):
            if j < len(order[k]):
                handles[k].submit(order[k][j], tdocs[k][order[k][j]],
                                  arrival=float(j))
        while server.pending():
            server.step()
    out = server.drain()
    return iso, [out[h.query_id] for h in handles], server


def run_multi_tenant(docs, tokz, models, batch_size: int, rate: float,
                     seed: int, n_tenants: int = 2):
    """Shared ``CascadeServer`` vs per-query isolation, same workload.

    Interactive replay (``interactive_replay``): deterministic, untimed;
    per-query $-parity must be EXACT per document and predictions must
    match the isolated engines'.  Streaming pass (wall clock): N
    concurrent Poisson feeds on the shared server vs each feed served
    alone, per-query p50/p99.
    """
    cascades = tenant_cascades(n_tenants)
    tdocs, order = _tenant_split(docs, n_tenants)
    arrivals = [poisson_arrivals(order[k], rate, seed + k)
                for k in range(n_tenants)]

    eng, _ = make_engine("arena", tokz, models, batch_size)
    distinct = {tuple(t.config.key() for t in c.tasks): c for c in cascades}
    for c in distinct.values():
        warm_arena(eng, c, docs, batch_size)

    iso_batch, shared_batch, server = interactive_replay(
        eng, cascades, tdocs, order, batch_size)
    iso_launches = sum(r.stats.batches for r in iso_batch)
    iso_docs = sum(sum(r.stats.stage_docs) for r in iso_batch)
    shared_launches = server.stats().batches
    shared_occupancy = server.occupancy()

    pred_match = all(shared_batch[k].pred == iso_batch[k].pred
                     for k in range(n_tenants))
    cost_parity = all(shared_batch[k].doc_cost == iso_batch[k].doc_cost
                      for k in range(n_tenants))

    # ---- isolated streaming: each Poisson feed served alone
    iso_stream = []
    for k in range(n_tenants):
        sres, wall = drive_request_loop(eng, cascades[k], tdocs[k],
                                        arrivals[k])
        st = sres.stats
        iso_stream.append(_stream_report(
            len(tdocs[k]), wall, st.latencies, st.total_new_tokens(),
            st.total_cached_tokens(), sres.cost, st.batches))

    # ---- shared streaming: N concurrent Poisson feeds, one wall clock
    server.reset()
    handles = [server.register(c) for c in cascades]
    streams = [(handles[k], tdocs[k], arrivals[k])
               for k in range(n_tenants)]
    results, wall = drive_server(server, streams)
    shared_stream = []
    for k, h in enumerate(handles):
        st = results[h.query_id].stats
        shared_stream.append(_stream_report(
            len(tdocs[k]), wall, st.latencies, st.total_new_tokens(),
            st.total_cached_tokens(), results[h.query_id].cost, st.batches))
    stream_occupancy = server.occupancy()

    iso_occupancy = iso_docs / max(iso_launches, 1)
    return {
        "n_tenants": n_tenants,
        "docs_per_tenant": [len(t) for t in tdocs],
        "rate_docs_per_s_per_tenant": round(rate, 3),
        "interactive": {
            "shared": {
                "launches": shared_launches,
                "occupancy": round(shared_occupancy, 3),
                "per_query_cost": [round(r.cost, 4) for r in shared_batch],
            },
            "isolated": {
                "launches": iso_launches,
                "occupancy": round(iso_occupancy, 3),
                "per_query_cost": [round(r.cost, 4) for r in iso_batch],
            },
            "pred_match": pred_match,
            "doc_cost_parity_exact": cost_parity,
            "launch_reduction": round(iso_launches
                                      / max(shared_launches, 1), 2),
            "occupancy_gain": round(shared_occupancy
                                    / max(iso_occupancy, 1e-9), 2),
        },
        "streaming": {
            "shared": {"wall_s": round(wall, 4),
                       "occupancy": round(stream_occupancy, 3),
                       "per_query": shared_stream},
            "isolated": {"per_query": iso_stream},
        },
    }


# ---------------------------------------------------------------------------
# Paged section: in-kernel slot lookup vs the gather/scatter stage step
# ---------------------------------------------------------------------------

def _paged_backend(tokz, paged: bool, seed: int = 3):
    m, p = _model(seed)
    return LMBackend(name="proxy", model=m, params=p, tokenizer=tokz,
                     rate_per_token=0.06, s_alloc=512, paged=paged)


def paged_parity_check():
    """Bitwise A/B on a pallas_interpret mini-engine: the paged stage step
    must reproduce the gather step's preds/confs/per-doc $ EXACTLY (the
    undo log keeps even the arena contents bitwise equal)."""
    rt = Runtime(attn_impl="pallas_interpret", block_q=16, block_kv=16,
                 remat=False)
    tokz = HashWordTokenizer(vocab_size=512)
    # 50 words: ceil(50 * 0.25) = 13 < fraction_len(64, 0.25) = 16, so the
    # op suffix decodes over live document KV — the undo log's hard case
    docs = {0: " ".join(f"a{j}" for j in range(20)),
            1: " ".join(f"b{j}" for j in range(50))}
    thr = {0: 2.0, 1: 2.0}
    ladder = Cascade([Task(TaskConfig("proxy", "sur_1", 0.25), thr),
                      Task(TaskConfig("proxy", "o_orig", 0.5), thr)])
    out = {}
    for paged in (False, True):
        def be(name, seed):
            cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                              num_layers=2)
            m = LM(resolve(cfg, tp=1), rt)
            return LMBackend(
                name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
                tokenizer=tokz,
                rate_per_token=1.0 if name == "oracle" else 0.06,
                s_alloc=512, paged=paged)
        eng = CascadeEngine({"proxy": be("proxy", 1),
                             "oracle": be("oracle", 2)},
                            OPS, n_classes=2, batch_size=2)
        out[paged] = eng.run(ladder, docs)
    return {
        "pred_match": out[False].pred == out[True].pred,
        "conf_bitwise": out[False].conf == out[True].conf,
        "doc_cost_parity_exact": out[False].doc_cost == out[True].doc_cost,
    }


def run_paged_section(tokz, smoke: bool):
    """Copy-traffic model (exact, from state shapes) + decode-launch
    latency A/B across bucket sizes + the bitwise parity check."""
    op = np.asarray(tokz.encode(OPS["o_orig"]), np.int32)
    buckets = (64,) if smoke else (64, 128, 256)
    batch = 4 if smoke else 8
    iters = 3 if smoke else 10
    be = {False: _paged_backend(tokz, False), True: _paged_backend(tokz, True)}
    section = {
        "note": "copy bytes are structural (exact, from state shapes); "
                "latency measured on CPU xla — the paged plane there uses "
                "the kernels' gather fallback, so HBM savings show on "
                "Pallas runtimes, not in these wall-clocks",
        "op_len": int(len(op)),
        "batch": batch,
        "per_bucket": {},
    }
    for bucket in buckets:
        n_words = int(bucket * 0.8)
        # doc ids are unique per bucket: a document stays staged in one
        # bucket for its lifetime on a given backend
        toks = {bucket * 1000 + i: np.asarray(
            tokz.encode(" ".join(f"w{i}q{j}" for j in range(n_words))),
            np.int32) for i in range(batch)}
        row = {
            "gather_copy_bytes_per_launch":
                be[False].gather_bytes_per_launch(bucket, batch),
            "paged_arena_copy_bytes_per_launch": 0,
            "paged_undo_log_bytes_per_launch":
                be[True].paged_copy_bytes_per_launch(bucket, batch, len(op)),
        }
        row["copy_reduction"] = round(
            row["gather_copy_bytes_per_launch"]
            / max(row["paged_undo_log_bytes_per_launch"], 1), 1)
        for paged in (False, True):
            b = be[paged]
            ids = list(toks)
            b.run_stage(ids, toks, bucket, 1.0, op, 2)   # prefill + compile
            b.run_stage(ids, toks, bucket, 1.0, op, 2)   # warm decode-only
            t0 = time.perf_counter()
            for _ in range(iters):
                b.run_stage(ids, toks, bucket, 1.0, op, 2)
            ms = 1e3 * (time.perf_counter() - t0) / iters
            key = "paged" if paged else "gather"
            row[f"{key}_decode_launch_ms"] = round(ms, 3)
        section["per_bucket"][str(bucket)] = row
    print("== paged parity (pallas_interpret mini-engine) ==", flush=True)
    section["parity"] = paged_parity_check()
    assert all(section["parity"].values()), section["parity"]
    return section


# ---------------------------------------------------------------------------
# Chaos section: seeded fault injection; terminal-state + accounting gates
# ---------------------------------------------------------------------------

CHAOS_DOCS = 12
CHAOS_SEED = 23          # default --chaos-seed


def _accounting_exact(server) -> bool:
    """Replaying the billing ledger (same float additions, same order)
    must reproduce per-query AND per-document $ EXACTLY — the chaos
    invariant: however many retries/quarantines/recoveries happened,
    every billed launch is attributed exactly once."""
    per_q = {qid: 0.0 for qid in server._handles}
    per_doc = {}
    for _, qid, rid, cost in server.ledger():
        per_q[qid] += cost
        per_doc[rid] = per_doc.get(rid, 0.0) + cost
    if any(total != server.cost(qid) for qid, total in per_q.items()):
        return False
    return all(per_doc.get(rid, 0.0) == req.cost
               for rid, req in server._requests.items())


def _chaos_server(models, tokz, journal=None, inflight=None):
    return CascadeServer(
        make_backends("arena", tokz, models), OPS, n_classes=2,
        batch_size=GATE_BATCH,
        # backoff 0 keeps the launch schedule (and so the fault schedule)
        # a pure function of the chaos seed — no wall-clock in the loop
        retry=RetryPolicy(max_retries=2, backoff_base=0.0), journal=journal,
        inflight=_INFLIGHT if inflight is None else inflight)


def _chaos_submit(server, docs):
    """Two tenants, logical-tick arrivals; the first document of tenant 0
    carries an already-expired deadline — a deterministic TIMED_OUT."""
    cascades = tenant_cascades(GATE_TENANTS)
    tdocs, order = _tenant_split(docs, GATE_TENANTS)
    handles = [server.register(c) for c in cascades]
    futs = {}
    for k, h in enumerate(handles):
        for j, d in enumerate(order[k]):
            deadline = 0.0 if (k == 0 and j == 0) else None
            futs[(h.query_id, d)] = h.submit(d, tdocs[k][d],
                                             arrival=float(j),
                                             deadline_s=deadline)
    return handles, futs


def run_chaos_section(chaos_seed: int, models, tokz):
    """Fault-injected serving: every submitted document must reach a
    terminal state (RESOLVED/FAILED/TIMED_OUT) and $-accounting must stay
    exact; then a mid-flight "crash" is recovered from the write-ahead
    journal.  All invariants are booleans gated by check_regression.py
    (chaos COUNTS vary with the seed and are reported, not gated)."""
    docs = {d.doc_id: d.text
            for d in generate_corpus(CHAOS_DOCS, avg_lines=12,
                                     seed=GATE_SEED)}
    plan = FaultPlan(seed=chaos_seed, launch_failure_p=0.25, nan_p=0.15,
                     latency_spike_p=0.1, spike_s=1e-4, arena_loss_at=4)

    # ---- part A: chaotic drain on one server
    server = _chaos_server(models, tokz)
    inj = FaultInjector(plan).install(server)
    handles, futs = _chaos_submit(server, docs)
    server.drain()
    statuses = {k: f.status for k, f in futs.items()}
    agg = server.stats()
    part_a = {
        "all_docs_terminal": all(f.done for f in futs.values())
        and all(s in TERMINAL_STATES for s in statuses.values()),
        "accounting_exact": _accounting_exact(server),
        "deadline_timed_out":
            statuses[(handles[0].query_id, sorted(docs)[0])] == TIMED_OUT,
        "arena_loss_injected": inj.counts["arena_losses"] == 1,
    }
    counters = {
        "injected": dict(inj.counts),
        "retries": agg.retries, "quarantines": agg.quarantines,
        "timeouts": agg.timeouts, "failures": agg.failures,
        "breaker_trips": agg.breaker_trips,
        "recovered_docs": agg.recovered_docs,
        "terminal_states": {s: sum(1 for v in statuses.values() if v == s)
                            for s in sorted(set(statuses.values()))},
    }

    # ---- part B: crash mid-flight, warm-restart from the journal
    crashed = _chaos_server(models, tokz, journal=RequestJournal())
    FaultInjector(plan).install(crashed)
    _chaos_submit(crashed, docs)
    for _ in range(4):                      # partial progress, then "crash"
        crashed.step()
    journal = crashed.journal
    pre = dict(journal.resolutions)

    fresh = _chaos_server(models, tokz, journal=RequestJournal())
    for c in tenant_cascades(GATE_TENANTS):     # same cascades, same order
        fresh.register(c)
    rec_futs = fresh.recover(journal)
    restored_exact = all(
        rec_futs[key].done
        and rec_futs[key].status == res["status"]
        and rec_futs[key].pred == res["pred"]
        and rec_futs[key].cost == res["cost"]
        for key, res in pre.items())
    fresh.drain()
    part_b = {
        "recovery_all_terminal":
            all(f.done and f.status in TERMINAL_STATES
                for f in rec_futs.values()),
        "recovery_restored_exact": restored_exact,
        "recovery_accounting_exact": _accounting_exact(fresh),
    }
    counters["journal"] = {
        "submitted": len(journal.submits),
        "resolved_before_crash": len(pre),
        "resubmitted": len(journal.submits) - len(pre),
    }

    section = {"seed": chaos_seed, "docs": CHAOS_DOCS, **part_a, **part_b,
               "counters": counters}
    invariants = [k for k in (*part_a, *part_b)]
    failed = [k for k in invariants if section[k] is not True]
    assert not failed, f"chaos invariants failed: {failed}"
    return section


# ---------------------------------------------------------------------------
# Capacity section (PR 7): prefix-sharing + bf16 KV arenas under overload
# ---------------------------------------------------------------------------

# Three arms, all explicit (immune to --kv-dtype): the PR-1 doc-before-op
# plane, the op-first prefix-sharing plane, and prefix sharing over a
# bf16-compressed arena.  kv_dtype=None keeps the model compute dtype.
CAP_ARMS = {
    "f32_private": dict(prefix_sharing=False, kv_dtype=None),
    "f32_prefix": dict(prefix_sharing=True, kv_dtype=None),
    "bf16_prefix": dict(prefix_sharing=True, kv_dtype="bfloat16"),
}
# bf16 vs f32 prediction/confidence drift bounds (empirically ~1.0 match
# and <1e-3 max |dconf| on the gate workload; wide margins keep the gate
# about correctness, not numerics)
CAP_BF16_PRED_MATCH_MIN = 0.75
CAP_BF16_DCONF_MAX = 0.05
CAP_REPREFILL_RATIO_MIN = 1.8


def same_op_ladder():
    """Both stages run o_orig: $-parity between the doc-before-op and
    op-first planes holds exactly on SAME-op fraction ladders.  (The
    op-first layout bakes the op prefix into every document's KV — the
    doc attends to it — so an op switch invalidates the doc cache and
    stage 2 re-prefills; ``forced_ladder``'s sur_1 -> o_orig switch is
    covered by tests/test_prefix_sharing.py, not gated here.)"""
    thr = {0: 2.0, 1: 2.0}
    return Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])


def _cap_run(tokz, docs, arm_kw, byte_budget=None):
    """One capacity arm: fresh backends, same-op forced ladder, and a
    PRIORITY-INVERTED arrival burst — each newcomer is submitted with an
    arrival older than every cached veteran's (arrival=-j) and stepped
    immediately, so under a budget its launch must steal slots from
    cached documents (a batch drain would resolve veterans first and
    recycle their slots without ever evicting; this burst is the
    overload's adversarial limit).  Deterministic: logical arrivals, no
    wall clock.  Returns (engine result, metric row, backends)."""
    models = {"proxy": _model(1), "oracle": _model(2)}
    backends = make_backends("arena", tokz, models, byte_budget=byte_budget,
                             **arm_kw)
    eng = CascadeEngine(backends, OPS, n_classes=2, batch_size=GATE_BATCH)
    eng.start(same_op_ladder())
    for j, d in enumerate(sorted(docs)):
        eng.submit(d, docs[d], arrival=float(-j))
        eng.step()
    res = eng.drain()
    assert set(res.pred) == set(docs), "capacity arm dropped documents"
    st = res.stats
    row = {
        "evictions": int(st.evictions),
        "re_prefill_tokens": int(st.re_prefill_tokens),
        "prefix_hits": int(st.prefix_hits),
        "cow_copies": int(st.cow_copies),
        "arena_bytes_peak": int(st.arena_bytes_peak),
        "launches": int(st.batches),
        "cost": round(float(res.cost), 6),
    }
    return res, row, backends


def run_capacity_section(tokz, smoke: bool):
    """Fixed byte budget, three arms: f32 private KV (PR-1 plane), f32 +
    prefix sharing, bf16 + prefix sharing.

    Pass 1 (no pressure) is the correctness gate: per-document $ must be
    EXACTLY equal across all three arms — the op-token memo and the bf16
    compression change the physical work, never the billing — and bf16
    preds/confs must sit within quantization tolerance of f32.

    Pass 2 fixes ``byte_budget`` to HALF the f32 arms' unbudgeted peak
    and drains the same burst: the f32 arms thrash (evict + re-prefill)
    while bf16 halves the bytes per row — ~2x the effective rows in the
    same budget — so the same overload resolves with strictly fewer
    evictions and >= 1.8x fewer re-prefilled tokens.  Counts are
    deterministic (seeded corpus/params, batch drain, no wall clock) and
    gated exactly by check_regression.py.
    """
    docs = {d.doc_id: d.text
            for d in generate_corpus(GATE_DOCS, avg_lines=12,
                                     seed=GATE_SEED)}

    # ---- pass 1: unbudgeted — parity + tolerance + peak measurement
    free = {}
    results = {}
    for arm, kw in CAP_ARMS.items():
        results[arm], free[arm], _ = _cap_run(tokz, docs, kw)
    ids = sorted(docs)
    r32, rp, r16 = (results[a] for a in
                    ("f32_private", "f32_prefix", "bf16_prefix"))
    parity_exact = all(r32.doc_cost[d] == rp.doc_cost[d] == r16.doc_cost[d]
                       for d in ids)
    pred_match = float(np.mean([rp.pred[d] == r16.pred[d] for d in ids]))
    max_dconf = float(max(abs(rp.conf[d] - r16.conf[d]) for d in ids))
    parity = {
        "doc_cost_parity_exact": parity_exact,
        "bf16_pred_match": round(pred_match, 4),
        "bf16_max_dconf": round(max_dconf, 6),
        "bf16_within_tolerance": (pred_match >= CAP_BF16_PRED_MATCH_MIN
                                  and max_dconf <= CAP_BF16_DCONF_MAX),
    }
    assert parity["doc_cost_parity_exact"], \
        "prefix/bf16 arenas changed the $-ledger"
    assert parity["bf16_within_tolerance"], parity

    # ---- pass 2: fixed byte budget = half the f32 unbudgeted peak
    budget = free["f32_private"]["arena_bytes_peak"] // 2
    over = {}
    row_bytes = {}
    for arm, kw in CAP_ARMS.items():
        _, over[arm], backends = _cap_run(tokz, docs, kw, byte_budget=budget)
        row_bytes[arm] = backends["proxy"].slot_nbytes(128)
    a, b2 = over["f32_private"], over["bf16_prefix"]
    reduction = a["re_prefill_tokens"] / max(b2["re_prefill_tokens"], 1)
    overload = {
        **{arm: over[arm] for arm in CAP_ARMS},
        "fewer_evictions_bf16": b2["evictions"] < a["evictions"],
        "reprefill_reduction": round(reduction, 2),
        "reprefill_reduction_ge_1_8": reduction >= CAP_REPREFILL_RATIO_MIN,
    }
    assert a["evictions"] > 0, \
        "overload pass produced no pressure on the f32 arm"
    assert overload["fewer_evictions_bf16"], (a, b2)
    assert overload["reprefill_reduction_ge_1_8"], (a, b2)

    section = {
        "docs": GATE_DOCS,
        "ladder": "proxy o_orig 0.25 -> proxy o_orig 1.0 (forced)",
        "byte_budget": int(budget),
        # bf16 halves the per-row bytes, so the SAME budget hosts ~2x the
        # rows (the eviction-reduction workhorse)
        "effective_rows_at_budget": {
            arm: int(budget // row_bytes[arm]) for arm in CAP_ARMS},
        "parity": parity,
        "no_pressure": free,
        "overload": overload,
    }
    if not smoke:
        # Poisson overload (wall clock, reported not gated): the same
        # budget under a streamed burst — arrivals at 4x the nominal
        # service rate so admission outruns capacity
        stream = {}
        for arm, kw in CAP_ARMS.items():
            models = {"proxy": _model(1), "oracle": _model(2)}
            backends = make_backends("arena", tokz, models,
                                     byte_budget=budget, **kw)
            eng = CascadeEngine(backends, OPS, n_classes=2,
                                batch_size=GATE_BATCH)
            warm_arena(eng, same_op_ladder(), docs, GATE_BATCH)
            arrivals = poisson_arrivals(sorted(docs), 64.0, GATE_SEED)
            sres, wall = drive_request_loop(eng, same_op_ladder(), docs,
                                            arrivals)
            st = sres.stats
            stream[arm] = _stream_report(
                len(docs), wall, st.latencies, st.total_new_tokens(),
                st.total_cached_tokens(), sres.cost, st.batches,
                evictions=st.evictions)
            stream[arm]["re_prefill_tokens"] = int(st.re_prefill_tokens)
        section["poisson_overload"] = stream
    return section


# ---------------------------------------------------------------------------
# Telemetry section (PR 8): bitwise inertness + span/timeline invariants
# ---------------------------------------------------------------------------

def _arena_leaves(backends):
    """Every device leaf of every bucket arena, host-side, in a canonical
    order — the bitwise fingerprint for the telemetry-inertness probe
    (valid only when both runs share a launch schedule; the overlap
    section uses ``_capture_releases`` instead)."""
    out = []
    for name in sorted(backends):
        be = backends[name]
        for bucket in sorted(getattr(be, "_arenas", {})):
            for leaf in jax.tree_util.tree_leaves(be._arenas[bucket].states):
                out.append((name, bucket, np.asarray(leaf)))
    return out


def _capture_releases(backends):
    """Fingerprint every document's arena row at the moment it exits.

    Post-drain arena bytes are NOT comparable across launch schedules:
    dispatch order at K>1 legally differs from K=1 (the window fills
    with already-ready cohorts before a completion re-queues escalated
    docs), so doc->slot assignment permutes AND freed slots are reused
    in different orders, leaving schedule-dependent stale bytes past
    each new owner's valid region.  The schedule-independent contract
    is what a document LEAVES BEHIND: wrap ``release`` to snapshot the
    departing doc's valid KV window ``[0, cached_len)`` (its slot is
    still owned here, and eviction drains conflicting tickets before
    releasing, so no open ticket can be writing the row).  Returns the
    store, filled as ``(backend, bucket, doc) -> [(cached_len,
    true_len, bytes), ...]`` (a list: an evicted doc releases once per
    preemption plus once at exit)."""
    store = {}
    for nm in sorted(backends):
        be = backends[nm]
        orig = be.release

        def release(doc_id, be=be, orig=orig, nm=nm):
            bs = be._doc_slot.get(doc_id)
            if bs is not None:
                bucket, slot = bs
                ar = be._arenas.get(bucket)
                if ar is not None:
                    c = int(ar.cached_len[slot])
                    t = int(ar.true_len[slot])
                    if c == 0:
                        body = b""
                    elif be.model.supports_paged_kv:
                        win = be.model.take_kv_window(
                            ar.states, jnp.asarray([slot], jnp.int32),
                            jnp.asarray([0], jnp.int32), c)
                        body = b"".join(np.asarray(leaf).tobytes()
                                        for leaf in jax.tree.leaves(win))
                    else:       # no seq-axis contract: full row, best-effort
                        flat, _ = jax.tree_util.tree_flatten_with_path(
                            ar.states)
                        body = b"".join(
                            np.take(np.asarray(leaf), slot,
                                    axis=ar.model._state_batch_axis(path)
                                    ).tobytes()
                            for path, leaf in flat)
                    store.setdefault((nm, bucket, doc_id), []).append(
                        (c, t, body))
            orig(doc_id)

        be.release = release
    return store


def run_telemetry_section(models, tokz, trace_out=None):
    """Observability gates (PR 8), two probes on separate backends.

    INERTNESS: the default-on ``level="counters"`` telemetry must be
    bitwise invisible to the fault-free data plane — preds, confs,
    per-document $, and the full arena device state must equal a
    ``level="off"`` run exactly (instrumentation is host-side dict/float
    work plus ``perf_counter`` reads; nothing crosses into jitted code).

    TRACE PROBE: the chaos workload (fixed seed ``CHAOS_SEED`` — NOT
    ``--chaos-seed``, so these counts stay a pure function of the source
    tree and are gated exactly) re-runs at ``level="trace"``.  Spans must
    be well-formed under injected faults (SUBMIT-opened, terminal-closed,
    monotone stamps), nothing may be dropped at the gate workload's
    scale, and each launch's sched/host/dispatch/device segments must sum
    to its wall time within 5% (exact by construction: host is the
    clamped residual).  Structural counts (spans, events, launch records,
    metric series) are deterministic — the chaos launch schedule is a
    pure function of the seed and the call index (zero backoff, logical
    arrivals) — and gated exactly; timings in the embedded snapshot are
    reported, never gated.  ``trace_out`` additionally writes the probe's
    Chrome/Perfetto trace JSON (the CI artifact).
    """
    docs = {d.doc_id: d.text
            for d in generate_corpus(GATE_DOCS, avg_lines=12,
                                     seed=GATE_SEED)}

    # ---- inertness: counters (default) vs off, bitwise
    runs, arenas = {}, {}
    for level in ("off", "counters"):
        eng, backends = make_engine("arena", tokz, models, GATE_BATCH)
        eng.telemetry.level = level
        runs[level] = eng.run(forced_ladder(), docs)
        arenas[level] = _arena_leaves(backends)
    a, b = runs["off"], runs["counters"]
    inert = (a.pred == b.pred and a.conf == b.conf
             and a.doc_cost == b.doc_cost
             and len(arenas["off"]) == len(arenas["counters"])
             and all(ka == kb and ba == bb and np.array_equal(la, lb)
                     for (ka, ba, la), (kb, bb, lb)
                     in zip(arenas["off"], arenas["counters"])))

    # ---- trace probe: chaos workload at level="trace", fixed seed
    chaos_docs = {d.doc_id: d.text
                  for d in generate_corpus(CHAOS_DOCS, avg_lines=12,
                                           seed=GATE_SEED)}
    # depth pinned at 1: at K>1 the injector draws at dispatch order but
    # picks NaN victims at completion order, so the fault schedule — and
    # with it these exactly-gated structural counts — would depend on
    # ``--inflight`` (the overlap section and the chaos legs cover K>1)
    server = _chaos_server(models, tokz, inflight=1)
    server.telemetry.level = "trace"
    plan = FaultPlan(seed=CHAOS_SEED, launch_failure_p=0.25, nan_p=0.15,
                     latency_spike_p=0.1, spike_s=1e-4, arena_loss_at=4)
    FaultInjector(plan).install(server)
    _chaos_submit(server, chaos_docs)
    server.drain()
    snap = server.telemetry_snapshot()
    if trace_out:
        from repro.serving.telemetry import write_chrome_trace
        write_chrome_trace(server.telemetry, trace_out)
        print(f"wrote Perfetto trace to {trace_out} "
              f"(open at https://ui.perfetto.dev)", flush=True)
    c = snap["counters"]
    probe = {
        "seed": CHAOS_SEED,
        "docs": CHAOS_DOCS,
        # booleans, REQUIRED_TRUE in check_regression.py (no baseline)
        "spans_well_formed": bool(snap["spans"]["ok"]),
        "no_dropped_events": (c["dropped_events"] == 0
                              and c["dropped_launch_records"] == 0
                              and c["dropped_metric_series"] == 0),
        "segments_sum_ok": bool(c["segments_sum_ok"]),
        # structural counts, gated exactly against the baseline
        "spans": int(snap["spans"]["checked"]),
        "events_total": int(c["events_total"]),
        "launch_records": int(c["launch_records"]),
        "failed_launch_records": int(c["failed_launch_records"]),
        "metric_series": int(c["metric_series"]),
    }
    section = {
        "counters_bitwise_inert": bool(inert),
        "trace_probe": probe,
        # full snapshot for humans + CI artifacts; timings NOT gated
        "snapshot": snap,
    }
    assert section["counters_bitwise_inert"], \
        "level='counters' telemetry perturbed the fault-free data plane"
    assert probe["spans_well_formed"], snap["spans"]["violations"][:5]
    assert probe["no_dropped_events"], c
    assert probe["segments_sum_ok"], c
    return section


def run_overlap_section(models, tokz, inflight: int):
    """Overlapped ahead-of-time dispatch gate (ROADMAP item 2).

    Replays the multi-tenant interactive workload on FRESH backends at
    ``inflight=1`` and ``inflight=K`` (K >= 2 even when the smoke runs
    unflagged, so the overlap machinery is always exercised) and checks
    the contract: ahead-of-time dispatch may only change WHEN the host
    blocks, never what it computes — preds, confs, per-document $ and
    the arena row content every document leaves behind must be BITWISE
    identical (release-time capture; ``_capture_releases`` documents
    why post-drain leaves are not comparable) — while the K
    run must actually reach a dispatch-window depth >= 2 and publish the
    overlap metrics CI tracks.  The booleans are REQUIRED_TRUE in
    ``check_regression.py``; the overlap economics (gap, hidden
    fraction) are wall-clock and reported, never gated.
    """
    k = max(2, int(inflight))
    docs = {d.doc_id: d.text
            for d in generate_corpus(GATE_DOCS, avg_lines=12,
                                     seed=GATE_SEED)}
    cascades = tenant_cascades(GATE_TENANTS)
    tdocs, order = _tenant_split(docs, GATE_TENANTS)
    runs = {}
    for depth in (1, k):
        eng, backends = make_engine("arena", tokz, models, GATE_BATCH)
        captured = _capture_releases(backends)
        server = CascadeServer(eng.backends, OPS, n_classes=2,
                               batch_size=GATE_BATCH, inflight=depth)
        handles = [server.register(c) for c in cascades]
        for j in range(max(len(o) for o in order)):
            for t in range(GATE_TENANTS):
                if j < len(order[t]):
                    handles[t].submit(order[t][j], tdocs[t][order[t][j]],
                                      arrival=float(j))
            while server.pending():
                server.step()
        out = server.drain()
        runs[depth] = {"results": [out[h.query_id] for h in handles],
                       "rows": captured,
                       "snap": server.telemetry_snapshot()}
    r1, rk = runs[1]["results"], runs[k]["results"]
    l1, lk = runs[1]["rows"], runs[k]["rows"]
    tl1, tlk = runs[1]["snap"]["timeline"], runs[k]["snap"]["timeline"]
    parity = {
        "pred_match": all(a.pred == b.pred for a, b in zip(r1, rk)),
        "conf_bitwise": all(a.conf == b.conf for a, b in zip(r1, rk)),
        "doc_cost_parity_exact": all(a.doc_cost == b.doc_cost
                                     for a, b in zip(r1, rk)),
        # release-time row fingerprints, keyed (backend, bucket, doc):
        # the KV bytes each doc leaves behind, bitwise (see
        # _capture_releases for why post-drain leaves can't be compared)
        "arena_leaves_bitwise": bool(l1) and l1 == lk,
    }
    section = {
        "inflight": k,
        "max_inflight": int(runs[k]["snap"]["server"]["max_inflight"]),
        "max_inflight_ge_2":
            int(runs[k]["snap"]["server"]["max_inflight"]) >= 2,
        "metrics_present": ("overlap_hidden_frac" in tlk
                            and "mean_launch_gap_ms" in tlk),
        "parity": parity,
        # wall-clock overlap economics (artifact trajectories, NOT gated)
        "timings": {
            "mean_launch_gap_ms_inflight1": tl1["mean_launch_gap_ms"],
            "mean_launch_gap_ms": tlk["mean_launch_gap_ms"],
            "overlap_hidden_frac_inflight1": tl1["overlap_hidden_frac"],
            "overlap_hidden_frac": tlk["overlap_hidden_frac"],
            "inflight_s": tlk["inflight_s"],
            "device_s": tlk["device_s"],
        },
    }
    assert section["max_inflight_ge_2"], runs[k]["snap"]["server"]
    assert section["metrics_present"], sorted(tlk)
    assert all(parity.values()), parity
    return section


# ---------------------------------------------------------------------------
# Deterministic smoke-gate summary (CI benchmark-regression gate)
# ---------------------------------------------------------------------------

# Fixed workload constants — NEVER derived from CLI args, so the gate
# numbers are comparable across any invocation of this benchmark.
GATE_DOCS = 16
GATE_BATCH = 4
GATE_SEED = 7
GATE_TENANTS = 2


def smoke_gate_summary(parity=None, chaos_seed: int = CHAOS_SEED,
                       trace_out=None, inflight: int = 1):
    """Timing-free, machine-comparable summary for the CI regression gate.

    Every metric here is DETERMINISTIC for a given source tree: corpora
    and params are seeded, the tokenizer hashes with blake2, thresholds
    are forced impossible (no accuracy-dependent early exits), and the
    interactive replay admits documents on logical ticks rather than the
    wall clock.  ``check_regression.py`` compares these against the
    committed baseline with explicit tolerances.

    The ``chaos`` subsection runs the fault-injected workload
    (``run_chaos_section``) on SEPARATE backends AFTER the fault-free
    metrics are computed, so enabling injection cannot perturb them: the
    fault-free summary stays byte-identical to the committed baseline.

    ``parity`` reuses a ``paged_parity_check()`` result already computed
    by ``run_paged_section`` (the pallas_interpret A/B is the slowest
    piece of the smoke; no need to pay it twice per run).
    """
    tokz = HashWordTokenizer(vocab_size=512)
    models = {"proxy": _model(1), "oracle": _model(2)}
    corpus = generate_corpus(GATE_DOCS, avg_lines=12, seed=GATE_SEED)
    docs = {d.doc_id: d.text for d in corpus}

    # -- static: arena engine accounting on the forced ladder
    eng, _ = make_engine("arena", tokz, models, GATE_BATCH)
    res = eng.run(forced_ladder(), docs)
    static = {
        "new_tokens": int(res.stats.total_new_tokens()),
        "cached_tokens": int(res.stats.total_cached_tokens()),
        "cost": round(float(res.cost), 6),
        "launches": int(res.stats.batches),
        "cache_hit_rate": round(res.stats.cache_hit_rate(), 6),
        # arena/prefix counters (PR 7): peak device bytes across arenas
        # plus the prefix-sharing and eviction counters.  On the default
        # doc-before-op plane hits/copies/re-prefills are structurally 0;
        # the gate pins that (the capacity section exercises nonzero).
        "arena_bytes_peak": int(res.stats.arena_bytes_peak),
        "prefix_hits": int(res.stats.prefix_hits),
        "cow_copies": int(res.stats.cow_copies),
        "re_prefill_tokens": int(res.stats.re_prefill_tokens),
    }

    # -- multi-tenant interactive replay: shared server vs isolated
    # (same helper as the benchmark's multi-tenant section, so the gate
    # baseline measures exactly the benchmarked replay semantics)
    cascades = tenant_cascades(GATE_TENANTS)
    tdocs, order = _tenant_split(docs, GATE_TENANTS)
    iso, shared, server = interactive_replay(eng, cascades, tdocs, order,
                                             GATE_BATCH)
    iso_launches = sum(r.stats.batches for r in iso)
    iso_docs = sum(sum(r.stats.stage_docs) for r in iso)
    multi_tenant = {
        "shared_launches": int(server.stats().batches),
        "isolated_launches": int(iso_launches),
        "occupancy": round(server.occupancy(), 6),
        "isolated_occupancy": round(iso_docs / max(iso_launches, 1), 6),
        "per_query_cost": [round(float(r.cost), 6) for r in shared],
        "pred_match": all(shared[k].pred == iso[k].pred
                          for k in range(GATE_TENANTS)),
        "doc_cost_parity_exact": all(shared[k].doc_cost == iso[k].doc_cost
                                     for k in range(GATE_TENANTS)),
    }

    # -- paged plane: structural copy bytes + bitwise parity
    op = np.asarray(tokz.encode(OPS["o_orig"]), np.int32)
    be = _paged_backend(tokz, True)
    paged = {
        "bucket": 64,
        "batch": GATE_BATCH,
        "gather_copy_bytes_per_launch":
            int(be.gather_bytes_per_launch(64, GATE_BATCH)),
        "paged_arena_copy_bytes_per_launch": 0,
        "paged_undo_log_bytes_per_launch":
            int(be.paged_copy_bytes_per_launch(64, GATE_BATCH, len(op))),
        "parity": parity if parity is not None else paged_parity_check(),
    }

    # -- capacity: prefix-sharing + bf16 arenas, fixed byte budget
    # (explicit per-arm dtypes/planes: byte-identical whatever --kv-dtype
    # the rest of the smoke ran under)
    capacity = run_capacity_section(tokz, smoke=True)

    # -- chaos: fault-injected terminal-state + accounting invariants
    # (separate backends, computed last — cannot perturb the fault-free
    # metrics above)
    chaos = run_chaos_section(chaos_seed, models, tokz)

    # -- telemetry: counters-level bitwise inertness + trace-probe span /
    # timeline invariants (separate backends; fixed seed, so its
    # structural counts are exactly gateable whatever --chaos-seed is)
    telemetry = run_telemetry_section(models, tokz, trace_out=trace_out)

    # -- overlap: ahead-of-time dispatch parity + depth/metric gates
    # (fresh backends per arm; runs at K >= 2 regardless of --inflight)
    overlap = run_overlap_section(models, tokz, inflight)

    return {"static": static, "multi_tenant": multi_tenant, "paged": paged,
            "capacity": capacity, "chaos": chaos, "telemetry": telemetry,
            "overlap": overlap,
            "constants": {"docs": GATE_DOCS, "batch": GATE_BATCH,
                          "seed": GATE_SEED, "tenants": GATE_TENANTS}}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--stream-docs", type=int, default=96)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (docs/s); 0 = 0.6x the "
                         "arena engine's measured static throughput")
    ap.add_argument("--tenants", type=int, default=2,
                    help="concurrent queries in the multi-tenant section")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default=None,
                    help="output JSON (default BENCH_serve_engine.json; "
                         "BENCH_smoke.json under --smoke)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: assert non-empty stats and write "
                         "the deterministic gate summary only")
    ap.add_argument("--chaos-seed", type=int, default=CHAOS_SEED,
                    help="seed for the fault-injection chaos section")
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="write the telemetry trace probe's Chrome/"
                         "Perfetto trace-event JSON here (the CI smoke "
                         "uploads it as an artifact; open at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--kv-dtype", choices=("f32", "bf16"), default="f32",
                    help="KV-cache storage dtype for every arena backend; "
                         "bf16 halves arena bytes on the f32 models while "
                         "the $-ledger stays exactly unchanged, so the "
                         "same committed gate baseline applies to both "
                         "legs (the capacity section pins its own arm "
                         "dtypes and is immune to this flag)")
    ap.add_argument("--inflight", type=int, default=1,
                    help="dispatch-window depth for every CascadeServer "
                         "the benchmark builds (JAX async dispatch keeps "
                         "up to K launches in flight); fault-free "
                         "results are bitwise identical at any depth, "
                         "so the committed gate baseline applies to the "
                         "--inflight CI legs unchanged")
    ap.add_argument("--chaos-only", action="store_true",
                    help="run ONLY the chaos section (fast CI job): "
                         "asserts all-docs-terminal + exact accounting "
                         "under injected faults, writes {'chaos': ...}")
    args = ap.parse_args()
    if args.out is None:
        args.out = "BENCH_chaos.json" if args.chaos_only \
            else "BENCH_smoke.json" if args.smoke \
            else "BENCH_serve_engine.json"
    if args.smoke:
        args.docs = min(args.docs, 16)
        args.stream_docs = min(args.stream_docs, 12)
        args.batch_size = min(args.batch_size, 4)
    if args.kv_dtype == "bf16":
        _ARENA_KW["kv_dtype"] = "bfloat16"
    global _INFLIGHT
    _INFLIGHT = max(1, args.inflight)

    tokz = HashWordTokenizer(vocab_size=512)
    models = {"proxy": _model(1), "oracle": _model(2)}

    if args.chaos_only:
        print(f"== chaos (seed {args.chaos_seed}) ==", flush=True)
        chaos = run_chaos_section(args.chaos_seed, models, tokz)
        print(json.dumps(chaos, indent=2), flush=True)
        with open(args.out, "w") as f:
            json.dump({"chaos": chaos, "backend": jax.default_backend(),
                       "generated_by":
                           "benchmarks/serve_engine.py --chaos-only"}, f,
                      indent=2)
            f.write("\n")
        print(f"chaos OK; wrote {args.out}")
        return
    corpus = generate_corpus(args.docs, avg_lines=12, seed=args.seed)
    docs = {d.doc_id: d.text for d in corpus}
    cascade = forced_ladder()

    report = {"n_docs": args.docs, "batch_size": args.batch_size,
              "backend": jax.default_backend(),
              "workload": "synthetic court-opinion corpus (generate_corpus)"}
    for kind in ("seed", "arena"):
        print(f"== {kind} engine (static) ==", flush=True)
        report[kind] = run_static(kind, cascade, docs, tokz, models,
                                  args.batch_size)
        print(json.dumps(report[kind]["warm"], indent=2), flush=True)

    sw, aw = report["seed"]["warm"], report["arena"]["warm"]
    report["summary"] = {
        "docs_per_s_speedup": round(aw["docs_per_s"] / sw["docs_per_s"], 2),
        "host_overhead_reduction":
            round(sw["host_overhead_s"] / max(aw["host_overhead_s"], 1e-9),
                  2),
        "host_overhead_per_batch_reduction":
            round(sw["host_overhead_per_batch_ms"]
                  / max(aw["host_overhead_per_batch_ms"], 1e-9), 2),
    }
    print("static summary:", json.dumps(report["summary"], indent=2))

    # ---- streaming: Poisson arrivals over a subset of the corpus
    stream_ids = sorted(docs)[: args.stream_docs]
    stream_docs = {d: docs[d] for d in stream_ids}
    rate = args.rate or 0.6 * aw["docs_per_s"]
    arrivals = poisson_arrivals(stream_ids, rate, args.seed)
    streaming = {"n_docs": len(stream_ids), "rate_docs_per_s": round(rate, 3)}
    drivers = {
        "request_loop": lambda: stream_request_loop(
            cascade, stream_docs, arrivals, tokz, models, args.batch_size),
        "stage_sync": lambda: stream_waves(
            "arena", cascade, stream_docs, arrivals, tokz, models,
            args.batch_size),
        "legacy": lambda: stream_waves(
            "seed", cascade, stream_docs, arrivals, tokz, models,
            args.batch_size),
    }
    for name, fn in drivers.items():
        print(f"== {name} (streaming, rate {rate:.1f}/s) ==", flush=True)
        streaming[name] = fn()
        print(json.dumps(streaming[name], indent=2), flush=True)
    rl, ss = streaming["request_loop"], streaming["stage_sync"]
    streaming["summary"] = {
        "p50_speedup_vs_stage_sync":
            round(ss["latency_p50_ms"] / max(rl["latency_p50_ms"], 1e-9), 2),
        "p99_speedup_vs_stage_sync":
            round(ss["latency_p99_ms"] / max(rl["latency_p99_ms"], 1e-9), 2),
        "cache_hit_ge_stage_sync":
            rl["cache_hit_rate"] >= ss["cache_hit_rate"],
    }
    report["streaming"] = streaming
    print("streaming summary:", json.dumps(streaming["summary"], indent=2))

    # ---- multi-tenant: N concurrent queries, shared server vs isolation
    print(f"== multi-tenant ({args.tenants} queries, shared server vs "
          f"isolated) ==", flush=True)
    mt = run_multi_tenant(stream_docs, tokz, models, args.batch_size,
                          rate / args.tenants, args.seed,
                          n_tenants=args.tenants)
    report["multi_tenant"] = mt
    print(json.dumps(mt["interactive"], indent=2), flush=True)

    # ---- paged data plane: copy traffic + latency A/B + bitwise parity
    print("== paged vs gather (copy bytes, decode launch latency) ==",
          flush=True)
    report["paged"] = run_paged_section(tokz, args.smoke)
    print(json.dumps(report["paged"]["per_bucket"], indent=2), flush=True)

    # ---- capacity: prefix sharing + bf16 arenas under a fixed byte
    # budget (in --smoke the gate summary below runs the identical
    # deterministic passes itself; full runs add the Poisson leg)
    if not args.smoke:
        print("== capacity (prefix sharing + bf16 arenas, byte budget) ==",
              flush=True)
        report["capacity"] = run_capacity_section(tokz, smoke=False)
        print(json.dumps(report["capacity"]["overload"], indent=2),
              flush=True)

    # ---- deterministic gate summary (fixed constants; CI compares this;
    # the parity A/B from the paged section is reused, not recomputed)
    print("== smoke gate (deterministic summary) ==", flush=True)
    report["smoke"] = smoke_gate_summary(parity=report["paged"]["parity"],
                                         chaos_seed=args.chaos_seed,
                                         trace_out=args.trace_out,
                                         inflight=_INFLIGHT)
    print(json.dumps(report["smoke"], indent=2), flush=True)

    if args.smoke:
        assert rl["latency_p50_ms"] > 0 and rl["new_tokens"] > 0
        assert rl["cache_hit_rate"] >= ss["cache_hit_rate"]
        assert aw["new_tokens"] == sw["new_tokens"]   # identical token work
        # mixed-query launches: same preds and exact per-doc $ as isolated
        # engines, at strictly better batch occupancy
        mi = mt["interactive"]
        assert mi["pred_match"]
        assert mi["doc_cost_parity_exact"]
        assert mi["shared"]["occupancy"] > mi["isolated"]["occupancy"]
        assert mi["shared"]["launches"] < mi["isolated"]["launches"]
        # paged plane: zero arena-copy bytes per decode launch, bitwise
        # parity with the gather plane
        for row in report["paged"]["per_bucket"].values():
            assert row["paged_arena_copy_bytes_per_launch"] == 0
            assert row["gather_copy_bytes_per_launch"] \
                > row["paged_undo_log_bytes_per_launch"]
        assert all(report["paged"]["parity"].values())
        # capacity: exact $-parity across planes/dtypes, bf16 resolving
        # the same overload with fewer evictions and >= 1.8x fewer
        # re-prefilled tokens (run_capacity_section asserts these too)
        cap = report["smoke"]["capacity"]
        assert cap["parity"]["doc_cost_parity_exact"]
        assert cap["parity"]["bf16_within_tolerance"]
        assert cap["overload"]["fewer_evictions_bf16"]
        assert cap["overload"]["reprefill_reduction_ge_1_8"]
        # chaos: every injected-fault document terminal, $ exact, journal
        # recovery intact (run_chaos_section asserts these too)
        ch = report["smoke"]["chaos"]
        assert ch["all_docs_terminal"] and ch["accounting_exact"]
        assert ch["recovery_all_terminal"] and ch["recovery_restored_exact"]
        # telemetry: default counters level is bitwise inert; trace-probe
        # spans well-formed with exact per-launch segment accounting
        # (run_telemetry_section asserts these too)
        tel = report["smoke"]["telemetry"]
        assert tel["counters_bitwise_inert"]
        assert tel["trace_probe"]["spans_well_formed"]
        assert tel["trace_probe"]["no_dropped_events"]
        assert tel["trace_probe"]["segments_sum_ok"]
        # overlap (ahead-of-time dispatch): window depth actually reached,
        # overlap metrics published, bitwise parity vs inflight=1
        # (run_overlap_section asserts these too)
        ov = report["smoke"]["overlap"]
        assert ov["max_inflight_ge_2"] and ov["metrics_present"]
        assert all(ov["parity"].values())
        gate = {"smoke": report["smoke"],
                "backend": report["backend"],
                "generated_by": "benchmarks/serve_engine.py --smoke"}
        with open(args.out, "w") as f:
            json.dump(gate, f, indent=2)
            f.write("\n")
        print(f"smoke OK; wrote gate summary to {args.out}")
        return

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
