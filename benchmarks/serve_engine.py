"""Serving-engine benchmark: static data-plane comparison + streaming
(Poisson-arrival) workload.

Static section (PR 1): the same task cascade over the same corpus through

  * the SEED engine (``serving.legacy_engine``): per-doc dict cache,
    per-stage ``_stack_states``/``_slice_states`` pytree rebuilds, eager
    model dispatch, whole-batch re-prefill on mixed cached lengths;
  * the ARENA engine (``serving.engine``): persistent slot-based KV
    arenas, jitted per-(bucket, cached_len) stage steps, gather/scatter
    survivor compaction, kv_len-masked op suffixes.

Streaming section (PR 2): documents arrive as a Poisson process and three
control planes serve the stream —

  * ``request_loop``: the continuous-batching loop (``submit``/``step``)
    admits each document the moment it arrives, packing cross-stage
    launches; veterans keep their KV caches, arrivals never force a
    re-prefill;
  * ``stage_sync``: the arena data plane driven stage-synchronously in
    WAVES — arrivals buffer while a whole cascade runs, then the next
    wave starts (the PR-1 control plane under streaming load);
  * ``legacy``: the seed engine driven in the same waves.

Multi-tenant section (PR 4): N concurrent queries with DISTINCT cascades
(overlapping launch signatures) served two ways —

  * ``shared``: one ``CascadeServer``; every query registered on it,
    documents from different queries merging into cross-query launches
    over one shared arena pool;
  * ``isolated``: N independent ``CascadeEngine``s, each with its own
    backends (own KV arenas), each serving only its own query.

A deterministic batch pass (same admission order both ways) checks exact
per-query $-parity + matching predictions and measures batch occupancy
(docs per launch) — the shared server packs partial per-query groups into
fuller launches, so occupancy rises and launch count falls.  A wall-clock
pass then streams N concurrent Poisson feeds for per-query p50/p99.

Reports p50/p99 per-document latency (scheduled arrival -> resolution),
docs/sec, cache-hit rate, and $-cost per control plane.  Engines are
compile-warmed on the same corpus before the timed pass.

    PYTHONPATH=src python benchmarks/serve_engine.py --docs 512 \
        --stream-docs 96 --out BENCH_serve_engine.json

``--smoke`` runs a tiny CPU workload (including a 2-query multi-tenant
case, so CI exercises mixed-query launches) and asserts non-empty stats.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.launch.serve import (drive_request_loop, drive_server,
                                poisson_arrivals, warm_arena)
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import CascadeEngine, CascadeServer, LMBackend
from repro.serving.legacy_engine import DictCacheLMBackend, SeedCascadeEngine

OPS = {
    "o_orig": "does this opinion overturn a lower court decision",
    "sur_1": "is any lower court mentioned",
}


def _model(seed: int):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    m = LM(resolve(cfg, tp=1), CPU_TEST)
    return m, m.init(jax.random.PRNGKey(seed))


def make_backends(kind: str, tokz, models):
    cls = {"seed": DictCacheLMBackend, "arena": LMBackend}[kind]
    rates = {"proxy": 0.06, "oracle": 1.0}
    return {
        name: cls(name=name, model=m, params=p, tokenizer=tokz,
                  rate_per_token=rates[name], s_alloc=512)
        for name, (m, p) in models.items()
    }


def make_engine(kind: str, tokz, models, batch_size: int):
    backends = make_backends(kind, tokz, models)
    cls = {"seed": SeedCascadeEngine, "arena": CascadeEngine}[kind]
    return cls(backends, OPS, n_classes=2, batch_size=batch_size), backends


def forced_ladder():
    """Impossible thresholds: every doc walks the whole ladder, so every
    control plane does IDENTICAL token work and the comparison isolates
    scheduling + data plane."""
    thr = {0: 2.0, 1: 2.0}
    return Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])


# ---------------------------------------------------------------------------
# Static (PR-1) section: seed vs arena, same corpus, batch semantics
# ---------------------------------------------------------------------------

def run_static(kind: str, cascade, docs, tokz, models, batch_size: int):
    eng, backends = make_engine(kind, tokz, models, batch_size)
    result = {}
    for run in ("cold", "warm"):
        t0 = time.perf_counter()
        out = eng.run(cascade, docs)
        wall = time.perf_counter() - t0
        stats = out[2] if kind == "seed" else out.stats
        cost = out[1] if kind == "seed" else out.cost
        host = sum(be.host_overhead_s for be in backends.values())
        result[run] = {
            "wall_s": round(wall, 4),
            "docs_per_s": round(len(docs) / wall, 3),
            "host_overhead_s": round(host, 4),
            "host_overhead_per_batch_ms":
                round(1e3 * host / max(stats.batches, 1), 4),
            "batches": stats.batches,
            "cache_hit_rate": round(stats.cache_hit_rate(), 4),
            "new_tokens": stats.total_new_tokens(),
            "cached_tokens": stats.total_cached_tokens(),
            "cost": round(cost, 4),
            "stage_cost": [round(c, 4) for c in stats.stage_cost],
        }
    return result


# ---------------------------------------------------------------------------
# Streaming section: Poisson arrivals, three control planes
# ---------------------------------------------------------------------------

def _stream_report(n_docs, wall, latencies, new_tok, cached_tok, cost,
                   batches, evictions=None):
    tot = new_tok + cached_tok
    lat = np.asarray(latencies) if latencies else np.zeros(1)
    rep = {
        "wall_s": round(wall, 4),
        "docs_per_s": round(n_docs / max(wall, 1e-9), 3),
        "latency_p50_ms": round(1e3 * float(np.quantile(lat, 0.5)), 1),
        "latency_p99_ms": round(1e3 * float(np.quantile(lat, 0.99)), 1),
        "batches": batches,
        "cache_hit_rate": round(cached_tok / tot if tot else 0.0, 4),
        "new_tokens": int(new_tok),
        "cached_tokens": int(cached_tok),
        "cost": round(cost, 4),
    }
    if evictions is not None:
        rep["evictions"] = evictions
    return rep


def stream_request_loop(cascade, docs, arrivals, tokz, models,
                        batch_size: int):
    eng, _ = make_engine("arena", tokz, models, batch_size)
    warm_arena(eng, cascade, docs, batch_size)
    res, wall = drive_request_loop(eng, cascade, docs, arrivals)
    assert set(res.pred) == set(docs)
    st = res.stats
    return _stream_report(
        len(docs), wall, st.latencies, st.total_new_tokens(),
        st.total_cached_tokens(), res.cost, st.batches,
        evictions=st.evictions)


def stream_waves(kind: str, cascade, docs, arrivals, tokz, models,
                 batch_size: int):
    """Stage-synchronous streaming baseline: arrivals buffer during each
    whole-cascade ``run()`` wave and are only admitted at the next wave."""
    eng, _ = make_engine(kind, tokz, models, batch_size)
    if kind == "seed":
        eng.run(cascade, docs)                   # eager: one warm pass
    else:
        warm_arena(eng, cascade, docs, batch_size)
    order = sorted(docs, key=lambda d: (arrivals[d], d))
    t0 = time.perf_counter()
    i = 0
    latencies = []
    new_tok = cached_tok = batches = 0
    cost = 0.0
    resolved = 0
    while i < len(order):
        now = time.perf_counter() - t0
        wave = []
        while i < len(order) and arrivals[order[i]] <= now:
            wave.append(order[i])
            i += 1
        if not wave:
            time.sleep(min(arrivals[order[i]] - now, 0.05))
            continue
        out = eng.run(cascade, {d: docs[d] for d in wave})
        stats = out[2] if kind == "seed" else out.stats
        cost += out[1] if kind == "seed" else out.cost
        end = time.perf_counter() - t0
        latencies += [end - arrivals[d] for d in wave]
        new_tok += stats.total_new_tokens()
        cached_tok += stats.total_cached_tokens()
        batches += stats.batches
        resolved += len(wave)
    wall = time.perf_counter() - t0
    assert resolved == len(docs)
    return _stream_report(len(docs), wall, latencies, new_tok, cached_tok,
                          cost, batches)


# ---------------------------------------------------------------------------
# Multi-tenant section: N concurrent queries, shared server vs isolated
# ---------------------------------------------------------------------------

def tenant_cascades(n_tenants: int):
    """Distinct per-tenant cascades with OVERLAPPING signatures: every
    tenant opens with the same cheap screen (stage-0 launches merge) and
    shares the oracle fall-through; stage 1 alternates between the
    original and the surrogate operation.  Impossible thresholds keep the
    token work deterministic, so occupancy/parity isolate scheduling."""
    thr = {0: 2.0, 1: 2.0}
    variants = [
        Cascade([Task(TaskConfig("proxy", "sur_1", 0.25), thr),
                 Task(TaskConfig("proxy", "o_orig", 1.0), thr)]),
        Cascade([Task(TaskConfig("proxy", "sur_1", 0.25), thr),
                 Task(TaskConfig("proxy", "sur_1", 1.0), thr)]),
    ]
    return [variants[k % len(variants)] for k in range(n_tenants)]


def run_multi_tenant(docs, tokz, models, batch_size: int, rate: float,
                     seed: int, n_tenants: int = 2):
    """Shared ``CascadeServer`` vs per-query isolation, same workload.

    Interactive replay (deterministic, untimed): one document per tenant
    per tick, serve to idle between ticks — the interactive regime where
    requests trickle in.  An ISOLATED engine can never batch across
    queries, so every launch is width 1 (occupancy exactly 1.0); the
    shared server merges same-tick arrivals and survivors whose static
    signatures agree, so occupancy rises and launch count falls.
    Per-query $-parity must be EXACT per document and predictions must
    match the isolated engines'.  Streaming pass (wall clock): N
    concurrent Poisson feeds on the shared server vs each feed served
    alone, per-query p50/p99.
    """
    cascades = tenant_cascades(n_tenants)
    ids = sorted(docs)
    tdocs = [{d: docs[d] for d in ids[k::n_tenants]}
             for k in range(n_tenants)]
    order = [sorted(t) for t in tdocs]
    arrivals = [poisson_arrivals(order[k], rate, seed + k)
                for k in range(n_tenants)]

    eng, _ = make_engine("arena", tokz, models, batch_size)
    distinct = {tuple(t.config.key() for t in c.tasks): c for c in cascades}
    for c in distinct.values():
        warm_arena(eng, c, docs, batch_size)

    # ---- isolated: each query served alone (own arenas, own queue)
    iso_batch, iso_stream = [], []
    for k in range(n_tenants):
        eng.start(cascades[k])
        for j, d in enumerate(order[k]):
            eng.submit(d, tdocs[k][d], arrival=float(j))
            while eng.pending():               # serve this tick to idle
                eng.step()
        iso_batch.append(eng.result())
        sres, wall = drive_request_loop(eng, cascades[k], tdocs[k],
                                        arrivals[k])
        st = sres.stats
        iso_stream.append(_stream_report(
            len(tdocs[k]), wall, st.latencies, st.total_new_tokens(),
            st.total_cached_tokens(), sres.cost, st.batches))
    iso_launches = sum(r.stats.batches for r in iso_batch)
    iso_docs = sum(sum(r.stats.stage_docs) for r in iso_batch)

    # ---- shared: every query registered on ONE server over the SAME
    # backends (compile caches carry over; arenas reset per session)
    server = CascadeServer(eng.backends, OPS, n_classes=2,
                           batch_size=batch_size)

    def shared_session():
        server.reset()
        return [server.register(c) for c in cascades]

    # interactive replay: the k-th tenant's j-th document arrives at tick
    # j for every tenant; the server serves each tick to idle
    handles = shared_session()
    for j in range(max(len(o) for o in order)):
        for k in range(n_tenants):
            if j < len(order[k]):
                handles[k].submit(order[k][j], tdocs[k][order[k][j]],
                                  arrival=float(j))
        while server.pending():
            server.step()
    out = server.drain()
    shared_batch = [out[h.query_id] for h in handles]
    shared_launches = server.stats().batches
    shared_occupancy = server.occupancy()

    pred_match = all(shared_batch[k].pred == iso_batch[k].pred
                     for k in range(n_tenants))
    cost_parity = all(shared_batch[k].doc_cost == iso_batch[k].doc_cost
                      for k in range(n_tenants))

    # streaming pass: N concurrent Poisson feeds, one wall clock
    handles = shared_session()
    streams = [(handles[k], tdocs[k], arrivals[k])
               for k in range(n_tenants)]
    results, wall = drive_server(server, streams)
    shared_stream = []
    for k, h in enumerate(handles):
        st = results[h.query_id].stats
        shared_stream.append(_stream_report(
            len(tdocs[k]), wall, st.latencies, st.total_new_tokens(),
            st.total_cached_tokens(), results[h.query_id].cost, st.batches))
    stream_occupancy = server.occupancy()

    iso_occupancy = iso_docs / max(iso_launches, 1)
    return {
        "n_tenants": n_tenants,
        "docs_per_tenant": [len(t) for t in tdocs],
        "rate_docs_per_s_per_tenant": round(rate, 3),
        "interactive": {
            "shared": {
                "launches": shared_launches,
                "occupancy": round(shared_occupancy, 3),
                "per_query_cost": [round(r.cost, 4) for r in shared_batch],
            },
            "isolated": {
                "launches": iso_launches,
                "occupancy": round(iso_occupancy, 3),
                "per_query_cost": [round(r.cost, 4) for r in iso_batch],
            },
            "pred_match": pred_match,
            "doc_cost_parity_exact": cost_parity,
            "launch_reduction": round(iso_launches
                                      / max(shared_launches, 1), 2),
            "occupancy_gain": round(shared_occupancy
                                    / max(iso_occupancy, 1e-9), 2),
        },
        "streaming": {
            "shared": {"wall_s": round(wall, 4),
                       "occupancy": round(stream_occupancy, 3),
                       "per_query": shared_stream},
            "isolated": {"per_query": iso_stream},
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--stream-docs", type=int, default=96)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (docs/s); 0 = 0.6x the "
                         "arena engine's measured static throughput")
    ap.add_argument("--tenants", type=int, default=2,
                    help="concurrent queries in the multi-tenant section")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_serve_engine.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI run: assert non-empty stats, no file")
    args = ap.parse_args()
    if args.smoke:
        args.docs = min(args.docs, 16)
        args.stream_docs = min(args.stream_docs, 12)
        args.batch_size = min(args.batch_size, 4)

    tokz = HashWordTokenizer(vocab_size=512)
    models = {"proxy": _model(1), "oracle": _model(2)}
    corpus = generate_corpus(args.docs, avg_lines=12, seed=args.seed)
    docs = {d.doc_id: d.text for d in corpus}
    cascade = forced_ladder()

    report = {"n_docs": args.docs, "batch_size": args.batch_size,
              "backend": jax.default_backend(),
              "workload": "synthetic court-opinion corpus (generate_corpus)"}
    for kind in ("seed", "arena"):
        print(f"== {kind} engine (static) ==", flush=True)
        report[kind] = run_static(kind, cascade, docs, tokz, models,
                                  args.batch_size)
        print(json.dumps(report[kind]["warm"], indent=2), flush=True)

    sw, aw = report["seed"]["warm"], report["arena"]["warm"]
    report["summary"] = {
        "docs_per_s_speedup": round(aw["docs_per_s"] / sw["docs_per_s"], 2),
        "host_overhead_reduction":
            round(sw["host_overhead_s"] / max(aw["host_overhead_s"], 1e-9),
                  2),
        "host_overhead_per_batch_reduction":
            round(sw["host_overhead_per_batch_ms"]
                  / max(aw["host_overhead_per_batch_ms"], 1e-9), 2),
    }
    print("static summary:", json.dumps(report["summary"], indent=2))

    # ---- streaming: Poisson arrivals over a subset of the corpus
    stream_ids = sorted(docs)[: args.stream_docs]
    stream_docs = {d: docs[d] for d in stream_ids}
    rate = args.rate or 0.6 * aw["docs_per_s"]
    arrivals = poisson_arrivals(stream_ids, rate, args.seed)
    streaming = {"n_docs": len(stream_ids), "rate_docs_per_s": round(rate, 3)}
    drivers = {
        "request_loop": lambda: stream_request_loop(
            cascade, stream_docs, arrivals, tokz, models, args.batch_size),
        "stage_sync": lambda: stream_waves(
            "arena", cascade, stream_docs, arrivals, tokz, models,
            args.batch_size),
        "legacy": lambda: stream_waves(
            "seed", cascade, stream_docs, arrivals, tokz, models,
            args.batch_size),
    }
    for name, fn in drivers.items():
        print(f"== {name} (streaming, rate {rate:.1f}/s) ==", flush=True)
        streaming[name] = fn()
        print(json.dumps(streaming[name], indent=2), flush=True)
    rl, ss = streaming["request_loop"], streaming["stage_sync"]
    streaming["summary"] = {
        "p50_speedup_vs_stage_sync":
            round(ss["latency_p50_ms"] / max(rl["latency_p50_ms"], 1e-9), 2),
        "p99_speedup_vs_stage_sync":
            round(ss["latency_p99_ms"] / max(rl["latency_p99_ms"], 1e-9), 2),
        "cache_hit_ge_stage_sync":
            rl["cache_hit_rate"] >= ss["cache_hit_rate"],
    }
    report["streaming"] = streaming
    print("streaming summary:", json.dumps(streaming["summary"], indent=2))

    # ---- multi-tenant: N concurrent queries, shared server vs isolation
    print(f"== multi-tenant ({args.tenants} queries, shared server vs "
          f"isolated) ==", flush=True)
    mt = run_multi_tenant(stream_docs, tokz, models, args.batch_size,
                          rate / args.tenants, args.seed,
                          n_tenants=args.tenants)
    report["multi_tenant"] = mt
    print(json.dumps(mt["interactive"], indent=2), flush=True)

    if args.smoke:
        assert rl["latency_p50_ms"] > 0 and rl["new_tokens"] > 0
        assert rl["cache_hit_rate"] >= ss["cache_hit_rate"]
        assert aw["new_tokens"] == sw["new_tokens"]   # identical token work
        # mixed-query launches: same preds and exact per-doc $ as isolated
        # engines, at strictly better batch occupancy
        mi = mt["interactive"]
        assert mi["pred_match"]
        assert mi["doc_cost_parity_exact"]
        assert mi["shared"]["occupancy"] > mi["isolated"]["occupancy"]
        assert mi["shared"]["launches"] < mi["isolated"]["launches"]
        print("smoke OK")
        return

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
