"""Serving-engine data-plane benchmark: seed dict-cache vs slot arena.

Runs the same task cascade over the same simulated corpus through

  * the SEED engine (``serving.legacy_engine``): per-doc dict cache,
    per-stage ``_stack_states``/``_slice_states`` pytree rebuilds, eager
    model dispatch, whole-batch re-prefill on mixed cached lengths;
  * the ARENA engine (``serving.engine``): persistent slot-based KV
    arenas, jitted per-(bucket, cached_len) stage steps, gather/scatter
    survivor compaction, kv_len-masked op suffixes.

Reports docs/sec, per-stage host overhead (wall-clock spent in the Python
data plane: state stack/slice vs slot pack + dispatch), and cache-hit
rate.  Both engines are run twice and the warm (second) pass is reported,
so one-time tracing/compilation is excluded from the comparison on both
sides.

    PYTHONPATH=src python benchmarks/serve_engine.py --docs 512 \
        --out BENCH_serve_engine.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import CascadeEngine, LMBackend
from repro.serving.legacy_engine import DictCacheLMBackend, SeedCascadeEngine

OPS = {
    "o_orig": "does this opinion overturn a lower court decision",
    "sur_1": "is any lower court mentioned",
}


def _model(seed: int):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    m = LM(resolve(cfg, tp=1), CPU_TEST)
    return m, m.init(jax.random.PRNGKey(seed))


def make_backends(kind: str, tokz, models):
    cls = {"seed": DictCacheLMBackend, "arena": LMBackend}[kind]
    rates = {"proxy": 0.06, "oracle": 1.0}
    return {
        name: cls(name=name, model=m, params=p, tokenizer=tokz,
                  rate_per_token=rates[name], s_alloc=512)
        for name, (m, p) in models.items()
    }


def run_one(kind: str, cascade, docs, tokz, models, batch_size: int):
    backends = make_backends(kind, tokz, models)
    if kind == "seed":
        eng = SeedCascadeEngine(backends, OPS, n_classes=2,
                                batch_size=batch_size)
    else:
        eng = CascadeEngine(backends, OPS, n_classes=2,
                            batch_size=batch_size)
    result = {}
    for run in ("cold", "warm"):
        t0 = time.perf_counter()
        out = eng.run(cascade, docs)
        wall = time.perf_counter() - t0
        stats = out[2] if kind == "seed" else out.stats
        cost = out[1] if kind == "seed" else out.cost
        host = sum(be.host_overhead_s for be in backends.values())
        result[run] = {
            "wall_s": round(wall, 4),
            "docs_per_s": round(len(docs) / wall, 3),
            "host_overhead_s": round(host, 4),
            "host_overhead_per_batch_ms":
                round(1e3 * host / max(stats.batches, 1), 4),
            "batches": stats.batches,
            "cache_hit_rate": round(stats.cache_hit_rate(), 4),
            "new_tokens": stats.total_new_tokens(),
            "cached_tokens": stats.total_cached_tokens(),
            "cost": round(cost, 4),
            "stage_cost": [round(c, 4) for c in stats.stage_cost],
        }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--out", default="BENCH_serve_engine.json")
    args = ap.parse_args()

    tokz = HashWordTokenizer(vocab_size=512)
    models = {"proxy": _model(1), "oracle": _model(2)}
    corpus = generate_corpus(args.docs, avg_lines=12, seed=7)
    docs = {d.doc_id: d.text for d in corpus}
    # fraction ladder on the proxy with impossible thresholds: every doc
    # walks the whole ladder to the oracle, so both engines do IDENTICAL
    # token work and the comparison isolates the data plane (confidence
    # numerics differ slightly between the engines — the arena op suffix
    # is kv_len-masked — which would otherwise skew early exits)
    thr = {0: 2.0, 1: 2.0}
    cascade = Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])

    report = {"n_docs": args.docs, "batch_size": args.batch_size,
              "backend": jax.default_backend(),
              "workload": "synthetic court-opinion corpus (generate_corpus)"}
    for kind in ("seed", "arena"):
        print(f"== {kind} engine ==", flush=True)
        report[kind] = run_one(kind, cascade, docs, tokz, models,
                               args.batch_size)
        print(json.dumps(report[kind]["warm"], indent=2), flush=True)

    sw, aw = report["seed"]["warm"], report["arena"]["warm"]
    report["summary"] = {
        "docs_per_s_speedup": round(aw["docs_per_s"] / sw["docs_per_s"], 2),
        "host_overhead_reduction":
            round(sw["host_overhead_s"] / max(aw["host_overhead_s"], 1e-9),
                  2),
        "host_overhead_per_batch_reduction":
            round(sw["host_overhead_per_batch_ms"]
                  / max(aw["host_overhead_per_batch_ms"], 1e-9), 2),
    }
    print("summary:", json.dumps(report["summary"], indent=2))
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
