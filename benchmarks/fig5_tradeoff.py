"""Paper Figure 5: accuracy-cost tradeoff as the target varies 0.75..0.95."""
from __future__ import annotations

import numpy as np

from .common import fmt_table, run_variant

GROUPS = {"A": ["enron", "legal"], "B": ["games", "court"], "C": ["agnews"]}
TARGETS = (0.75, 0.80, 0.85, 0.90, 0.95)


def run(quick: bool = False):
    workloads = [w for ws in GROUPS.values() for w in ws]
    if quick:
        workloads = ["enron", "games"]
    n_docs = 400 if quick else 1000
    rows = []
    curves = {}
    for w in workloads:
        for alpha in TARGETS:
            mc = run_variant("model_cascade", w, alpha=alpha, n_docs=n_docs)
            tc = run_variant("task_cascades", w, alpha=alpha, n_docs=n_docs)
            curves[(w, alpha)] = {
                "mc": (mc["accuracy"], mc["total_cost"]),
                "tc": (tc["accuracy"], tc["total_cost"]),
            }
            rows.append([w, f"{alpha:.2f}",
                         f"{mc['accuracy']:.1%} ${mc['total_cost']:.2f}",
                         f"{tc['accuracy']:.1%} ${tc['total_cost']:.2f}",
                         f"{tc['total_cost'] / max(mc['total_cost'], 1e-9):.2f}x"])
    table = fmt_table(
        ["workload", "target", "2-Model Cascade", "Task Cascades", "ratio"],
        rows)
    print(table)
    # paper claim: largest TC gains at LOWER targets on hard workloads
    gains = {}
    for w in workloads:
        lo = curves[(w, 0.75)]["tc"][1] / max(curves[(w, 0.75)]["mc"][1], 1e-9)
        hi = curves[(w, 0.95)]["tc"][1] / max(curves[(w, 0.95)]["mc"][1], 1e-9)
        gains[w] = (lo, hi)
        print(f"{w}: ratio@0.75={lo:.2f} ratio@0.95={hi:.2f} "
              f"({'gains shrink at high targets' if lo <= hi else 'flat'})")
    return {"table": table, "curves": {f"{w}|{a}": v for (w, a), v
                                       in curves.items()}, "gains": gains}


if __name__ == "__main__":
    run()
