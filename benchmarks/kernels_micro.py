"""Kernel microbenchmarks: CPU wall-clock of the XLA path vs naive ref +
analytic v5e roofline terms per kernel configuration.

(interpret=True Pallas is a correctness tool, not a perf tool — on-TPU
timing is the deploy-side measurement; here we report the structural
terms the BlockSpecs were sized for.)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import fmt_table

PEAK = 197e12
HBM = 819e9


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.time() - t0) / reps


def run(quick: bool = False):
    rows = []
    cases = [
        ("flash causal", dict(B=1, Sq=1024, Skv=1024, Hq=8, Hkv=2, Dh=64,
                              causal=True, window=None)),
        ("flash window1k", dict(B=1, Sq=2048, Skv=2048, Hq=8, Hkv=2, Dh=64,
                                causal=True, window=1024)),
        ("prefix extend", dict(B=2, Sq=256, Skv=2048, Hq=8, Hkv=2, Dh=64,
                               causal=True, window=None, q_offset=1792)),
    ]
    if quick:
        cases = cases[:1]
    key = jax.random.PRNGKey(0)
    for name, c in cases:
        q = jax.random.normal(key, (c["B"], c["Sq"], c["Hq"], c["Dh"]),
                              jnp.float32)
        k = jax.random.normal(key, (c["B"], c["Skv"], c["Hkv"], c["Dh"]),
                              jnp.float32)
        v = k + 0.1
        qo = c.get("q_offset", 0)

        def xla_fn(q, k, v):
            return ops.attention(q, k, v, causal=c["causal"],
                                 window=c["window"], q_offset=qo,
                                 impl="xla")

        def naive_fn(q, k, v):
            return ref.mha_reference(q, k, v, causal=c["causal"],
                                     window=c["window"], q_offset=qo)

        t_x = _time(jax.jit(xla_fn), q, k, v)
        t_n = _time(jax.jit(naive_fn), q, k, v)
        # analytic terms for the kernel's visited blocks
        flops = 4 * c["B"] * c["Hq"] * c["Sq"] * c["Skv"] * c["Dh"] * 0.5
        bytes_ = 2 * (q.size + 2 * k.size) * 2
        rows.append([name, f"{t_x*1e3:.1f}ms", f"{t_n*1e3:.1f}ms",
                     f"{t_n/max(t_x,1e-9):.1f}x",
                     f"{flops/PEAK*1e6:.1f}us", f"{bytes_/HBM*1e6:.1f}us"])
    table = fmt_table(["kernel", "xla-blocked", "naive ref", "speedup",
                       "v5e compute", "v5e memory"], rows)
    print(table)
    out = {"table": table}
    if not quick:
        out["paged_read"] = run_paged_read()
    return out


def run_paged_read():
    """Paged arena read, f32 vs bf16 KV storage (PR 7): one decode step
    reading ``k_arena[slot]`` through the paged path.  bf16 halves the
    arena bytes the kernel streams — the v5e memory term halves while
    compute is unchanged (keys are upcast inside the kernel); CPU
    wall-clock goes through the XLA gather fallback, so treat it as a
    sanity number, not the deploy-side measurement."""
    B, rows_n, s_alloc, Hq, Hkv, Dh = 8, 32, 1024, 8, 2, 64
    kv_valid = s_alloc
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (B, 1, Hq, Dh), jnp.float32)
    k32 = jax.random.normal(key, (rows_n, s_alloc, Hkv, Dh), jnp.float32)
    v32 = k32 + 0.1
    slots = jnp.arange(B, dtype=jnp.int32)
    rows = []
    section = {}
    for name, dt in (("f32", jnp.float32), ("bf16", jnp.bfloat16)):
        k, v = k32.astype(dt), v32.astype(dt)

        def paged_fn(q, k, v):
            return ops.attention_paged(
                q, k, v, slots, kv_valid=kv_valid, causal=True,
                q_offset=kv_valid - 1, impl="xla")

        t = _time(jax.jit(paged_fn), q, k, v)
        arena_bytes = 2 * B * kv_valid * Hkv * Dh * k.dtype.itemsize
        rows.append([f"paged read {name}", f"{t*1e3:.2f}ms",
                     f"{arena_bytes/1e6:.2f}MB",
                     f"{arena_bytes/HBM*1e6:.1f}us"])
        section[name] = {"wall_ms": round(t * 1e3, 3),
                         "arena_bytes_read": arena_bytes}
    assert (section["bf16"]["arena_bytes_read"]
            == section["f32"]["arena_bytes_read"] // 2)
    table = fmt_table(["paged decode read", "cpu-xla", "KV streamed",
                       "v5e memory"], rows)
    print(table)
    section["table"] = table
    return section


if __name__ == "__main__":
    run()
