"""Paper Figure 4: run-to-run cost variance of the +Guarantees variants."""
from __future__ import annotations

import numpy as np

from .common import fmt_table, run_variant

WORKLOADS = ("enron", "legal", "games", "court", "agnews")


def run(n_runs: int = 10, quick: bool = False):
    workloads = WORKLOADS[:2] if quick else WORKLOADS
    runs = 4 if quick else n_runs
    rows = []
    dists = {}
    for w in workloads:
        tc = [run_variant("task_cascades_g", w, seed=s,
                          n_docs=400 if quick else 1000)["total_cost"]
              for s in range(runs)]
        mc = [run_variant("model_cascade_g", w, seed=s,
                          n_docs=400 if quick else 1000)["total_cost"]
              for s in range(runs)]
        dists[w] = {"tc": tc, "mc": mc}
        rows.append([
            w,
            f"{np.mean(tc):.2f} / {np.median(tc):.2f} (sd {np.std(tc):.2f})",
            f"{np.mean(mc):.2f} / {np.median(mc):.2f} (sd {np.std(mc):.2f})",
            "yes" if np.mean(tc) <= np.mean(mc) else "no",
        ])
    table = fmt_table(
        ["workload", "TC+G mean/median cost", "MC+G mean/median cost",
         "TC mean <= MC mean"], rows)
    print(table)
    return {"table": table, "dists": dists}


if __name__ == "__main__":
    run()
