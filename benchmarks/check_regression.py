#!/usr/bin/env python
"""Benchmark-regression gate for CI.

Diffs a fresh ``benchmarks/serve_engine.py --smoke`` summary against the
``"smoke"`` section committed in ``BENCH_serve_engine.json``, with an
EXPLICIT per-metric tolerance table.  Every gated metric is deterministic
for a given source tree (seeded corpora/params, blake2 word hashing,
forced-impossible thresholds, tick-based interactive replay), so the
tolerances are tight: structural counts (tokens, launches, copy bytes)
must match exactly, float aggregates ($, occupancy) within 1e-6
relative.  Timing metrics (docs/s, latency) are intentionally NOT gated.
The chaos (fault-injection) section is gated on its boolean invariants
only — all docs terminal, exact accounting, journal recovery — since its
counters vary with ``--chaos-seed``; the fault-free metrics above must
stay byte-identical whether or not injection ran.  The capacity section
(prefix sharing + bf16 arenas) pins its own per-arm dtypes, so its gates
hold on the ``--kv-dtype=bf16`` smoke leg too — the one committed
baseline serves both legs.

    python benchmarks/serve_engine.py --smoke          # writes BENCH_smoke.json
    python benchmarks/check_regression.py BENCH_smoke.json \
        --baseline BENCH_serve_engine.json

Exit status 0 = within tolerance; 1 = drift (every violation listed).
An intentional change to the serving economics (token accounting, packing
policy, copy-traffic model) regenerates the baseline by re-running the
full benchmark: ``python benchmarks/serve_engine.py``.
"""
from __future__ import annotations

import argparse
import json
import sys

# metric path inside the "smoke" section -> (kind, tolerance)
#   exact  values must be equal (ints, bools, structural byte counts)
#   rel    |fresh - base| <= tol * max(|base|, 1e-12)   (floats, lists of
#          floats elementwise; length mismatch is a violation)
TOLERANCES = {
    # static arena engine: token/$ accounting and launch schedule
    "static.new_tokens":                      ("exact", 0),
    "static.cached_tokens":                   ("exact", 0),
    "static.launches":                        ("exact", 0),
    "static.cost":                            ("rel", 1e-6),
    "static.cache_hit_rate":                  ("rel", 1e-6),
    # multi-tenant interactive replay: cross-query packing
    "multi_tenant.shared_launches":           ("exact", 0),
    "multi_tenant.isolated_launches":         ("exact", 0),
    "multi_tenant.occupancy":                 ("rel", 1e-6),
    "multi_tenant.isolated_occupancy":        ("rel", 1e-6),
    "multi_tenant.per_query_cost":            ("rel", 1e-6),
    # paged data plane: structural copy traffic
    "paged.gather_copy_bytes_per_launch":     ("exact", 0),
    "paged.paged_arena_copy_bytes_per_launch": ("exact", 0),
    "paged.paged_undo_log_bytes_per_launch":  ("exact", 0),
    # default doc-before-op plane: prefix-sharing counters structurally 0
    # (the capacity section exercises the nonzero paths)
    "static.prefix_hits":                     ("exact", 0),
    "static.cow_copies":                      ("exact", 0),
    "static.re_prefill_tokens":               ("exact", 0),
    # capacity: prefix sharing + bf16 arenas under a fixed byte budget.
    # The arms pin their own dtypes/planes, so every number here is
    # byte-identical whatever --kv-dtype the smoke leg ran under.
    # (static.arena_bytes_peak is intentionally NOT gated: it halves on
    # the bf16 leg; the per-arm peaks below pin the byte accounting.)
    "capacity.byte_budget":                   ("exact", 0),
    "capacity.no_pressure.f32_private.arena_bytes_peak": ("exact", 0),
    "capacity.no_pressure.f32_prefix.arena_bytes_peak": ("exact", 0),
    "capacity.no_pressure.bf16_prefix.arena_bytes_peak": ("exact", 0),
    "capacity.no_pressure.f32_prefix.prefix_hits": ("exact", 0),
    "capacity.no_pressure.f32_prefix.cow_copies": ("exact", 0),
    "capacity.no_pressure.f32_prefix.cost":   ("rel", 1e-6),
    "capacity.overload.f32_private.evictions": ("exact", 0),
    "capacity.overload.f32_private.re_prefill_tokens": ("exact", 0),
    "capacity.overload.bf16_prefix.evictions": ("exact", 0),
    "capacity.overload.bf16_prefix.re_prefill_tokens": ("exact", 0),
    # telemetry trace probe: structural span/event/launch counts from the
    # FIXED-seed chaos workload (a pure function of the source tree —
    # zero backoff, logical arrivals — so they gate exactly; timings in
    # the embedded snapshot are intentionally NOT gated)
    "telemetry.trace_probe.spans":             ("exact", 0),
    "telemetry.trace_probe.events_total":      ("exact", 0),
    "telemetry.trace_probe.launch_records":    ("exact", 0),
    "telemetry.trace_probe.failed_launch_records": ("exact", 0),
    "telemetry.trace_probe.metric_series":     ("exact", 0),
}

# invariants the FRESH summary must satisfy regardless of the baseline
REQUIRED_TRUE = (
    "multi_tenant.pred_match",
    "multi_tenant.doc_cost_parity_exact",
    "paged.parity.pred_match",
    "paged.parity.conf_bitwise",
    "paged.parity.doc_cost_parity_exact",
    # capacity (prefix sharing + bf16 KV compression): the op-token memo
    # and the compressed arena must leave the $-ledger exactly unchanged
    # (same-op ladder), bf16 preds/confs must sit within the gated
    # tolerance of f32, and under the fixed byte budget the bf16 arm must
    # resolve the same overload with strictly fewer evictions and >= 1.8x
    # fewer re-prefilled tokens than the f32 private baseline
    "capacity.parity.doc_cost_parity_exact",
    "capacity.parity.bf16_within_tolerance",
    "capacity.overload.fewer_evictions_bf16",
    "capacity.overload.reprefill_reduction_ge_1_8",
    # chaos (fault injection): every submitted document reaches a terminal
    # state, per-query/per-document $ replay the billing ledger exactly,
    # and a mid-flight crash warm-restarts from the write-ahead journal
    # (counts — retries, quarantines, trips — vary with --chaos-seed and
    # are intentionally NOT gated)
    "chaos.all_docs_terminal",
    "chaos.accounting_exact",
    "chaos.deadline_timed_out",
    "chaos.arena_loss_injected",
    "chaos.recovery_all_terminal",
    "chaos.recovery_restored_exact",
    "chaos.recovery_accounting_exact",
    # telemetry (PR 8): the default-on counters level must be bitwise
    # invisible to the fault-free data plane (preds/confs/per-doc $ and
    # arena device state equal a level="off" run exactly); the trace
    # probe's spans must be well-formed under injected faults, nothing
    # dropped from the bounded rings at gate scale, and every launch's
    # sched/host/dispatch/device segments must sum to its wall time
    "telemetry.counters_bitwise_inert",
    "telemetry.trace_probe.spans_well_formed",
    "telemetry.trace_probe.no_dropped_events",
    "telemetry.trace_probe.segments_sum_ok",
    # overlap (ahead-of-time dispatch, ROADMAP item 2): the K-deep
    # dispatch window must actually be reached (max_inflight >= 2), the
    # overlap metrics (overlap_hidden_frac, mean_launch_gap_ms) must be
    # present in the snapshot timeline, and the fault-free plane must be
    # BITWISE identical to inflight=1 — preds, confs, per-document $,
    # and every arena device leaf (gap/hidden-fraction values are
    # wall-clock and intentionally NOT gated)
    "overlap.max_inflight_ge_2",
    "overlap.metrics_present",
    "overlap.parity.pred_match",
    "overlap.parity.conf_bitwise",
    "overlap.parity.doc_cost_parity_exact",
    "overlap.parity.arena_leaves_bitwise",
)


def _get(tree, path: str):
    for part in path.split("."):
        tree = tree[part]
    return tree


def _rel_ok(fresh: float, base: float, tol: float) -> bool:
    return abs(float(fresh) - float(base)) <= tol * max(abs(float(base)),
                                                        1e-12)


def section_diff(fresh: dict, base: dict) -> list:
    """Top-level section drift between the fresh summary and the
    baseline, reported in BOTH directions.  A section present in the
    baseline but absent from the fresh run means the benchmark silently
    stopped producing it (the per-metric loop would only say 'missing
    from fresh' for gated paths); a fresh-only section means the
    baseline predates it and must be regenerated."""
    violations = []
    missing = sorted(set(base) - set(fresh))
    extra = sorted(set(fresh) - set(base))
    if missing:
        violations.append(
            f"sections missing from fresh summary: {missing} "
            f"(baseline has {sorted(base)})")
    if extra:
        violations.append(
            f"sections missing from baseline: {extra} "
            f"(regenerate BENCH_serve_engine.json)")
    return violations


def compare(fresh: dict, base: dict) -> list:
    """Return the list of violations (empty = gate passes)."""
    violations = section_diff(fresh, base)
    for path, (kind, tol) in TOLERANCES.items():
        try:
            f = _get(fresh, path)
        except (KeyError, TypeError):
            violations.append(f"{path}: missing from fresh summary")
            continue
        try:
            b = _get(base, path)
        except (KeyError, TypeError):
            violations.append(f"{path}: missing from baseline "
                              f"(regenerate BENCH_serve_engine.json)")
            continue
        if isinstance(b, list) or isinstance(f, list):
            if not isinstance(f, list) or not isinstance(b, list) \
                    or len(f) != len(b):
                violations.append(f"{path}: shape mismatch {f!r} vs {b!r}")
                continue
            pairs = list(zip(f, b))
        else:
            pairs = [(f, b)]
        for i, (fv, bv) in enumerate(pairs):
            tag = f"{path}[{i}]" if len(pairs) > 1 else path
            if kind == "exact":
                if fv != bv:
                    violations.append(
                        f"{tag}: {fv!r} != baseline {bv!r} (exact)")
            else:
                if not _rel_ok(fv, bv, tol):
                    violations.append(
                        f"{tag}: {fv!r} vs baseline {bv!r} "
                        f"(rel tol {tol:g})")
    for path in REQUIRED_TRUE:
        try:
            if _get(fresh, path) is not True:
                violations.append(f"{path}: must be true, got "
                                  f"{_get(fresh, path)!r}")
        except (KeyError, TypeError):
            violations.append(f"{path}: missing from fresh summary")
    return violations


def _load_section(path: str, which: str) -> dict:
    """Load the gated ``"smoke"`` section of ``path`` or exit 2 with a
    diagnostic naming the file, the missing piece, and the keys that ARE
    there — a truncated/renamed summary must not surface as a KeyError."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except FileNotFoundError:
        print(f"regression gate: {which} file not found: {path}")
        raise SystemExit(2)
    except json.JSONDecodeError as e:
        print(f"regression gate: {which} {path} is not valid JSON: {e}")
        raise SystemExit(2)
    if not isinstance(doc, dict) or "smoke" not in doc:
        keys = sorted(doc) if isinstance(doc, dict) else type(doc).__name__
        fix = ("re-run benchmarks/serve_engine.py --smoke"
               if which == "fresh summary"
               else "regenerate it with benchmarks/serve_engine.py")
        print(f"regression gate: {which} {path} has no 'smoke' section "
              f"(top-level keys: {keys}); {fix}")
        raise SystemExit(2)
    smoke = doc["smoke"]
    if not isinstance(smoke, dict):
        print(f"regression gate: {which} {path} 'smoke' section is "
              f"{type(smoke).__name__}, expected an object")
        raise SystemExit(2)
    return smoke


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("summary", help="fresh --smoke summary JSON")
    ap.add_argument("--baseline", default="BENCH_serve_engine.json",
                    help="committed benchmark JSON holding the baseline "
                         "'smoke' section")
    args = ap.parse_args()
    fresh = _load_section(args.summary, "fresh summary")
    base = _load_section(args.baseline, "baseline")
    violations = compare(fresh, base)
    if violations:
        print(f"REGRESSION GATE FAILED ({len(violations)} violation(s) "
              f"vs {args.baseline}):")
        for v in violations:
            print(f"  - {v}")
        return 1
    n = len(TOLERANCES) + len(REQUIRED_TRUE)
    print(f"regression gate OK: {n} gated metrics within tolerance "
          f"of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
