"""Benchmark runner: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints each table and a cross-check against the paper's headline claims.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small doc counts / fewer trials (CI mode)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from . import (fig4_variance, fig5_tradeoff, fig7_sensitivity,
                   kernels_micro, table3_main, table4_breakeven)

    sections = [
        ("table3_main", lambda: table3_main.run(quick=args.quick)),
        ("table4_breakeven", lambda: table4_breakeven.run(quick=args.quick)),
        ("fig4_variance", lambda: fig4_variance.run(quick=args.quick)),
        ("fig5_tradeoff", lambda: fig5_tradeoff.run(quick=args.quick)),
        ("fig7_sensitivity", lambda: fig7_sensitivity.run(quick=args.quick)),
        ("kernels_micro", lambda: kernels_micro.run(quick=args.quick)),
    ]
    results = {}
    for name, fn in sections:
        if args.only and args.only != name:
            continue
        print(f"\n{'=' * 70}\n== {name}\n{'=' * 70}")
        t0 = time.time()
        results[name] = fn()
        print(f"[{name}: {time.time() - t0:.0f}s]")
    if args.out:
        serializable = {k: v.get("table", "") for k, v in results.items()}
        with open(args.out, "w") as f:
            json.dump(serializable, f, indent=1)
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
