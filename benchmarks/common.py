"""Shared benchmark plumbing: variant registry + table formatting."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.pipeline import (BuildConfig, build_task_cascade,
                                 evaluate_on, model_cascade,
                                 restructure_top25)
from repro.core.simulation import make_workload

ALL_WORKLOADS = ("agnews", "court", "enron", "fever", "games", "legal",
                 "pubmed", "wiki_talk")
N_DOCS = 1000
N_DEV = 200


def split(workload, seed: int = 0):
    n = workload.n_docs
    rng = np.random.default_rng(1000 + seed)
    perm = rng.permutation(n)
    return workload.subset(perm[:N_DEV]), workload.subset(perm[N_DEV:])


def run_variant(name: str, wname: str, alpha: float = 0.9, seed: int = 0,
                n_docs: int = N_DOCS) -> Dict[str, float]:
    """Build + evaluate one method variant on one workload."""
    reorder = "learned"
    bc = BuildConfig(alpha=alpha, seed=seed)
    if name == "naive_rag":
        reorder = "rag"
    elif name == "rag_nosur":
        reorder = "rag"
        bc = BuildConfig(alpha=alpha, seed=seed, use_surrogates=False)
    elif name == "no_filtering":
        reorder = "none"
        bc = BuildConfig(alpha=alpha, seed=seed, fractions=(1.0,))
    elif name == "no_surrogates":
        bc = BuildConfig(alpha=alpha, seed=seed, use_surrogates=False)
    elif name == "single_iteration":
        bc = BuildConfig(alpha=alpha, seed=seed, single_iteration=True)
    elif name == "selectivity":
        bc = BuildConfig(alpha=alpha, seed=seed, ordering="selectivity")
    elif name == "task_cascades_g":
        bc = BuildConfig(alpha=alpha, seed=seed, guarantee=True)
    elif name == "lite":
        bc = BuildConfig(alpha=alpha, seed=seed, lite=True)

    w = make_workload(wname, n_docs, reorder_mode=reorder)
    dev, test = split(w, seed)
    t0 = time.time()
    if name == "oracle_only":
        cm = test.cost_model()
        return {"accuracy": 1.0, "total_cost": cm.oracle_only_cost(),
                "n_tasks": 0, "build_s": 0.0}
    if name == "model_cascade":
        out = model_cascade(dev, alpha, seed=seed)
    elif name == "model_cascade_g":
        out = model_cascade(dev, alpha, guarantee=True, seed=seed)
    elif name == "restructure_top25":
        out = restructure_top25(dev, alpha)
    else:
        out = build_task_cascade(dev, bc)
    r = evaluate_on(test, out)
    r["build_s"] = time.time() - t0
    r["n_candidates"] = len(getattr(out, "candidate_configs", []) or [])
    return r


def fmt_table(headers: List[str], rows: List[List[str]]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)
