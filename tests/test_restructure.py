"""Document restructuring (§4): granularity, classifier, reorder quality."""
import numpy as np
import pytest

from repro.core.restructure import (DocumentRestructurer, HashEmbedder,
                                    SyntheticOracle, determine_granularity,
                                    expand_ranges, merge_ranges,
                                    train_relevance_classifier)
from repro.data.documents import generate_corpus

OP = ("does this opinion overturn a lower court decision overturn reversed "
      "vacated remanded affirmed upheld")


def test_merge_ranges():
    assert merge_ranges([(5, 7), (1, 2), (6, 9)]) == [(1, 2), (5, 9)]
    # adjacent ranges stay separate (paper §4 worked example semantics)
    assert merge_ranges([(1, 2), (3, 4)]) == [(1, 2), (3, 4)]
    assert merge_ranges([]) == []


def test_expand_ranges_paper_example():
    # §4 example: [23,25],[28,30] -> expand -> [22,26],[27,31] -> expand ->
    # [21,27],[26,32] overlap -> merged [21,32]
    r = [(23, 25), (28, 30)]
    r = expand_ranges(r, 100)
    assert r == [(22, 26), (27, 31)]
    r = expand_ranges(r, 100)
    assert r == [(21, 32)]


def test_determine_granularity_runs():
    docs = generate_corpus(20, avg_lines=30, seed=0)
    gran, per_doc = determine_granularity(docs, SyntheticOracle(), 0.9)
    assert gran >= 1
    assert len(per_doc) == len(docs)


def test_classifier_learns_signal():
    docs = generate_corpus(50, avg_lines=40, seed=1)
    emb = HashEmbedder()
    xs, ys = [], []
    for d in docs:
        for li, line in enumerate(d.lines):
            xs.append(emb.pooled(line))
            ys.append(1 if li in d.relevant_lines else 0)
    x, y = np.stack(xs), np.asarray(ys)
    n = len(y) // 2
    w, b, f1 = train_relevance_classifier(
        x[:n], y[:n], x[n:], y[n:], init_w=emb.pooled(OP))
    assert f1 > 0.6


def test_reorder_front_loads_relevance():
    docs = generate_corpus(50, avg_lines=40, seed=3)
    r = DocumentRestructurer(OP).fit(docs[:35], SyntheticOracle(noise=0.1))
    hits = tot = 0
    for d in docs[35:]:
        rd = r.reorder(d)
        top = set(range(max(len(rd.lines) // 4, 1)))
        hits += sum(1 for rl in rd.relevant_lines if rl in top)
        tot += len(rd.relevant_lines)
    assert hits / tot > 0.5            # >> random 0.25


def test_reorder_preserves_content():
    docs = generate_corpus(5, avg_lines=20, seed=4)
    r = DocumentRestructurer(OP).fit(docs, SyntheticOracle())
    rd = r.reorder(docs[0])
    assert sorted(rd.lines) == sorted(docs[0].lines)
    assert len(rd.relevant_lines) == len(docs[0].relevant_lines)


def test_kernel_and_ref_paths_agree():
    docs = generate_corpus(8, avg_lines=24, seed=5)
    r = DocumentRestructurer(OP).fit(docs, SyntheticOracle())
    r.impl = "xla"
    s_ref = r.score_chunks(docs[0])
    r.impl = "pallas_interpret"
    s_pal = r.score_chunks(docs[0])
    np.testing.assert_allclose(s_pal, s_ref, atol=1e-5)
