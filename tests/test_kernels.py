"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes/dtypes per the deliverable spec; hypothesis drives extra
randomized shape/mask configurations against the reference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _mk_qkv(key, B, Sq, Skv, Hq, Hkv, Dh, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, Hq, Dh), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, Skv, Hkv, Dh), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, Skv, Hkv, Dh), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Skv,Hq,Hkv,Dh,causal,window,q_off",
    [
        (1, 32, 32, 4, 2, 16, True, None, 0),
        (2, 32, 32, 4, 4, 16, False, None, 0),
        (1, 64, 64, 2, 1, 32, True, 16, 0),     # sliding window
        (1, 16, 64, 4, 2, 16, True, None, 48),   # prefix-extend
        (2, 32, 64, 8, 2, 16, True, 24, 32),     # extend + window
    ],
)
def test_flash_attention_vs_ref(dtype, B, Sq, Skv, Hq, Hkv, Dh, causal,
                                window, q_off):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), B, Sq, Skv, Hq, Hkv, Dh, dtype)
    out_ref = ref.mha_reference(q, k, v, causal=causal, window=window,
                                q_offset=q_off)
    out_pal = ops.attention(q, k, v, causal=causal, window=window,
                            q_offset=q_off, impl="pallas_interpret",
                            block_q=16, block_kv=16)
    out_xla = ops.attention(q, k, v, causal=causal, window=window,
                            q_offset=q_off, impl="xla",
                            block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(out_pal, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)
    np.testing.assert_allclose(np.asarray(out_xla, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("q_off,causal", [(0, True), (32, True), (0, False)])
def test_flash_attention_per_row_kv_len(q_off, causal):
    """Per-row kv_len masks bucket PAD keys for every query (extend path)."""
    B, Sq, Skv, Hq, Hkv, Dh = 3, 16, 64, 4, 2, 16
    q, k, v = _mk_qkv(jax.random.PRNGKey(3), B, Sq, Skv, Hq, Hkv, Dh,
                      jnp.float32)
    kv_len = jnp.asarray([Skv, q_off + 5, 3], jnp.int32)
    out_ref = ref.mha_reference(q, k, v, causal=causal, q_offset=q_off,
                                kv_len=kv_len)
    for impl in ("xla", "pallas_interpret"):
        out = ops.attention(q, k, v, causal=causal, q_offset=q_off,
                            kv_len=kv_len, impl=impl,
                            block_q=16, block_kv=16)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(out_ref, np.float32),
                                   atol=ATOL[jnp.float32], rtol=1e-2)
    # row 0 masks nothing: must match the kv_len=None fast path bit-for-bit
    out_none = ops.attention(q, k, v, causal=causal, q_offset=q_off,
                             impl="xla", block_q=16, block_kv=16)
    out_full = ops.attention(q, k, v, causal=causal, q_offset=q_off,
                             kv_len=kv_len, impl="xla",
                             block_q=16, block_kv=16)
    np.testing.assert_array_equal(np.asarray(out_none)[0],
                                  np.asarray(out_full)[0])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,Hq,Hkv,Dh", [
    (2, 64, 4, 2, 16),
    (1, 128, 8, 1, 32),
    (3, 32, 4, 4, 16),
])
def test_decode_attention_vs_ref(dtype, B, S, Hq, Hkv, Dh):
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (B, Hq, Dh), jnp.float32).astype(dtype)
    _, k, v = _mk_qkv(key, B, 1, S, Hq, Hkv, Dh, dtype)
    kv_len = jnp.asarray(
        np.random.default_rng(0).integers(1, S + 1, B), jnp.int32)
    out_ref = ref.decode_reference(q, k, v, kv_len=kv_len)
    out_pal = ops.decode_attention(q, k, v, kv_len,
                                   impl="pallas_interpret", block_kv=16)
    np.testing.assert_allclose(np.asarray(out_pal, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=ATOL[dtype], rtol=1e-2)


@pytest.mark.parametrize("C,T,D", [(8, 16, 32), (16, 8, 64), (24, 4, 16)])
def test_relevance_score_vs_ref(C, T, D):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (C, T, D), jnp.float32)
    lengths = jnp.asarray(
        np.random.default_rng(1).integers(1, T + 1, C), jnp.int32)
    w = jax.random.normal(jax.random.PRNGKey(3), (D,), jnp.float32)
    b = jnp.asarray(0.3, jnp.float32)
    out_ref = ref.relevance_reference(x, lengths, w, b)
    out_pal = ops.relevance_score(x, lengths, w, b,
                                  impl="pallas_interpret", block_c=8)
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=2e-5, rtol=1e-5)


def test_relevance_score_ragged_chunk_count():
    """C=130 with block_c=128: internal padding, exact [C] output."""
    C, T, D = 130, 4, 16
    x = jax.random.normal(jax.random.PRNGKey(4), (C, T, D), jnp.float32)
    lengths = jnp.asarray(
        np.random.default_rng(2).integers(1, T + 1, C), jnp.int32)
    w = jax.random.normal(jax.random.PRNGKey(5), (D,), jnp.float32)
    b = jnp.asarray(-0.2, jnp.float32)
    out_ref = ref.relevance_reference(x, lengths, w, b)
    out_pal = ops.relevance_score(x, lengths, w, b,
                                  impl="pallas_interpret", block_c=128)
    assert out_pal.shape == (C,)
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=2e-5, rtol=1e-5)


def test_decode_attention_ragged_cache_len():
    """S not a block multiple: ops pads the cache axis; kv_len masks pads."""
    B, S, Hq, Hkv, Dh = 2, 72, 4, 2, 16     # 72 % 16 != 0
    q = jax.random.normal(jax.random.PRNGKey(6), (B, Hq, Dh), jnp.float32)
    _, k, v = _mk_qkv(jax.random.PRNGKey(7), B, 1, S, Hq, Hkv, Dh,
                      jnp.float32)
    kv_len = jnp.asarray([40, 72], jnp.int32)
    out_ref = ref.decode_reference(q, k, v, kv_len=kv_len)
    out_pal = ops.decode_attention(q, k, v, kv_len,
                                   impl="pallas_interpret", block_kv=16)
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=2e-5, rtol=1e-2)


def test_arena_decode_attention_gathers_slots():
    """Arena layout: rows addressed by slot id match direct decode."""
    N, B, S, Hq, Hkv, Dh = 5, 3, 32, 4, 2, 16
    key = jax.random.PRNGKey(8)
    q = jax.random.normal(key, (B, Hq, Dh), jnp.float32)
    k_arena = jax.random.normal(jax.random.fold_in(key, 1),
                                (N, S, Hkv, Dh), jnp.float32)
    v_arena = jax.random.normal(jax.random.fold_in(key, 2),
                                (N, S, Hkv, Dh), jnp.float32)
    slots = jnp.asarray([4, 0, 2], jnp.int32)
    kv_len = jnp.asarray([10, 32, 7], jnp.int32)
    out = ops.arena_decode_attention(q, k_arena, v_arena, slots, kv_len,
                                     impl="naive")
    out_ref = ref.decode_reference(
        q, k_arena[np.asarray(slots)], v_arena[np.asarray(slots)],
        kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# Paged kernels: in-kernel slot lookup over the arena (no gather copy)
# ---------------------------------------------------------------------------

def _mk_arena(key, N, S, Hkv, Dh):
    k_arena = jax.random.normal(jax.random.fold_in(key, 1),
                                (N, S, Hkv, Dh), jnp.float32)
    v_arena = jax.random.normal(jax.random.fold_in(key, 2),
                                (N, S, Hkv, Dh), jnp.float32)
    return k_arena, v_arena


@pytest.mark.parametrize("slots,kv_len", [
    # permuted, duplicate-free slots; ragged kv_len incl. full and tiny
    ([4, 0, 2], [10, 64, 7]),
    # scratch row (n_slots = N-1) as padding sentinel, duplicated
    ([4, 4, 4], [1, 1, 64]),
    # batch larger than slot count is no constraint either way
    ([3, 1, 0], [64, 33, 16]),
])
def test_paged_decode_bitwise_equals_gather(slots, kv_len):
    """The paged decode kernel (slots in scalar-prefetch SMEM) is BITWISE
    identical to gathering the rows and running the dense kernel — the
    serving engine's paged/gather parity rests on this."""
    N, B, S, Hq, Hkv, Dh = 5, 3, 64, 4, 2, 16   # N not a multiple of B
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (B, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S, Hkv, Dh)
    slots = jnp.asarray(slots, jnp.int32)
    kv_len = jnp.asarray(kv_len, jnp.int32)
    out_paged = ops.arena_decode_attention(
        q, k_arena, v_arena, slots, kv_len,
        impl="pallas_interpret", block_kv=16)
    out_gather = ops.decode_attention(
        q, k_arena[np.asarray(slots)], v_arena[np.asarray(slots)], kv_len,
        impl="pallas_interpret", block_kv=16)
    np.testing.assert_array_equal(np.asarray(out_paged),
                                  np.asarray(out_gather))
    out_ref = ref.decode_reference(
        q, k_arena[np.asarray(slots)], v_arena[np.asarray(slots)],
        kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_ref),
                               atol=2e-5, rtol=1e-2)


def test_paged_decode_ragged_arena_falls_back_to_gather():
    """S not a kv-block multiple: the entry point silently uses the
    gather + padded dense kernel (only non-Pallas-built arenas hit this)."""
    N, B, S, Hq, Hkv, Dh = 4, 2, 72, 4, 2, 16   # 72 % 16 != 0
    key = jax.random.PRNGKey(10)
    q = jax.random.normal(key, (B, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S, Hkv, Dh)
    slots = jnp.asarray([3, 1], jnp.int32)
    kv_len = jnp.asarray([40, 72], jnp.int32)
    out = ops.arena_decode_attention(q, k_arena, v_arena, slots, kv_len,
                                     impl="pallas_interpret", block_kv=16)
    out_ref = ref.decode_reference(
        q, k_arena[np.asarray(slots)], v_arena[np.asarray(slots)],
        kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=2e-5, rtol=1e-2)


@pytest.mark.parametrize("impl", ["pallas_interpret", "xla"])
@pytest.mark.parametrize("bad", [[-1, 0, 1], [5, 0, 1], [0, 99, 1]])
def test_paged_decode_rejects_out_of_range_slots(impl, bad):
    """Concrete out-of-range slot ids raise instead of clipping silently
    (the jnp.take clip / arbitrary-DMA failure mode of the old gather)."""
    N, B, S, Hq, Hkv, Dh = 5, 3, 32, 4, 2, 16
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (B, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S, Hkv, Dh)
    kv_len = jnp.asarray([4, 8, 2], jnp.int32)
    with pytest.raises(ValueError, match="scratch row"):
        ops.arena_decode_attention(q, k_arena, v_arena,
                                   jnp.asarray(bad, jnp.int32), kv_len,
                                   impl=impl, block_kv=16)


@pytest.mark.parametrize("q_off,Sq,kv_valid", [
    (0, 16, 16),       # prefill-into-arena (cached_len == 0)
    (16, 16, 32),      # mid-cascade fraction extension
    (48, 16, 64),      # extension reaching the end of the bucket
])
def test_paged_extend_bitwise_equals_gather(q_off, Sq, kv_valid):
    """Paged flash extend == dense flash on the gathered slice, bitwise,
    with ragged per-row kv_len masking bucket PAD inside the chunk."""
    N, B, S_alloc, Hq, Hkv, Dh = 6, 3, 64, 4, 2, 16
    key = jax.random.PRNGKey(12)
    q = jax.random.normal(key, (B, Sq, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S_alloc, Hkv, Dh)
    slots = jnp.asarray([5, 0, 3], jnp.int32)   # scratch row 5 included
    kv_len = jnp.asarray([kv_valid, max(q_off - 3, 1), q_off + 5],
                         jnp.int32)
    out_paged = ops.attention_paged(
        q, k_arena, v_arena, slots, kv_valid=kv_valid, q_offset=q_off,
        kv_len=kv_len, impl="pallas_interpret", block_q=16, block_kv=16)
    kg = k_arena[np.asarray(slots)][:, :kv_valid]
    vg = v_arena[np.asarray(slots)][:, :kv_valid]
    out_dense = ops.attention(
        q, kg, vg, causal=True, q_offset=q_off, kv_len=kv_len,
        impl="pallas_interpret", block_q=16, block_kv=16)
    np.testing.assert_array_equal(np.asarray(out_paged),
                                  np.asarray(out_dense))
    out_ref = ref.mha_reference(q, kg, vg, causal=True, q_offset=q_off,
                                kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out_paged), np.asarray(out_ref),
                               atol=3e-5, rtol=1e-3)


def test_paged_extend_xla_fallback_matches_reference():
    """The gather fallback of ``attention_paged`` (CPU/reference impls)."""
    N, B, S_alloc, Hq, Hkv, Dh = 4, 2, 64, 4, 2, 16
    key = jax.random.PRNGKey(13)
    q = jax.random.normal(key, (B, 16, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S_alloc, Hkv, Dh)
    slots = jnp.asarray([2, 3], jnp.int32)
    kv_len = jnp.asarray([30, 17], jnp.int32)
    out = ops.attention_paged(q, k_arena, v_arena, slots, kv_valid=32,
                              q_offset=16, kv_len=kv_len, impl="xla",
                              block_q=16, block_kv=16)
    kg = k_arena[np.asarray(slots)][:, :32]
    vg = v_arena[np.asarray(slots)][:, :32]
    out_ref = ref.mha_reference(q, kg, vg, causal=True, q_offset=16,
                                kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=3e-5, rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2),
    nq=st.integers(1, 3),
    nkv=st.integers(1, 3),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    causal=st.booleans(),
    use_window=st.booleans(),
)
def test_flash_attention_property(b, nq, nkv, hkv, g, causal, use_window):
    """Property sweep: any block-divisible shape matches the oracle."""
    Sq, Skv, Dh = nq * 16, nkv * 16, 8
    window = 24 if use_window else None
    q_off = max(Skv - Sq, 0)
    q, k, v = _mk_qkv(jax.random.PRNGKey(b * 7 + nq), b, Sq, Skv,
                      hkv * g, hkv, Dh, jnp.float32)
    out_ref = ref.mha_reference(q, k, v, causal=causal, window=window,
                                q_offset=q_off)
    out_pal = ops.attention(q, k, v, causal=causal, window=window,
                            q_offset=q_off, impl="pallas_interpret",
                            block_q=16, block_kv=16)
    np.testing.assert_allclose(np.asarray(out_pal), np.asarray(out_ref),
                               atol=3e-5, rtol=1e-3)


def test_flash_attention_fully_masked_rows_are_zero():
    """Rows with no visible keys (window slid past) must not NaN."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(5), 1, 32, 32, 2, 1, 16,
                      jnp.float32)
    out = ops.attention(q, k, v, causal=False, window=4, q_offset=64,
                        impl="pallas_interpret", block_q=16, block_kv=16)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

# ---------------------------------------------------------------------------
# Block tables: per-block row indirection (prefix sharing)
# ---------------------------------------------------------------------------

def _materialize(arena, bt, tb):
    """Compose each batch row's virtual cache from its block table:
    positions [j*tb, (j+1)*tb) come from arena row bt[b, j]."""
    bt = np.asarray(bt)
    out = np.stack([
        np.concatenate([np.asarray(arena[bt[b, j], j * tb:(j + 1) * tb])
                        for j in range(bt.shape[1])], axis=0)
        for b in range(bt.shape[0])])
    return jnp.asarray(out)


@pytest.mark.parametrize("impl", ["pallas_interpret", "xla"])
def test_paged_decode_block_tables_bitwise(impl):
    """Block-tabled decode == the SAME impl over a materialized arena,
    bitwise: the leading columns point at a shared prefix row, the rest
    at each document's private row (prefix-sharing read geometry)."""
    N, B, S, Hq, Hkv, Dh, tb = 6, 3, 64, 4, 2, 16, 16
    key = jax.random.PRNGKey(21)
    q = jax.random.normal(key, (B, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S, Hkv, Dh)
    shared = 4                                   # the pinned prefix row
    slots = jnp.asarray([0, 2, 3], jnp.int32)
    bt = np.repeat(np.asarray(slots)[:, None], S // tb, axis=1)
    bt[:, 0] = shared                            # first block shared
    bt = jnp.asarray(bt, jnp.int32)
    kv_len = jnp.asarray([40, 64, 17], jnp.int32)
    out_bt = ops.arena_decode_attention(
        q, k_arena, v_arena, slots, kv_len, block_tables=bt,
        impl=impl, block_kv=tb)
    km = _materialize(k_arena, bt, tb)
    vm = _materialize(v_arena, bt, tb)
    ident = jnp.arange(B, dtype=jnp.int32)
    out_mat = ops.arena_decode_attention(
        q, km, vm, ident, kv_len, impl=impl, block_kv=tb)
    np.testing.assert_array_equal(np.asarray(out_bt), np.asarray(out_mat))


@pytest.mark.parametrize("impl", ["pallas_interpret", "xla"])
def test_paged_extend_block_tables_bitwise(impl):
    """Block-tabled flash extend == the SAME impl over a materialized
    arena, bitwise (mid-cascade fraction extension reading through the
    shared prefix block)."""
    N, B, S_alloc, Hq, Hkv, Dh, tb = 6, 2, 64, 4, 2, 16, 16
    key = jax.random.PRNGKey(22)
    Sq, q_off, kv_valid = 16, 16, 32
    q = jax.random.normal(key, (B, Sq, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S_alloc, Hkv, Dh)
    shared = 5
    slots = jnp.asarray([1, 3], jnp.int32)
    bt = np.repeat(np.asarray(slots)[:, None], S_alloc // tb, axis=1)
    bt[:, 0] = shared
    bt = jnp.asarray(bt, jnp.int32)
    kv_len = jnp.asarray([kv_valid, q_off + 7], jnp.int32)
    out_bt = ops.attention_paged(
        q, k_arena, v_arena, slots, kv_valid=kv_valid, q_offset=q_off,
        kv_len=kv_len, block_tables=bt, impl=impl, block_q=tb, block_kv=tb)
    km = _materialize(k_arena, bt, tb)
    vm = _materialize(v_arena, bt, tb)
    ident = jnp.arange(B, dtype=jnp.int32)
    out_mat = ops.attention_paged(
        q, km, vm, ident, kv_valid=kv_valid, q_offset=q_off,
        kv_len=kv_len, impl=impl, block_q=tb, block_kv=tb)
    np.testing.assert_array_equal(np.asarray(out_bt), np.asarray(out_mat))


def test_paged_decode_bf16_arena_tolerance():
    """A bf16-stored arena decodes within quantization tolerance of the
    f32 arena it was cast from (the serving arena's compressed storage)."""
    N, B, S, Hq, Hkv, Dh = 5, 3, 64, 4, 2, 16
    key = jax.random.PRNGKey(23)
    q = jax.random.normal(key, (B, Hq, Dh), jnp.float32)
    k_arena, v_arena = _mk_arena(key, N, S, Hkv, Dh)
    slots = jnp.asarray([0, 2, 4], jnp.int32)
    kv_len = jnp.asarray([64, 33, 16], jnp.int32)
    out32 = ops.arena_decode_attention(
        q, k_arena, v_arena, slots, kv_len,
        impl="pallas_interpret", block_kv=16)
    out16 = ops.arena_decode_attention(
        q, k_arena.astype(jnp.bfloat16), v_arena.astype(jnp.bfloat16),
        slots, kv_len, impl="pallas_interpret", block_kv=16)
    np.testing.assert_allclose(np.asarray(out16, np.float32),
                               np.asarray(out32), atol=3e-2, rtol=3e-2)
