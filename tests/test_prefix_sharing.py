"""Prefix-sharing paged arenas + bf16 KV compression.

The op-first serving plane (``LMBackend.prefix_sharing``): the operation
prefix is prefilled once per (backend, op, bucket) into a pinned,
refcounted arena row; every document's block table points its leading
columns at that row (whole-block sharing) or copies the remainder into
its private row at attach time (copy-on-write).  ``kv_dtype='bfloat16'``
stores the arena compressed, dequantizing at read.

Covered here: $-parity with the doc-before-op plane on same-op fraction
ladders; paged-vs-gather agreement inside prefix mode; bf16 tolerance +
halved byte billing; shared rows billed exactly once; bitwise COW
pristineness of the pinned prefix row; op-switch invalidation; eviction
skipping pinned rows while re-prefill tokens are counted.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.serving.engine import CascadeEngine, LMBackend
from repro.serving.scheduler import bucket_len

VOCAB = 512
# 16 words -> P == 16 == tb on the block-16 runtimes: one fully shared
# block-table column, zero COW remainder
OP_ALIGNED = ("alpha beta gamma delta epsilon zeta eta theta "
              "iota kappa lam mu nu xi omicron pi")
# 20 words: on big-block runtimes (tb == s_alloc) the whole prefix shares
# via the copy-on-write remainder instead of block-table columns
OP_RAGGED = OP_ALIGNED + " rho sigma tau upsilon"
OPS = {"o_orig": OP_ALIGNED, "sur_1": OP_RAGGED}
IMPOSSIBLE = {0: 2.0, 1: 2.0}      # no early exit: schedule-identical runs


def _mk_backend(name, seed, tokz, impl="xla", blocks=16, **kw):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=VOCAB,
                      num_layers=2)
    rcfg = resolve(cfg, tp=1)
    rt = (Runtime(attn_impl=impl, block_q=blocks, block_kv=blocks,
                  remat=False)
          if blocks else Runtime(attn_impl=impl, remat=False))
    m = LM(rcfg, rt)
    return LMBackend(
        name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
        tokenizer=tokz,
        rate_per_token=1.0 if name == "oracle" else 0.06, s_alloc=512, **kw)


@pytest.fixture(scope="module")
def tokz():
    return HashWordTokenizer(vocab_size=VOCAB)


@pytest.fixture(scope="module")
def docs():
    return {d.doc_id: d.text
            for d in generate_corpus(6, avg_lines=6, seed=7)}


def _toks(tokz, docs):
    return {d: np.asarray(tokz.encode(t), np.int32)
            for d, t in docs.items()}


def _run_ladder(tokz, docs, prefix, kv_dtype=None, op="o_orig", **be_kw):
    backends = {
        "proxy": _mk_backend("proxy", 1, tokz, prefix_sharing=prefix,
                             kv_dtype=kv_dtype, **be_kw),
        "oracle": _mk_backend("oracle", 2, tokz, prefix_sharing=prefix,
                              kv_dtype=kv_dtype, **be_kw)}
    eng = CascadeEngine(backends, OPS, n_classes=2, batch_size=4)
    ladder = Cascade([
        Task(TaskConfig("proxy", op, 0.25), IMPOSSIBLE),
        Task(TaskConfig("proxy", op, 1.0), IMPOSSIBLE),
    ])
    return eng.run(ladder, docs), backends


def test_prefix_dollar_parity_and_counters(tokz, docs):
    """Same-op fraction ladder: the op-first plane bills EXACTLY what the
    doc-before-op plane bills, per document — billing follows the token
    accounting contract, not the physical prefill work the memo saves."""
    res_a, _ = _run_ladder(tokz, docs, prefix=False)
    res_b, _ = _run_ladder(tokz, docs, prefix=True)
    for d in docs:
        assert res_a.doc_cost[d] == res_b.doc_cost[d]
    assert set(res_b.pred) == set(docs)
    st = res_b.stats
    assert st.prefix_hits > 0
    assert st.arena_bytes_peak > 0
    assert res_a.stats.prefix_hits == 0


def test_prefix_paged_vs_gather_parity(tokz, docs):
    """Inside prefix mode the pallas plane and the XLA gather reference
    agree on preds (and confs to numerical tolerance) stage by stage."""
    toks = _toks(tokz, docs)
    ids = sorted(toks)
    blen = max(bucket_len(len(toks[d])) for d in ids)
    op = np.asarray(tokz.encode(OPS["o_orig"]), np.int32)
    be_x = _mk_backend("proxy", 1, tokz, impl="xla", prefix_sharing=True)
    be_p = _mk_backend("proxy", 1, tokz, impl="pallas_interpret",
                       prefix_sharing=True)
    for frac in (0.25, 1.0):
        px, cx, nx, cax = be_x.run_stage(ids, toks, blen, frac, op, 2)
        pp, cp, np_, cap = be_p.run_stage(ids, toks, blen, frac, op, 2)
        np.testing.assert_array_equal(px, pp)
        np.testing.assert_allclose(cx, cp, atol=1e-4)
        assert nx == np_ and cax == cap


def test_bf16_arena_parity_and_halved_bytes(tokz, docs):
    """bf16-compressed arenas: same $ to the cent, preds equal and confs
    within quantization tolerance of f32, and every byte-accounting
    surface bills the stored dtype (half an f32 row)."""
    res32, bes32 = _run_ladder(tokz, docs, prefix=True)
    res16, bes16 = _run_ladder(tokz, docs, prefix=True,
                               kv_dtype="bfloat16")
    for d in docs:
        assert res32.doc_cost[d] == res16.doc_cost[d]
    match = np.mean([res32.pred[d] == res16.pred[d] for d in docs])
    assert match >= 0.8        # random-init logits are near-uniform
    dconf = max(abs(res32.conf[d] - res16.conf[d]) for d in docs)
    assert dconf < 5e-2
    b32 = bes32["proxy"].slot_nbytes(128)
    b16 = bes16["proxy"].slot_nbytes(128)
    assert b16 == b32 // 2
    for ar in bes16["proxy"]._arenas.values():
        assert all(l.dtype == jnp.bfloat16
                   for l in jax.tree.leaves(ar.states))


def test_shared_prefix_row_billed_once(tokz, docs):
    """N attached documents pin ONE prefix row: the allocator issues one
    pseudo-slot for the op however many documents share it, so the byte
    ledger counts the shared KV exactly once."""
    toks = _toks(tokz, docs)
    ids = sorted(toks)
    blen = max(bucket_len(len(toks[d])) for d in ids)
    op = np.asarray(tokz.encode(OPS["o_orig"]), np.int32)
    be = _mk_backend("proxy", 1, tokz, prefix_sharing=True)
    be.run_stage(ids, toks, blen, 0.5, op, 2)
    assert be._alloc.live(blen) == len(ids) + 1     # docs + ONE prefix row
    ar = be._arenas[blen]
    assert len(ar.prefix_row) == 1
    row = next(iter(ar.prefix_row.values()))
    assert ar.prefix_refs[row] == len(ids)
    # arena bytes == rows * per-row bytes: the shared row appears once
    assert be.arena_nbytes() == (ar.capacity + 1) * be.slot_nbytes(blen)
    # a second stage attaches nothing new (idempotent refcounts)
    hits = be.prefix_hits
    be.run_stage(ids, toks, blen, 1.0, op, 2)
    assert be.prefix_hits == hits
    assert ar.prefix_refs[row] == len(ids)


def test_cow_prefix_row_stays_bitwise_pristine(tokz, docs):
    """Property: through extend / decode-undo-log / release / re-attach
    interleavings, the pinned prefix row's KV window stays BITWISE
    identical to the moment it was prefilled (documents copy on write,
    never write through the shared mapping)."""
    toks = _toks(tokz, docs)
    ids = sorted(toks)
    blen = max(bucket_len(len(toks[d])) for d in ids)
    op = np.asarray(tokz.encode(OPS["sur_1"]), np.int32)   # ragged: COW
    be = _mk_backend("proxy", 1, tokz, blocks=None, prefix_sharing=True)
    be.run_stage(ids[:2], toks, blen, 0.25, op, 2)
    assert be.cow_copies == 2          # big blocks: pure-COW sharing
    ar = be._arenas[blen]
    row = next(iter(ar.prefix_row.values()))
    p_eff = be._prefix_eff_len(len(op))

    def window():
        w = be.model.take_kv_window(
            ar.states, jnp.asarray([row], jnp.int32),
            jnp.asarray([0], jnp.int32), p_eff)
        return [np.asarray(l) for l in jax.tree.leaves(w)]

    baseline = window()
    be.run_stage(ids[:2], toks, blen, 1.0, op, 2)        # extend + readout
    be.run_stage(ids[:2], toks, blen, 0.5, op, 2)        # decode-only
    be.run_stage(ids[2:], toks, blen, 1.0, op, 2)        # new attachments
    be.release(ids[0])                                   # detach one
    be.run_stage([ids[0]], toks, blen, 1.0, op, 2)       # fresh re-attach
    for a, b in zip(baseline, window()):
        np.testing.assert_array_equal(a, b)
    # arena loss / retire drops the memo; the next stage re-prefills and
    # reproduces the same outputs (recovery path)
    p_before, c_before, *_ = be.run_stage(ids, toks, blen, 1.0, op, 2)
    for d in ids:
        be.release(d)
    be.retire(blen)
    assert blen not in be._arenas
    p_after, c_after, *_ = be.run_stage(ids, toks, blen, 1.0, op, 2)
    np.testing.assert_array_equal(p_before, p_after)
    np.testing.assert_allclose(c_before, c_after, atol=1e-6)


def test_op_switch_invalidates_prefix_cache(tokz, docs):
    """Op-first layout bakes the op into every document's KV (the doc
    attends to the prefix), so a stage advance that switches ops on the
    same backend must re-prefill from scratch — stage 1 bills ZERO cached
    tokens, where the doc-before-op plane reuses the fraction prefix."""
    backends = {
        "proxy": _mk_backend("proxy", 1, tokz, prefix_sharing=True),
        "oracle": _mk_backend("oracle", 2, tokz, prefix_sharing=True)}
    eng = CascadeEngine(backends, OPS, n_classes=2, batch_size=4)
    ladder = Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), IMPOSSIBLE),
        Task(TaskConfig("proxy", "o_orig", 1.0), IMPOSSIBLE),
    ])
    res = eng.run(ladder, docs)
    assert set(res.pred) == set(docs)
    assert res.stats.stage_cached_tokens[1] == 0
    res_base, _ = _run_ladder(tokz, docs, prefix=False)
    assert res_base.stats.stage_cached_tokens[1] > 0


def test_eviction_skips_pinned_prefix_rows(tokz, docs):
    """Under slot pressure evictions preempt documents, never the pinned
    prefix row, and every cached token an eviction loses is counted as a
    re-prefill token (the capacity benchmark's gated metric).

    Pressure needs priority inversion: each newcomer arrives OLDER than
    every cached veteran (arrival=-j), so its launch must steal a slot.
    A batch drain would instead resolve veterans first and recycle their
    slots without ever evicting."""
    res_ref, _ = _run_ladder(tokz, docs, prefix=True)   # unbudgeted ref
    backends = {
        "proxy": _mk_backend("proxy", 1, tokz, prefix_sharing=True,
                             slot_budget=3),
        "oracle": _mk_backend("oracle", 2, tokz, prefix_sharing=True)}
    eng = CascadeEngine(backends, OPS, n_classes=2, batch_size=4)
    eng.start(Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), IMPOSSIBLE),
        Task(TaskConfig("proxy", "o_orig", 1.0), IMPOSSIBLE),
    ]))
    for j, d in enumerate(sorted(docs)):
        eng.submit(d, docs[d], arrival=float(-j))
        eng.step()
    res = eng.drain()
    assert set(res.pred) == set(docs)
    st = res.stats
    assert st.evictions > 0
    assert st.re_prefill_tokens > 0
    assert st.prefix_hits > 0
    # the pinned row survived every eviction: the memo is still installed
    # and refcounts dropped back to zero as documents resolved
    proxy = backends["proxy"]
    rows = [(ar, row) for ar in proxy._arenas.values()
            for row in ar.prefix_row.values()]
    assert rows
    assert all(ar.prefix_refs.get(row, 0) == 0 for ar, row in rows)
    # evicted documents re-resolved to the unbudgeted plane's outputs
    assert res.pred == res_ref.pred
    np.testing.assert_allclose(
        [res.conf[d] for d in sorted(docs)],
        [res_ref.conf[d] for d in sorted(docs)], atol=1e-5)
