"""Runtime arena sanitizer (``repro.analysis.sanitizer``).

Unit level: hand-constructed overlap / pinned-write / use-after-release
fixtures deterministically raise :class:`ArenaRaceError` naming the
conflicting rows and both launch signatures.  Engine level: a seeded
chaos drain under ``sanitize=True`` runs violation-free and is bitwise
inert on preds/confs/$ versus the unsanitized run; the prefix-sharing
plane (pin + COW paths) gates green; the kernel-wrapper hook registry
skips tracers and validates eager row operands.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitizer import (ArenaRaceError, ArenaSanitizer,
                                      env_enabled)
from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.kernels import sanitize as ksan
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import CascadeEngine, CascadeServer, LMBackend
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.scheduler import RESOLVED, RetryPolicy

BK = 64          # arbitrary bucket id for unit tests


def _san(**kw):
    s = ArenaSanitizer(backend="proxy", **kw)
    for row, doc in ((0, 10), (1, 11), (2, 12), (3, 13)):
        s.note_alloc(BK, row, doc)
    return s


# ------------------------------------------------------------- unit: overlap
def test_write_write_overlap_names_rows_and_signatures():
    s = _san()
    s.begin_launch(BK, "launch-A", reads={0, 1}, writes={0, 1})
    with pytest.raises(ArenaRaceError) as ei:
        s.begin_launch(BK, "launch-B", reads={1, 2}, writes={1, 2})
    e = ei.value
    assert e.kind == "overlap" and e.bucket == BK
    assert e.rows == [1]
    assert set(e.signatures) == {"launch-A", "launch-B"}
    assert "row 1" in str(e) and "doc 11" in str(e)


def test_write_read_overlap():
    s = _san()
    s.begin_launch(BK, "writer", reads=set(), writes={2})
    with pytest.raises(ArenaRaceError) as ei:
        s.begin_launch(BK, "reader", reads={2}, writes=set())
    assert "write/read" in str(ei.value)


def test_disjoint_inflight_launches_are_legal():
    s = _san()
    t1 = s.begin_launch(BK, "A", reads={0}, writes={0})
    t2 = s.begin_launch(BK, "B", reads={1}, writes={1})
    s.end_launch(t1)
    s.end_launch(t2)
    # rows free again for the next launch once both retired
    s.end_launch(s.begin_launch(BK, "C", reads={0, 1}, writes={0, 1}))
    assert s.violations == 0 and s.checks == 3


def test_end_launch_clears_the_conflict():
    s = _san()
    t = s.begin_launch(BK, "A", reads={0}, writes={0})
    s.end_launch(t)
    s.end_launch(s.begin_launch(BK, "B", reads={0}, writes={0}))


# -------------------------------------------------------- unit: pinned rows
def test_pinned_write_raises_outside_cow():
    s = _san()
    s.note_pin(BK, 3, "op:sur_1")
    with pytest.raises(ArenaRaceError) as ei:
        s.begin_launch(BK, "step", reads={0, 3}, writes={0, 3})
    e = ei.value
    assert e.kind == "pinned_write" and e.rows == [3]
    assert "op:sur_1" in str(e)


def test_pinned_write_legal_inside_cow():
    s = _san()
    s.note_pin(BK, 3, "op:sur_1")
    with s.cow(BK):
        s.end_launch(s.begin_launch(BK, "prefill", reads={3}, writes={3}))
    # shared READ of a pinned row needs no COW
    s.end_launch(s.begin_launch(BK, "step", reads={0, 3}, writes={0}))
    assert s.violations == 0


def test_pinned_row_clear_and_release_raise():
    s = _san()
    s.note_pin(BK, 2, "op:o")
    with pytest.raises(ArenaRaceError):
        s.note_clear(BK, 2)
    s = _san()
    s.note_pin(BK, 2, "op:o")
    with pytest.raises(ArenaRaceError):
        s.note_release(BK, 2)
    s.note_unpin(BK, 2)
    s.note_release(BK, 2)           # unpin first -> legal


# ------------------------------------------------- unit: use after release
def test_use_after_release():
    s = _san()
    s.note_release(BK, 1)
    with pytest.raises(ArenaRaceError) as ei:
        s.begin_launch(BK, "stale", reads={1}, writes={1})
    assert ei.value.kind == "use_after_release" and ei.value.rows == [1]


def test_double_release_and_double_alloc():
    s = _san()
    s.note_release(BK, 1)
    with pytest.raises(ArenaRaceError):
        s.note_release(BK, 1)
    s = _san()
    with pytest.raises(ArenaRaceError) as ei:
        s.note_alloc(BK, 1, 99)     # row 1 is still LIVE for doc 11
    assert ei.value.kind == "double_alloc"


def test_clear_under_inflight_launch_raises():
    s = _san()
    s.begin_launch(BK, "A", reads={1}, writes={1})
    with pytest.raises(ArenaRaceError) as ei:
        s.note_clear(BK, 1)
    assert ei.value.kind == "overlap"


def test_retire_drops_rows_and_flags_stale_use():
    s = _san()
    t = s.begin_launch(BK, "A", reads={0}, writes={0})
    with pytest.raises(ArenaRaceError):
        s.note_retire(BK)           # retire under an in-flight launch
    s.end_launch(t)
    s.note_retire(BK)
    with pytest.raises(ArenaRaceError) as ei:
        s.begin_launch(BK, "B", reads={0}, writes={0})
    assert "retired" in str(ei.value)


def test_scratch_row_is_exempt():
    s = _san()
    # scratch (row 7 here) is never allocated yet legal in every set
    s.end_launch(s.begin_launch(BK, "A", reads={0, 7}, writes={0, 7},
                                scratch=7))
    assert s.violations == 0


def test_doc_info_callback_names_owner():
    s = _san(doc_info=lambda rid: {"query": 5, "doc": rid - 10})
    s.begin_launch(BK, "A", reads={0}, writes={0})
    with pytest.raises(ArenaRaceError) as ei:
        s.begin_launch(BK, "B", reads={0}, writes={0})
    assert "'query': 5" in str(ei.value)


# -------------------------------------------------------- unit: kernel hook
def test_kernel_hook_range_and_registration():
    s = _san()
    hook = s.kernel_hook()
    hook("decode", np.asarray([0, 1, 2]), 4)        # in range, none in flight
    with pytest.raises(ArenaRaceError) as ei:
        hook("decode", np.asarray([0, 5]), 4)
    assert ei.value.kind == "unregistered_rows" and ei.value.rows == [5]
    t = s.begin_launch(BK, "A", reads={0, 1}, writes={0, 1}, scratch=4)
    hook("decode", np.asarray([[0, 1], [4, 4]]), 4)  # registered + scratch
    with pytest.raises(ArenaRaceError) as ei:
        hook("decode", np.asarray([2]), 4)           # live but unregistered
    assert ei.value.rows == [2]
    s.end_launch(t)
    assert s.kernel_checks == 4


def test_notify_rows_skips_tracers_and_reaches_hooks_eagerly():
    calls = []
    hid = ksan.add_row_hook(lambda where, rows, n: calls.append(where))
    try:
        @jax.jit
        def f(x):
            ksan.notify_rows("traced", x, 4)
            return x
        f(jnp.arange(3))
        assert calls == []          # tracers short-circuit
        ksan.notify_rows("eager", np.arange(3), 4)
        assert calls == ["eager"]
    finally:
        ksan.remove_row_hook(hid)
    ksan.notify_rows("after-remove", np.arange(3), 4)
    assert calls == ["eager"]


# ----------------------------------------------------- unit: counters/reset
def test_private_counters_and_reset():
    s = _san()
    s.end_launch(s.begin_launch(BK, "A", reads={0, 1}, writes={0, 1}))
    c = s.counters()
    assert c["serve_sanitizer_checks_total"] == 1
    assert c["serve_sanitizer_rows_checked_total"] == 2
    assert c["serve_sanitizer_violations_total"] == 0
    s.reset()
    assert s.counters()["serve_sanitizer_checks_total"] == 1  # survive reset
    s.note_alloc(BK, 0, 42)        # rows forgotten -> re-allocatable


def test_env_enabled():
    assert env_enabled({"ARENA_SANITIZE": "1"})
    assert env_enabled({"ARENA_SANITIZE": "yes"})
    assert not env_enabled({"ARENA_SANITIZE": "0"})
    assert not env_enabled({"ARENA_SANITIZE": ""})
    assert not env_enabled({})


# =================================================== engine integration
VOCAB = 512
OPS = {"o_orig": "does this overturn a lower court decision",
       "sur_1": "is a lower court mentioned"}
THR = {0: 0.7, 1: 0.7}
IMPOSSIBLE = {0: 2.0, 1: 2.0}
CASCADE = Cascade([
    Task(TaskConfig("proxy", "sur_1", 0.25), THR),
    Task(TaskConfig("proxy", "o_orig", 1.0), THR),
])


def _mk_backend(name, seed, tokz, **kw):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=VOCAB,
                      num_layers=2)
    m = LM(resolve(cfg, tp=1), CPU_TEST)
    return LMBackend(
        name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
        tokenizer=tokz,
        rate_per_token=1.0 if name == "oracle" else 0.06, s_alloc=512, **kw)


@pytest.fixture(scope="module")
def tokz():
    return HashWordTokenizer(vocab_size=VOCAB)


@pytest.fixture(scope="module")
def docs():
    return {d.doc_id: d.text
            for d in generate_corpus(8, avg_lines=10, seed=7)}


def test_env_var_activates_sanitizer(tokz, monkeypatch):
    be = _mk_backend("proxy", 1, tokz)
    monkeypatch.setenv("ARENA_SANITIZE", "1")
    assert be.sanitize is None and be.sanitizer() is not None
    be2 = _mk_backend("proxy", 1, tokz)
    monkeypatch.setenv("ARENA_SANITIZE", "0")
    assert be2.sanitizer() is None
    be3 = _mk_backend("proxy", 1, tokz, sanitize=False)
    monkeypatch.setenv("ARENA_SANITIZE", "1")
    assert be3.sanitizer() is None          # explicit False wins over env


def _chaos_drain(backends, docs, sanitize):
    for be in backends.values():
        be.reset()
        be.sanitize = sanitize
        be._sanitizer = None
    srv = CascadeServer(dict(backends), OPS, n_classes=2, batch_size=4,
                        retry=RetryPolicy(max_retries=2, backoff_base=0.0))
    # seed 3 injects launch failures AND nan quarantines while leaving
    # the proxy enough successful launches to exercise its brackets
    inj = FaultInjector(FaultPlan(seed=3, launch_failure_p=0.15,
                                  nan_p=0.1, latency_spike_p=0.1))
    inj.install(srv)
    h = srv.register(CASCADE)
    for i, d in enumerate(sorted(docs)):
        h.submit(d, docs[d], arrival=float(i))
    res = h.drain()
    return srv, h, res


def test_seeded_chaos_sanitized_is_violation_free_and_bitwise_inert(
        tokz, docs):
    """The acceptance gate: a seeded chaos drain with the sanitizer on
    finishes with zero violations and EXACTLY the preds/confs/$ &
    status of the unsanitized run (host-side shadow only — no device
    math, no RNG draws, no hub counters)."""
    backends = {"proxy": _mk_backend("proxy", 1, tokz),
                "oracle": _mk_backend("oracle", 2, tokz)}
    srv0, h0, res0 = _chaos_drain(backends, docs, sanitize=False)
    assert h0.stats.sanitizer_checks == 0
    counters0 = srv0.telemetry.counters() \
        if hasattr(srv0.telemetry, "counters") else None

    srv1, h1, res1 = _chaos_drain(backends, docs, sanitize=True)
    # the sanitizer builds lazily on first launch — a backend no chaos
    # path ever launched (all docs exited earlier) stays None
    sans = [s for s in (backends[n]._sanitizer for n in backends)
            if s is not None]
    assert backends["proxy"]._sanitizer is not None
    assert sum(s.violations for s in sans) == 0
    assert sum(s.checks for s in sans) > 0
    assert h1.stats.sanitizer_checks == sum(s.checks for s in sans)

    # bitwise inert: preds / confs / per-doc $ / terminal statuses equal
    assert res0.status == res1.status
    assert res0.pred == res1.pred
    assert res0.conf == res1.conf           # float equality, not approx
    assert res0.doc_cost == res1.doc_cost
    # hub metric registry untouched by the sanitizer's check counters
    if counters0 is not None:
        counters1 = srv1.telemetry.counters()
        assert counters0.keys() == counters1.keys()
        assert not any(k.startswith("serve_sanitizer")
                       for k in counters1)


def test_prefix_sharing_paths_gate_green(tokz, docs):
    """Pin + COW lifecycle under the sanitizer: the op-first ladder
    (shared pinned prefix row, partial-block copy-on-write, reclaim)
    completes with zero violations."""
    backends = {
        "proxy": _mk_backend("proxy", 1, tokz, prefix_sharing=True,
                             sanitize=True),
        "oracle": _mk_backend("oracle", 2, tokz, prefix_sharing=True,
                              sanitize=True)}
    eng = CascadeEngine(backends, OPS, n_classes=2, batch_size=4)
    ladder = Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), IMPOSSIBLE),
        Task(TaskConfig("proxy", "o_orig", 1.0), IMPOSSIBLE),
    ])
    res = eng.run(ladder, docs)
    assert set(res.pred) == set(docs)
    assert res.stats.prefix_hits > 0
    san = backends["proxy"]._sanitizer
    assert san is not None and san.violations == 0 and san.checks > 0
    # the memoized op row is tracked as PINNED while referenced rows live
    assert any(r.state == "pinned"
               for rows in san._rows.values() for r in rows.values()) \
        or san.checks > 0


def test_engine_release_recycle_is_clean(tokz, docs):
    """Streaming slot recycling (release -> re-alloc of the same row for
    a new document) must not trip double_alloc/use_after_release."""
    be = _mk_backend("proxy", 1, tokz, sanitize=True, init_slots=2)
    orc = _mk_backend("oracle", 2, tokz, sanitize=True, init_slots=2)
    srv = CascadeServer({"proxy": be, "oracle": orc}, OPS, n_classes=2,
                        batch_size=2)
    h = srv.register(Cascade([Task(TaskConfig("proxy", "o_orig", 1.0),
                                   THR)]))
    for i, d in enumerate(sorted(docs)):
        h.submit(d, docs[d], arrival=float(i))
    res = h.drain()
    assert set(res.status.values()) == {RESOLVED}
    assert be._sanitizer.violations == 0 and be._sanitizer.checks > 0
