"""Overlapped ahead-of-time dispatch (ROADMAP item 2).

The server keeps up to ``inflight`` launches open: ``dispatch_group``
enqueues the jitted step non-blocking and returns a ticket whose
sanitizer bracket stays OPEN; ``complete_group`` syncs only when the
scheduler needs the launch's confidences for routing.  These tests pin
the contract: bitwise parity with ``inflight=1`` on the fault-free plane
(preds/confs/per-doc $ and arena device state), a seeded chaos drain
with K>1 under the sanitizer (all docs terminal, ledger exact, zero
violations), the sanitizer still catching two open tickets on one row,
faults surfacing at completion rather than dispatch, and per-ticket
timing that never forces synchronization.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.sanitizer import ArenaRaceError
from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import CascadeServer, LMBackend
from repro.serving.faults import (FaultInjector, FaultPlan,
                                  InjectedLaunchFailure)
from repro.serving.scheduler import (TERMINAL_STATES, RetryPolicy,
                                     bucket_len, fraction_len)


def _mk_backend(name, seed, tokz, **kw):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    rcfg = resolve(cfg, tp=1)
    m = LM(rcfg, CPU_TEST)
    return LMBackend(
        name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
        tokenizer=tokz,
        rate_per_token=1.0 if name == "oracle" else 0.06, s_alloc=512, **kw)


OPS = {"o_orig": "does this overturn a lower court decision",
       "sur_1": "is a lower court mentioned"}

IMPOSSIBLE = {0: 2.0, 1: 2.0}
# multi-stage forced ladder: every doc escalates, so the queue always
# holds several same-signature cohorts — the overlap window fills
LADDER = Cascade([
    Task(TaskConfig("proxy", "sur_1", 0.25), IMPOSSIBLE),
    Task(TaskConfig("proxy", "o_orig", 1.0), IMPOSSIBLE),
])
CASCADE = Cascade([
    Task(TaskConfig("proxy", "sur_1", 0.25), {0: 0.7, 1: 0.7}),
    Task(TaskConfig("proxy", "o_orig", 1.0), {0: 0.7, 1: 0.7}),
])


@pytest.fixture(scope="module")
def tokz():
    return HashWordTokenizer(vocab_size=512)


@pytest.fixture(scope="module")
def docs():
    return {d.doc_id: d.text
            for d in generate_corpus(8, avg_lines=10, seed=7)}


def _capture_releases(backends):
    """Fingerprint every document's arena row at the moment it exits.

    Post-drain arena bytes are NOT schedule-comparable: dispatch order
    at K>1 legally differs from K=1 (the window fills with already-ready
    cohorts before a completion re-queues escalated docs), so doc->slot
    assignment permutes AND freed slots are reused in different orders,
    leaving schedule-dependent stale bytes past each new owner's valid
    region.  The schedule-independent contract is what a document LEAVES
    BEHIND: wrap ``release`` to snapshot the departing doc's valid KV
    window ``[0, cached_len)`` (its slot is still owned here, and
    eviction drains conflicting tickets before releasing, so no open
    ticket can be writing the row).  Returns the store, filled as
    ``(backend, bucket, doc) -> [(cached_len, true_len, bytes), ...]``
    (a list: an evicted doc releases once per preemption plus once at
    exit)."""
    store = {}
    for nm in sorted(backends):
        be = backends[nm]
        orig = be.release

        def release(doc_id, be=be, orig=orig, nm=nm):
            bs = be._doc_slot.get(doc_id)
            if bs is not None:
                bucket, slot = bs
                ar = be._arenas.get(bucket)
                if ar is not None:
                    c = int(ar.cached_len[slot])
                    t = int(ar.true_len[slot])
                    if c == 0:
                        body = b""
                    elif be.model.supports_paged_kv:
                        win = be.model.take_kv_window(
                            ar.states, jnp.asarray([slot], jnp.int32),
                            jnp.asarray([0], jnp.int32), c)
                        body = b"".join(np.asarray(leaf).tobytes()
                                        for leaf in jax.tree.leaves(win))
                    else:       # no seq-axis contract: full row, best-effort
                        flat, _ = jax.tree_util.tree_flatten_with_path(
                            ar.states)
                        body = b"".join(
                            np.take(np.asarray(leaf), slot,
                                    axis=ar.model._state_batch_axis(path)
                                    ).tobytes()
                            for path, leaf in flat)
                    store.setdefault((nm, bucket, doc_id), []).append(
                        (c, t, body))
            orig(doc_id)

        be.release = release
    return store


def _replay(tokz, docs, cascade, inflight, sanitize=None, plan=None):
    """Fresh backends + server at the given window depth; drain the
    whole corpus (logical arrivals) and return (server, result,
    backends, release-time row fingerprints)."""
    backends = {"proxy": _mk_backend("proxy", 1, tokz, sanitize=sanitize),
                "oracle": _mk_backend("oracle", 2, tokz,
                                      sanitize=sanitize)}
    rows = _capture_releases(backends)
    srv = CascadeServer(dict(backends), OPS, n_classes=2, batch_size=4,
                        retry=RetryPolicy(max_retries=2, backoff_base=0.0),
                        inflight=inflight)
    if plan is not None:
        FaultInjector(plan).install(srv)
    h = srv.register(cascade)
    for i, d in enumerate(sorted(docs)):
        h.submit(d, docs[d], arrival=float(i))
    res = h.drain()
    return srv, res, backends, rows


def _ledger_exact(srv):
    per_q = {qid: 0.0 for qid in srv._handles}
    per_d = {}
    for _, qid, rid, cost in srv.ledger():
        per_q[qid] += cost
        per_d[rid] = per_d.get(rid, 0.0) + cost
    assert all(total == srv.cost(qid) for qid, total in per_q.items())
    assert all(per_d.get(rid, 0.0) == req.cost
               for rid, req in srv._requests.items())


# --------------------------------------------------- bitwise parity K vs 1
def test_inflight_parity_bitwise(tokz, docs):
    """Ahead-of-time dispatch may only change WHEN the host blocks,
    never what it computes: preds, confs, per-doc $, and the arena row
    content every document leaves behind must equal the ``inflight=1``
    run bitwise — and the deep run must actually overlap
    (``max_inflight >= 2``)."""
    srv1, res1, bk1, rows1 = _replay(tokz, docs, LADDER, inflight=1)
    srv3, res3, bk3, rows3 = _replay(tokz, docs, LADDER, inflight=3)
    assert srv1._max_inflight_seen == 1
    assert srv3._max_inflight_seen >= 2
    assert res3.pred == res1.pred
    assert res3.conf == res1.conf           # float equality, not approx
    assert res3.doc_cost == res1.doc_cost
    assert res3.status == res1.status
    # dispatch order may legally differ (the window fills with ready
    # cohorts before completions re-queue escalated docs), but billing
    # must be the same per-document ENTRIES, reordered at most
    assert sorted((q, r, c) for _, q, r, c in srv3.ledger()) \
        == sorted((q, r, c) for _, q, r, c in srv1.ledger())
    _ledger_exact(srv3)
    assert rows1, "release capture never fired"
    assert set(rows3) == set(rows1)
    for key in rows1:                       # (backend, bucket, doc)
        assert rows3[key] == rows1[key], key   # (lens, KV bytes), bitwise
    snap = srv3.telemetry_snapshot()
    assert snap["server"]["max_inflight"] >= 2
    assert "overlap_hidden_frac" in snap["timeline"]
    assert "mean_launch_gap_ms" in snap["timeline"]
    assert "inflight_s" in snap["timeline"]


# ------------------------------------------- chaos drain, K>1, sanitized
def test_chaos_drain_inflight_sanitized(tokz, docs):
    """Seeded fault injection with three launches in flight under the
    arena sanitizer: every document terminal, billing ledger exact,
    zero sanitizer violations across the open-bracket windows."""
    plan = FaultPlan(seed=3, launch_failure_p=0.15, nan_p=0.1,
                     latency_spike_p=0.1, spike_s=1e-4, arena_loss_at=4)
    srv, res, backends, _ = _replay(tokz, docs, CASCADE, inflight=3,
                                    sanitize=True, plan=plan)
    assert all(s in TERMINAL_STATES for s in res.status.values())
    assert set(res.status) == set(docs)
    _ledger_exact(srv)
    sans = [be._sanitizer for be in backends.values()
            if be._sanitizer is not None]
    assert sans, "sanitizer never engaged"
    assert sum(s.violations for s in sans) == 0
    assert sum(s.checks for s in sans) > 0
    assert srv.faults.counts["arena_losses"] == 1


# ------------------------------------- sanitizer catches overlapping rows
def test_sanitizer_raises_on_shared_row_open_tickets(tokz, docs):
    """The open bracket is the audit surface: while a dispatched
    ticket's launch is un-completed, a second launch registering the
    same row must raise ``ArenaRaceError`` — and succeed again once the
    ticket completes."""
    be = _mk_backend("proxy", 1, tokz, sanitize=True)
    d = sorted(docs)[0]
    toks = {d: np.asarray(be.tokenizer.encode(docs[d]), np.int32)}
    bucket = bucket_len(len(toks[d]))
    f_len = fraction_len(bucket, 1.0)
    op = np.asarray(be.tokenizer.encode("test op"), np.int32)
    ticket = be.dispatch_group([d], toks, bucket, f_len, 1.0, 0, op, 2)
    assert ticket.san is not None
    _, row = be._doc_slot[d]
    with pytest.raises(ArenaRaceError) as exc:
        ticket.san.begin_launch(bucket, "deliberate-overlap",
                                reads={row}, writes={row})
    assert exc.value.kind == "overlap"
    assert row in exc.value.rows
    be.complete_group(ticket)               # closes the bracket
    t2 = ticket.san.begin_launch(bucket, "after-completion",
                                 reads={row}, writes={row})
    ticket.san.end_launch(t2)
    assert ticket.san.inflight_peak >= 1


# ------------------------------------------- faults surface at completion
def test_faults_surface_at_completion(tokz, docs):
    """A poisoned launch returns a ticket from ``dispatch_group``
    without touching the wrapped backend (nothing enqueued, no state
    committed); the ``InjectedLaunchFailure`` raises at
    ``complete_group`` — where async dispatch surfaces real device
    errors."""
    be = _mk_backend("proxy", 1, tokz)
    fb = FaultInjector(FaultPlan(seed=0, launch_failure_p=1.0)).wrap(be)
    d = sorted(docs)[0]
    toks = {d: np.asarray(be.tokenizer.encode(docs[d]), np.int32)}
    bucket = bucket_len(len(toks[d]))
    f_len = fraction_len(bucket, 1.0)
    op = np.asarray(be.tokenizer.encode("test op"), np.int32)
    ticket = fb.dispatch_group([d], toks, bucket, f_len, 1.0, 0, op, 2)
    assert ticket.inner is None             # step never enqueued
    assert be.cached_len(d) == 0            # no state committed
    with pytest.raises(InjectedLaunchFailure):
        fb.complete_group(ticket)


# --------------------------------------------------- per-ticket timing
def test_per_ticket_timing_without_sync(tokz, docs):
    """``dispatch_group`` must not block: the ticket carries only the
    host/dispatch segments until completion measures the device wait;
    ``last_timing`` updates at completion (per-ticket, no forced
    sync inside the step)."""
    be = _mk_backend("proxy", 1, tokz)
    d = sorted(docs)[0]
    toks = {d: np.asarray(be.tokenizer.encode(docs[d]), np.int32)}
    bucket = bucket_len(len(toks[d]))
    f_len = fraction_len(bucket, 1.0)
    op = np.asarray(be.tokenizer.encode("test op"), np.int32)
    be.last_timing = None
    ticket = be.dispatch_group([d], toks, bucket, f_len, 1.0, 0, op, 2)
    assert set(ticket.timing) == {"host", "dispatch"}
    assert be.last_timing is None           # nothing synced yet
    assert ticket.ts_dispatched >= ticket.ts_enqueue > 0.0
    pred, conf, new_d, cached_d = be.complete_group(ticket)
    assert set(ticket.timing) == {"host", "dispatch", "device"}
    assert be.last_timing == ticket.timing
    assert ticket.ts_ready >= ticket.ts_sync >= ticket.ts_dispatched
    assert len(pred) == len(conf) == 1
    assert int(new_d[0]) > 0
