"""Static-analysis suite tests: each RSA rule on violating AND clean
snippets, inline suppression, baseline round-trip, CLI exit codes, and
the self-test that the shipped tree is clean against the committed
baseline."""
import json
import textwrap

import pytest

from repro.analysis import lint
from repro.analysis.lint import (Finding, diff_baseline, lint_source,
                                 load_baseline, main, save_baseline)


def _rules(src):
    return sorted({f.rule for f in lint_source(textwrap.dedent(src),
                                               "snippet.py")})


# --------------------------------------------------------------- RSA001
VIOLATING_RSA001_DEFAULT = """
    import jax

    @jax.jit
    def step(x, history=[]):
        return x
"""

VIOLATING_RSA001_CLOSURE = """
    import jax

    def build():
        cache = {}
        @jax.jit
        def step(x):
            return x + len(cache)
        cache["k"] = 1
        return step
"""

CLEAN_RSA001 = """
    import jax

    def build():
        scale = 2.0          # immutable closure capture is fine
        @jax.jit
        def step(x, history=None):
            return x * scale
        return step
"""


def test_rsa001_mutable_default():
    assert "RSA001" in _rules(VIOLATING_RSA001_DEFAULT)


def test_rsa001_mutated_closure():
    assert "RSA001" in _rules(VIOLATING_RSA001_CLOSURE)


def test_rsa001_clean():
    assert "RSA001" not in _rules(CLEAN_RSA001)


# --------------------------------------------------------------- RSA002
VIOLATING_RSA002_INDEX_MAP = """
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    spec = pl.BlockSpec((1, 128), lambda b, j: (jnp.argmax(b), j))
"""

VIOLATING_RSA002_PREFETCH_ORDER = """
    from jax.experimental.pallas import tpu as pltpu

    def kernel(q_ref, slots_ref, o_ref):
        o_ref[...] = q_ref[...]

    import jax.experimental.pallas as pl
    call = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(4,), in_specs=[], out_specs=None),
        out_shape=None)
"""

VIOLATING_RSA002_LITERAL_GRID = """
    from jax.experimental.pallas import tpu as pltpu

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=(8, 16), in_specs=[], out_specs=None)
"""

CLEAN_RSA002 = """
    from jax.experimental.pallas import tpu as pltpu

    def build(B, Hkv, nkv):
        def kernel(kv_len_ref, q_ref, o_ref):
            o_ref[...] = q_ref[...]
        spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=(B, Hkv, nkv),
            in_specs=[], out_specs=None)
        return kernel, spec
"""


def test_rsa002_traced_index_map():
    assert "RSA002" in _rules(VIOLATING_RSA002_INDEX_MAP)


def test_rsa002_prefetch_param_order():
    assert "RSA002" in _rules(VIOLATING_RSA002_PREFETCH_ORDER)


def test_rsa002_literal_grid():
    assert "RSA002" in _rules(VIOLATING_RSA002_LITERAL_GRID)


def test_rsa002_clean():
    assert "RSA002" not in _rules(CLEAN_RSA002)


# --------------------------------------------------------------- RSA003
VIOLATING_RSA003 = """
    import jax

    step = jax.jit(lambda p, s: (p, s), donate_argnums=(1,))

    def run(params, arena):
        logits, new_states = step(params, arena.states)
        stale = arena.states.mean()       # read of the DONATED buffer
        arena.states = new_states
        return logits, stale
"""

CLEAN_RSA003 = """
    import jax

    step = jax.jit(lambda p, s: (p, s), donate_argnums=(1,))

    def run(params, arena):
        logits, new_states = step(params, arena.states)
        arena.states = new_states         # donate-then-rebind idiom
        return logits, arena.states.mean()
"""


def test_rsa003_read_after_donate():
    assert "RSA003" in _rules(VIOLATING_RSA003)


def test_rsa003_donate_then_rebind_clean():
    assert "RSA003" not in _rules(CLEAN_RSA003)


# --------------------------------------------------------------- RSA004
VIOLATING_RSA004 = """
    from dataclasses import dataclass

    @dataclass
    class LaunchStats:
        launches: int = 0

        def merge_from(self, other):
            self.launches += other.launches
"""

CLEAN_RSA004 = """
    import dataclasses
    from dataclasses import dataclass, field

    def _stat(merge, **kw):
        return field(metadata={"merge": merge}, **kw)

    @dataclass
    class LaunchStats:
        launches: int = _stat("sum", default=0)
        peak: int = field(default=0, metadata={"merge": "max"})

        def merge_from(self, other):
            for f in dataclasses.fields(self):
                pass
"""


def test_rsa004_missing_merge_metadata():
    assert "RSA004" in _rules(VIOLATING_RSA004)


def test_rsa004_clean():
    assert "RSA004" not in _rules(CLEAN_RSA004)


# --------------------------------------------------------------- RSA005
VIOLATING_RSA005 = """
    import time
    import jax

    @jax.jit
    def step(x):
        return x * time.perf_counter()
"""

CLEAN_RSA005 = """
    import time
    import jax

    @jax.jit
    def step(x, key):
        return x + jax.random.normal(key, x.shape)

    def host_loop(x):
        t0 = time.perf_counter()     # wall clock OUTSIDE jit is fine
        return step(x, jax.random.PRNGKey(0)), time.perf_counter() - t0
"""


def test_rsa005_wallclock_in_jit():
    assert "RSA005" in _rules(VIOLATING_RSA005)


def test_rsa005_clean():
    assert "RSA005" not in _rules(CLEAN_RSA005)


# ----------------------------------------------------- inline suppression
def test_inline_suppression():
    src = textwrap.dedent(VIOLATING_RSA001_DEFAULT).replace(
        "def step(x, history=[]):",
        "def step(x, history=[]):  # lint: disable=RSA001")
    assert "RSA001" not in {f.rule for f in lint_source(src, "snippet.py")}


def test_syntax_error_is_rsa000():
    assert _rules("def broken(:\n    pass") == ["RSA000"]


# ------------------------------------------------------------- baseline
def test_baseline_round_trip(tmp_path):
    findings = lint_source(textwrap.dedent(VIOLATING_RSA001_DEFAULT),
                           "mod.py")
    assert findings
    bl = tmp_path / "baseline.json"
    save_baseline(bl, findings, {findings[0].key: "legacy, tracked in #12"})
    entries = load_baseline(bl)
    assert entries[0]["reason"] == "legacy, tracked in #12"

    new, stale, suppressed = diff_baseline(findings, entries)
    assert (new, stale, suppressed) == ([], [], len(findings))

    # baseline keys on line TEXT, so pure line drift keeps it valid...
    shifted = lint_source("\n\n\n" + textwrap.dedent(
        VIOLATING_RSA001_DEFAULT), "mod.py")
    new, stale, _ = diff_baseline(shifted, entries)
    assert (new, stale) == ([], [])

    # ...but editing the flagged line itself surfaces the finding again
    edited = lint_source(textwrap.dedent(VIOLATING_RSA001_DEFAULT).replace(
        "history=[]", "hist=[]"), "mod.py")
    new, stale, _ = diff_baseline(edited, entries)
    assert new and stale


def test_baseline_missing_file_is_empty(tmp_path):
    assert load_baseline(tmp_path / "nope.json") == []


# ------------------------------------------------------------ CLI driver
def test_cli_clean_exit_0(tmp_path, capsys):
    (tmp_path / "ok.py").write_text(textwrap.dedent(CLEAN_RSA001))
    assert main([str(tmp_path), "--no-baseline"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_findings_exit_1(tmp_path, capsys):
    (tmp_path / "bad.py").write_text(textwrap.dedent(
        VIOLATING_RSA001_DEFAULT))
    assert main([str(tmp_path), "--no-baseline"]) == 1
    assert "RSA001" in capsys.readouterr().out


def test_cli_usage_error_exit_2(tmp_path):
    assert main([str(tmp_path / "does-not-exist")]) == 2


def test_cli_baseline_suppresses_and_goes_stale(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(VIOLATING_RSA001_DEFAULT))
    bl = tmp_path / "baseline.json"
    assert main([str(tmp_path), "--baseline", str(bl),
                 "--write-baseline"]) == 0
    assert main([str(tmp_path), "--baseline", str(bl)]) == 0
    capsys.readouterr()
    # fixing the violation makes the baseline entry STALE -> exit 1
    bad.write_text(textwrap.dedent(CLEAN_RSA001))
    assert main([str(tmp_path), "--baseline", str(bl)]) == 1
    assert "stale" in capsys.readouterr().out


def test_every_rule_fires_in_selftest():
    """Deliberate violation of each rule is caught (acceptance gate)."""
    fired = set()
    for src in (VIOLATING_RSA001_DEFAULT, VIOLATING_RSA002_INDEX_MAP,
                VIOLATING_RSA003, VIOLATING_RSA004, VIOLATING_RSA005):
        fired |= set(_rules(src))
    assert fired >= {"RSA001", "RSA002", "RSA003", "RSA004", "RSA005"}


def test_shipped_tree_is_clean_vs_committed_baseline():
    """The committed source + committed baseline must gate green (the CI
    `analysis` job runs exactly this)."""
    pkg_root = lint._PKG_ROOT
    findings = lint.lint_paths([pkg_root])
    baseline = load_baseline(lint._DEFAULT_BASELINE)
    new, stale, _ = diff_baseline(findings, baseline)
    assert not new, [f.format() for f in new]
    assert not stale, stale


def test_committed_baseline_entries_have_reasons():
    data = json.loads(lint._DEFAULT_BASELINE.read_text())
    for e in data["suppressions"]:
        assert e.get("reason") and "TODO" not in e["reason"], e
