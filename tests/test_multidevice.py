"""Multi-device semantics, run in a subprocess with 8 forced host devices
(the main pytest process keeps the single real CPU device)."""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)


@pytest.mark.slow
def test_multidevice_checks():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(HERE, "multidevice_checks.py")],
        capture_output=True, text=True, timeout=1200, env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "ALL_MULTIDEVICE_OK" in proc.stdout, proc.stdout
