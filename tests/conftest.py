"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
