"""Launch-layer units: HLO collective parsing, R-extrapolation arithmetic,
roofline terms, logical param counts, mesh helpers, remesh-compatible specs.
(The heavy 512-device compile path is exercised by the dry-run itself.)
"""
import numpy as np
import pytest

from repro.config import SHAPES, resolve
from repro.configs import ARCHS, get_config
from repro.launch.dryrun import parse_collective_bytes
from repro.launch.roofline import (analytic_memory_floor, analyze,
                                   logical_param_counts, model_flops)

HLO_SNIPPET = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={}
  %ag = bf16[64,64]{1,0} all-gather(bf16[32,64]{1,0} %y), dimensions={0}
  %plain = f32[8,8]{1,0} add(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
  %a2a = f32[16]{0} all-to-all(f32[16]{0} %z)
"""


def test_parse_collective_bytes():
    out = parse_collective_bytes(HLO_SNIPPET)
    # output+operand convention: simple AR counts ~2x the payload
    assert out["all-reduce"] == 2 * 128 * 256 * 4
    assert out["all-gather"] == 64 * 64 * 2 + 32 * 64 * 2
    assert out["all-to-all"] == 2 * 16 * 4
    assert "add" not in out and len(out) == 3


def test_extrapolation_identity():
    # A + (R-1)(B-A) must reproduce exact linear costs
    base, body, R = 7.0, 3.0, 10
    a = base + body
    b = base + 2 * body
    assert a + (R - 1) * (b - a) == base + R * body


@pytest.mark.parametrize("arch", ARCHS)
def test_logical_param_counts_in_range(arch):
    """Param counts must land near the arch's advertised size."""
    advertised = {
        "gemma3_27b": 27e9, "minitron_4b": 4e9, "qwen3_1_7b": 1.7e9,
        "llama3_2_1b": 1.2e9, "qwen2_vl_2b": 1.5e9, "phi3_5_moe": 42e9,
        "dbrx_132b": 132e9, "whisper_base": 72e6, "xlstm_350m": 350e6,
        "recurrentgemma_2b": 2.7e9,
    }[arch]
    n = logical_param_counts(arch)["total"]
    assert 0.3 * advertised < n < 3.0 * advertised, (arch, n)


def test_moe_active_less_than_total():
    c = logical_param_counts("dbrx_132b")
    assert c["active"] < 0.5 * c["total"]


@pytest.mark.parametrize("arch,shape", [
    ("llama3_2_1b", "train_4k"), ("gemma3_27b", "prefill_32k"),
    ("gemma3_27b", "long_500k"), ("dbrx_132b", "decode_32k")])
def test_memory_floor_positive_and_sane(arch, shape):
    floor = analytic_memory_floor(arch, shape, 256)
    assert floor > 0
    # per-chip floor must be below HBM-feasible per-step traffic at 1 Hz
    assert floor < 1e13


def test_model_flops_train_is_6nd():
    mf = model_flops("llama3_2_1b", "train_4k")
    n = logical_param_counts("llama3_2_1b")["active"]
    d = SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
    assert mf == pytest.approx(6 * n * d)


def test_analyze_handles_failed_and_good_cells():
    assert analyze({"ok": False}) is None
    row = analyze({
        "ok": True, "arch": "llama3_2_1b", "shape": "train_4k",
        "mesh": "single", "devices": 256,
        "flops": 3.3e13, "bytes_accessed": 4.1e12,
        "collective_bytes": {"all-reduce": 1e10},
        "extrapolated": {"flops": 3.3e13, "bytes_accessed": 4.1e12,
                         "collective_bytes": {"all-reduce": 1e10}},
    })
    assert row.dominant in ("compute", "memory", "collective")
    assert 0 < row.useful_ratio < 2
    assert row.memory_s <= row.memory_hlo_s


def test_all_configs_resolve_for_tp16():
    """Padding policy must produce TP-clean dims for every arch."""
    for arch in ARCHS:
        cfg = get_config(arch)
        r = resolve(cfg, tp=16)
        assert r.padded_heads % 16 == 0 or r.padded_heads < 16
        assert r.padded_vocab % 16 == 0
        if cfg.pad_kv_to_tp or cfg.num_kv_heads >= 16:
            assert r.padded_kv_heads % 16 == 0
        assert r.padded_heads % r.padded_kv_heads == 0


def test_supported_shapes_follow_assignment_rules():
    from repro.config import ATTN_FULL
    for arch in ARCHS:
        cfg = get_config(arch)
        kinds = set(cfg.layer_kinds())
        pure_full_attn = kinds == {ATTN_FULL}
        if "long_500k" in cfg.supported_shapes:
            assert not pure_full_attn, f"{arch} must skip long_500k"
        assert "train_4k" in cfg.supported_shapes
        assert "decode_32k" in cfg.supported_shapes   # all archs decode
