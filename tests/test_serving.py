"""Serving engine: prefix reuse, exits, cost parity, scheduler buckets."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import CascadeEngine, LMBackend
from repro.serving.scheduler import ServeStats, bucket_len, make_buckets


@pytest.fixture(scope="module")
def engine():
    tokz = HashWordTokenizer(vocab_size=512)

    def mk(name, seed):
        cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                          num_layers=2)
        rcfg = resolve(cfg, tp=1)
        m = LM(rcfg, CPU_TEST)
        return LMBackend(
            name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
            tokenizer=tokz,
            rate_per_token=1.0 if name == "oracle" else 0.06, s_alloc=512)

    backends = {"proxy": mk("proxy", 1), "oracle": mk("oracle", 2)}
    ops = {"o_orig": "does this overturn a lower court decision",
           "sur_1": "is a lower court mentioned"}
    return CascadeEngine(backends, ops, n_classes=2, batch_size=4)


@pytest.fixture(scope="module")
def docs():
    return {d.doc_id: d.text
            for d in generate_corpus(10, avg_lines=10, seed=7)}


def test_engine_resolves_every_doc(engine, docs):
    cascade = Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), {0: 0.7, 1: 0.7}),
        Task(TaskConfig("proxy", "o_orig", 1.0), {0: 0.7, 1: 0.7}),
    ])
    res = engine.run(cascade, docs)
    assert set(res.pred) == set(docs)
    assert all(0 <= s <= 2 for s in res.exit_stage.values())
    assert res.cost > 0


def test_engine_prefix_reuse_reduces_cost(engine, docs):
    """fraction ladder 0.25 -> 1.0 on the same model must hit the cache."""
    thr = {0: 2.0, 1: 2.0}     # impossible thresholds: nothing exits early
    ladder = Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])
    res = engine.run(ladder, docs)
    assert res.stats.cache_hit_rate() > 0.05
    # cached tokens ~= the 0.25 prefix re-read at stage 2
    assert res.stats.stage_cached_tokens[1] > 0


def test_engine_extension_equals_fresh(engine, docs):
    """Same doc, fraction 0.25 then 1.0 == fresh 1.0 (logit-exact)."""
    be = engine.backends["proxy"]
    be.reset()
    d0 = next(iter(docs))
    toks = {d0: np.asarray(be.tokenizer.encode(docs[d0]), np.int32)}
    blen = bucket_len(len(toks[d0]))
    op = np.asarray(be.tokenizer.encode("test op"), np.int32)
    be.run_stage([d0], toks, blen, 0.25, op, 2)
    _, c_ext, *_ = be.run_stage([d0], toks, blen, 1.0, op, 2)
    be.reset()
    _, c_fresh, *_ = be.run_stage([d0], toks, blen, 1.0, op, 2)
    np.testing.assert_allclose(c_ext, c_fresh, atol=1e-5)


def test_engine_smaller_fraction_reuses_larger_cache(engine, docs):
    """After f=1.0 is cached, f=0.5 must be fully cached (no new doc toks)."""
    be = engine.backends["proxy"]
    be.reset()
    d0 = next(iter(docs))
    toks = {d0: np.asarray(be.tokenizer.encode(docs[d0]), np.int32)}
    blen = bucket_len(len(toks[d0]))
    op = np.asarray(be.tokenizer.encode("op"), np.int32)
    be.run_stage([d0], toks, blen, 1.0, op, 2)
    _, _, new_t, cached_t = be.run_stage([d0], toks, blen, 0.5, op, 2)
    assert new_t == len(op)            # only operation tokens are new
    assert cached_t > 0


def test_bucketing():
    assert bucket_len(10) == 32
    assert bucket_len(33) == 64
    lengths = {i: l for i, l in enumerate([10, 20, 40, 50, 60, 500])}
    batches = make_buckets(range(6), lengths, batch_size=2)
    sizes = [blen for blen, _ in batches]
    assert sizes == sorted(sizes)
    all_ids = [d for _, ids in batches for d in ids]
    assert sorted(all_ids) == list(range(6))
    assert all(len(ids) <= 2 for _, ids in batches)


def test_serve_stats_accounting():
    s = ServeStats()
    s.record(0, 4, 100, 0)
    s.record(1, 2, 50, 30)
    assert s.total_new_tokens() == 150
    assert s.total_cached_tokens() == 30
    assert 0 < s.cache_hit_rate() < 1
