"""Serving: multi-tenant server (cross-query packing, per-query
partitioning), request loop, prefix reuse, slot/byte budgets + eviction,
cost parity, scheduler buckets + ready queue + policies."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import CascadeEngine, CascadeServer, LMBackend
from repro.serving.scheduler import (DocRequest, RequestQueue, ServeStats,
                                     bucket_len, largest_ready_group,
                                     make_buckets, pack_stage_batches)


def _mk_backend(name, seed, tokz, **kw):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    rcfg = resolve(cfg, tp=1)
    m = LM(rcfg, CPU_TEST)
    return LMBackend(
        name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
        tokenizer=tokz,
        rate_per_token=1.0 if name == "oracle" else 0.06, s_alloc=512, **kw)


OPS = {"o_orig": "does this overturn a lower court decision",
       "sur_1": "is a lower court mentioned"}


def _mk_engine(batch_size=4, **backend_kw):
    tokz = HashWordTokenizer(vocab_size=512)
    backends = {"proxy": _mk_backend("proxy", 1, tokz, **backend_kw),
                "oracle": _mk_backend("oracle", 2, tokz, **backend_kw)}
    return CascadeEngine(backends, OPS, n_classes=2, batch_size=batch_size)


@pytest.fixture(scope="module")
def engine():
    return _mk_engine()


@pytest.fixture(scope="module")
def docs():
    return {d.doc_id: d.text
            for d in generate_corpus(10, avg_lines=10, seed=7)}


def test_engine_resolves_every_doc(engine, docs):
    cascade = Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), {0: 0.7, 1: 0.7}),
        Task(TaskConfig("proxy", "o_orig", 1.0), {0: 0.7, 1: 0.7}),
    ])
    res = engine.run(cascade, docs)
    assert set(res.pred) == set(docs)
    assert all(0 <= s <= 2 for s in res.exit_stage.values())
    assert res.cost > 0


def test_engine_prefix_reuse_reduces_cost(engine, docs):
    """fraction ladder 0.25 -> 1.0 on the same model must hit the cache."""
    thr = {0: 2.0, 1: 2.0}     # impossible thresholds: nothing exits early
    ladder = Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])
    res = engine.run(ladder, docs)
    assert res.stats.cache_hit_rate() > 0.05
    # cached tokens ~= the 0.25 prefix re-read at stage 2
    assert res.stats.stage_cached_tokens[1] > 0


def test_engine_extension_equals_fresh(engine, docs):
    """Same doc, fraction 0.25 then 1.0 == fresh 1.0 (logit-exact)."""
    be = engine.backends["proxy"]
    be.reset()
    d0 = next(iter(docs))
    toks = {d0: np.asarray(be.tokenizer.encode(docs[d0]), np.int32)}
    blen = bucket_len(len(toks[d0]))
    op = np.asarray(be.tokenizer.encode("test op"), np.int32)
    be.run_stage([d0], toks, blen, 0.25, op, 2)
    _, c_ext, *_ = be.run_stage([d0], toks, blen, 1.0, op, 2)
    be.reset()
    _, c_fresh, *_ = be.run_stage([d0], toks, blen, 1.0, op, 2)
    np.testing.assert_allclose(c_ext, c_fresh, atol=1e-5)


def test_engine_smaller_fraction_reuses_larger_cache(engine, docs):
    """After f=1.0 is cached, f=0.5 must be fully cached (no new doc toks)."""
    be = engine.backends["proxy"]
    be.reset()
    d0 = next(iter(docs))
    toks = {d0: np.asarray(be.tokenizer.encode(docs[d0]), np.int32)}
    blen = bucket_len(len(toks[d0]))
    op = np.asarray(be.tokenizer.encode("op"), np.int32)
    be.run_stage([d0], toks, blen, 1.0, op, 2)
    _, _, new_t, cached_t = be.run_stage([d0], toks, blen, 0.5, op, 2)
    assert new_t == len(op)            # only operation tokens are new
    assert cached_t > 0


def test_mixed_entry_stages_reuse_cached_prefixes(engine, docs):
    """Docs that enter the cascade at different stages share a bucket but
    keep their cached prefixes: the stage splits into per-cached-len
    launches instead of re-prefilling the whole batch (the seed fallback).
    """
    thr = {0: 2.0, 1: 2.0}     # impossible: nothing exits before the oracle
    ladder = Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])
    late = sorted(docs)[0]
    res = engine.run(ladder, docs, enter_stage={late: 1})
    # stage 1 mixes veterans (cached at f=0.25) with the late entrant
    # (cached_len 0); veterans' prefixes must be billed as cached
    assert res.stats.stage_cached_tokens[1] > 0
    # the late entrant only ever runs stages 1 and 2
    assert res.stats.stage_docs[0] == len(docs) - 1
    assert res.stats.stage_docs[1] == len(docs)
    assert set(res.pred) == set(docs)


def test_run_stage_heterogeneous_cache_matches_homogeneous(engine, docs):
    """A mixed-cache batch returns the same confidences as separate runs."""
    be = engine.backends["proxy"]
    ids = sorted(docs)[:2]
    toks = {d: np.asarray(be.tokenizer.encode(docs[d]), np.int32)
            for d in ids}
    blen = max(bucket_len(len(t)) for t in toks.values())
    op = np.asarray(be.tokenizer.encode("mixed op"), np.int32)
    # homogeneous reference: each doc alone, fresh, straight to f=1.0
    be.reset()
    _, c0, *_ = be.run_stage([ids[0]], toks, blen, 1.0, op, 2)
    _, c1, *_ = be.run_stage([ids[1]], toks, blen, 1.0, op, 2)
    # mixed: doc0 pre-cached at 0.25, doc1 cold, one batched call
    be.reset()
    be.run_stage([ids[0]], toks, blen, 0.25, op, 2)
    _, c_mix, new_t, cached_t = be.run_stage(ids, toks, blen, 1.0, op, 2)
    assert cached_t > 0                       # doc0's prefix was reused
    np.testing.assert_allclose(c_mix, [c0[0], c1[0]], atol=1e-5)


def test_slot_recycling(engine, docs):
    """Released slots are re-issued before the arena grows."""
    be = engine.backends["proxy"]
    be.reset()
    ids = sorted(docs)[:3]
    toks = {d: np.asarray(be.tokenizer.encode(docs[d]), np.int32)
            for d in ids}
    blen = max(bucket_len(len(t)) for t in toks.values())
    op = np.asarray(be.tokenizer.encode("op"), np.int32)
    be.run_stage(ids[:2], toks, blen, 1.0, op, 2)
    assert be._alloc.high_water(blen) == 2
    be.release(ids[0])
    be.run_stage([ids[2]], toks, blen, 1.0, op, 2)
    assert be._alloc.high_water(blen) == 2    # reused the freed slot
    assert be.cached_len(ids[2]) == max(int(np.ceil(blen)), 1)


def test_engine_stage_cost_exposed(engine, docs):
    cascade = Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), {0: 0.7, 1: 0.7}),
    ])
    res = engine.run(cascade, docs)
    assert res.stage_cost == res.stats.stage_cost
    assert res.cost == pytest.approx(sum(res.stage_cost))
    assert res.cost == pytest.approx(res.stats.total_cost())
    assert all(c >= 0 for c in res.stage_cost)


def test_pack_stage_batches_groups_by_cached_len():
    lengths = {1: 30, 2: 30, 3: 30, 4: 100}
    cached = {1: 8, 2: 8, 3: 0, 4: 0}
    batches = pack_stage_batches([1, 2, 3, 4], lengths, cached,
                                 fraction=1.0, batch_size=8)
    keys = {(b.bucket, b.cached_len): list(b.doc_ids) for b in batches}
    assert keys == {(32, 8): [1, 2], (32, 0): [3], (128, 0): [4]}
    # caches covering the fraction collapse into one decode-only group
    batches = pack_stage_batches([1, 2, 3], lengths,
                                 {1: 32, 2: 16, 3: 32},
                                 fraction=0.25, batch_size=8)
    assert [(b.bucket, b.cached_len, b.doc_ids) for b in batches] == \
        [(32, 8, (1, 2, 3))]


def test_bucketing():
    assert bucket_len(10) == 32
    assert bucket_len(33) == 64
    lengths = {i: l for i, l in enumerate([10, 20, 40, 50, 60, 500])}
    batches = make_buckets(range(6), lengths, batch_size=2)
    sizes = [blen for blen, _ in batches]
    assert sizes == sorted(sizes)
    all_ids = [d for _, ids in batches for d in ids]
    assert sorted(all_ids) == list(range(6))
    assert all(len(ids) <= 2 for _, ids in batches)


def test_serve_stats_accounting():
    s = ServeStats()
    s.record(0, 4, 100, 0)
    s.record(1, 2, 50, 30)
    assert s.total_new_tokens() == 150
    assert s.total_cached_tokens() == 30
    assert 0 < s.cache_hit_rate() < 1
    s.latencies = [0.1, 0.2, 0.3, 0.4]
    assert s.latency_quantile(0.5) == pytest.approx(0.25)
    assert s.latency_quantile(1.0) == pytest.approx(0.4)


# ---------------------------------------------------------------------------
# Continuous-batching request loop
# ---------------------------------------------------------------------------

LADDER = Cascade([
    Task(TaskConfig("proxy", "sur_1", 0.25), {0: 0.7, 1: 0.7}),
    Task(TaskConfig("proxy", "o_orig", 1.0), {0: 0.75, 1: 0.75}),
])


def test_request_loop_matches_run(engine, docs):
    """run() is a thin wrapper over submit()/step()/poll()/drain(): driving
    the loop by hand must produce identical preds/confs/cost."""
    ref = engine.run(LADDER, docs)

    engine.start(LADDER)
    for i, (d, text) in enumerate(docs.items()):
        engine.submit(d, text, arrival=float(i))
    polled = {}
    while engine.pending():
        engine.step()
        polled.update(engine.poll())
    res = engine.result()
    assert res.pred == ref.pred
    assert res.exit_stage == ref.exit_stage
    assert res.conf == ref.conf                      # bit-identical
    assert res.cost == pytest.approx(ref.cost, rel=1e-12)
    assert res.stats.stage_docs == ref.stats.stage_docs
    assert res.stats.total_new_tokens() == ref.stats.total_new_tokens()
    assert res.stats.total_cached_tokens() == ref.stats.total_cached_tokens()
    # poll() delivered every resolution exactly once
    assert {d: v[0] for d, v in polled.items()} == ref.pred
    assert len(res.stats.latencies) == len(docs)


def test_streaming_admission_mid_cascade(engine, docs):
    """Late arrivals are admitted between launches (not at stage barriers)
    and do not force veterans to re-prefill."""
    ids = sorted(docs)
    early, late = ids[: len(ids) // 2], ids[len(ids) // 2:]
    ref = engine.run(LADDER, docs)                    # static baseline

    engine.start(LADDER)
    for d in early:
        engine.submit(d, docs[d], arrival=0.0)
    # a few launches with only the early cohort in flight
    for _ in range(2):
        engine.step()
    mid_pending = engine.pending()
    for d in late:
        engine.submit(d, docs[d], arrival=1.0)
    assert engine.pending() > mid_pending             # admitted mid-run
    res = engine.drain()
    assert set(res.pred) == set(docs)
    assert res.pred == ref.pred
    # identical per-document token work: no whole-batch re-prefill happened
    assert res.stats.total_new_tokens() == ref.stats.total_new_tokens()
    assert res.stats.total_cached_tokens() == ref.stats.total_cached_tokens()
    assert res.stats.cache_hit_rate() >= ref.stats.cache_hit_rate()


def test_eviction_requeues_and_resolves(docs):
    """Under a tiny slot budget the newest-arrival slot is preempted; the
    evicted document re-resolves correctly with its re-prefill counted as
    new tokens."""
    ids = sorted(docs)[:2]
    sub = {d: docs[d] for d in ids}
    thr = {0: 2.0, 1: 2.0}                            # nothing exits early
    ladder = Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])
    ref_eng = _mk_engine(batch_size=1)
    ref = ref_eng.run(ladder, sub)                    # unbudgeted baseline

    eng = _mk_engine(batch_size=1, slot_budget=1)
    a, b = ids
    eng.start(ladder)
    eng.submit(a, sub[a], arrival=0.0)
    eng.step()                                        # a cached at stage 0
    assert eng.backends["proxy"].cached_len(a) > 0
    eng.submit(b, sub[b], arrival=-1.0)               # older -> higher prio
    eng.step()                                        # launches b, evicts a
    assert eng._stats.evictions >= 1
    assert eng.backends["proxy"].cached_len(a) == 0   # cache gone
    res = eng.drain()
    assert set(res.pred) == {a, b}
    assert res.pred == ref.pred
    np.testing.assert_allclose(
        [res.conf[d] for d in ids], [ref.conf[d] for d in ids], atol=1e-5)
    # the evicted doc's re-prefill is billed as new tokens
    assert res.stats.total_new_tokens() > ref.stats.total_new_tokens()
    assert res.stats.evictions == eng._reqs[a].evictions >= 1


def test_byte_budget_evicts_and_resolves(docs):
    """A byte-denominated budget preempts slots when the pending launch
    would GROW an arena past it; the evicted document re-resolves and the
    arenas never exceed the budget."""
    ids = _same_bucket_ids(docs, 2)
    sub = {d: docs[d] for d in ids}
    thr = {0: 2.0, 1: 2.0}
    ladder = Cascade([
        Task(TaskConfig("proxy", "o_orig", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 1.0), thr),
    ])
    ref = _mk_engine(batch_size=1).run(ladder, sub)   # unbudgeted baseline

    eng = _mk_engine(batch_size=1, init_slots=1)
    bucket = bucket_len(
        len(eng.backends["proxy"].tokenizer.encode(sub[ids[0]])))
    for be in eng.backends.values():
        # room for ONE live row + the scratch row, never a second slot
        be.byte_budget = 2 * be.slot_nbytes(bucket)
        assert be.slot_budget is None                 # bytes bind, not slots
    a, b = ids
    eng.start(ladder)
    eng.submit(a, sub[a], arrival=0.0)
    eng.step()                                        # a cached at stage 0
    assert eng.backends["proxy"].cached_len(a) > 0
    eng.submit(b, sub[b], arrival=-1.0)               # older -> higher prio
    eng.step()                                        # b launches, evicts a
    assert eng._stats.evictions >= 1
    be = eng.backends["proxy"]
    assert be.cached_len(a) == 0                      # cache gone
    # an arena irreducibly over budget must NOT thrash its residents:
    # with no growth forced, same-bucket eviction frees no bytes
    live, saved = be.live_docs(), be.byte_budget
    assert live
    be.byte_budget = 1                                # below even one row
    assert be.evict_for_room(bucket, 0, live) == []   # need_new == 0
    assert be.live_docs() == live
    be.byte_budget = saved
    res = eng.drain()
    assert res.pred == ref.pred
    np.testing.assert_allclose(
        [res.conf[d] for d in ids], [ref.conf[d] for d in ids], atol=1e-5)
    # re-prefill billed as new tokens; arenas stayed within budget
    assert res.stats.total_new_tokens() > ref.stats.total_new_tokens()
    for be in eng.backends.values():
        assert be.arena_nbytes() <= be.byte_budget


def test_slot_nbytes_matches_arena_accounting(engine, docs):
    """The shape-only per-slot projection agrees exactly with the bytes a
    materialized arena pins."""
    be = engine.backends["proxy"]
    be.reset()
    d0 = sorted(docs)[0]
    toks = {d0: np.asarray(be.tokenizer.encode(docs[d0]), np.int32)}
    blen = bucket_len(len(toks[d0]))
    op = np.asarray(be.tokenizer.encode("op"), np.int32)
    be.run_stage([d0], toks, blen, 1.0, op, 2)
    ar = be._arenas[blen]
    assert be.slot_nbytes(blen) * (ar.capacity + 1) == ar.nbytes()
    assert be.projected_nbytes(blen, 0) == be.arena_nbytes()


def test_victim_order_prefers_fewest_cached_tokens(docs):
    """Eviction victims are ordered fewest-cached-tokens-lost first, with
    newest arrival breaking ties (the old policy was newest-only)."""
    eng = _mk_engine(batch_size=1)
    be = eng.backends["proxy"]
    a, b, c = sorted(docs)[:3]
    toks = {a: np.asarray(be.tokenizer.encode(docs[a]), np.int32),
            b: np.asarray(be.tokenizer.encode(docs[b]), np.int32)}
    toks[c] = toks[b]              # equal lengths -> equal cache: tie-break
    blen = max(bucket_len(len(t)) for t in toks.values())
    op = np.asarray(be.tokenizer.encode("op"), np.int32)
    be.run_stage([a], toks, blen, 0.25, op, 2)        # a: small cache, old
    be.run_stage([b, c], toks, blen, 1.0, op, 2)      # b, c: full caches
    eng._requests.update({
        a: DocRequest(a, arrival=0.0, seq=0),
        b: DocRequest(b, arrival=1.0, seq=1),
        c: DocRequest(c, arrival=2.0, seq=2),
    })
    # fewest cached tokens first (a, despite being OLDEST); among the
    # equal-cache pair, the newer arrival (c) goes first
    assert eng._victim_order(be, protected=set()) == [a, c, b]
    assert eng._victim_order(be, protected={a}) == [c, b]


def test_bucket_retirement_frees_arena():
    """A bucket idle for ``retire_after`` launches releases its arena."""
    eng = _mk_engine(batch_size=4, retire_after=1)
    short = "alpha beta gamma delta"
    long = " ".join(f"w{i} token" for i in range(60))
    eng.start(Cascade([]))                            # oracle-only resolve
    eng.submit(1, short, arrival=0.0)
    eng.submit(2, long, arrival=1.0)
    eng.step()                                        # short doc resolves
    oracle = eng.backends["oracle"]
    assert oracle.arena_nbytes() >= 0
    res = eng.drain()                                 # long doc's launch sees
    assert set(res.pred) == {1, 2}                    # the idle small bucket
    assert res.stats.retired_buckets >= 1
    small = bucket_len(len(oracle.tokenizer.encode(short)))
    assert small not in oracle._arenas                # device arena freed


# ---------------------------------------------------------------------------
# Multi-tenant server
# ---------------------------------------------------------------------------

def _same_bucket_ids(docs, n=2):
    """First ``n`` doc ids sharing one length bucket (largest such group)."""
    tokz = HashWordTokenizer(vocab_size=512)
    by_bucket = {}
    for d in sorted(docs):
        by_bucket.setdefault(
            bucket_len(len(tokz.encode(docs[d]))), []).append(d)
    ids = max(by_bucket.values(), key=len)
    assert len(ids) >= n, "corpus fixture lost its bucket overlap"
    return ids[:n]


QUERY_A = Cascade([
    Task(TaskConfig("proxy", "sur_1", 0.25), {0: 0.7, 1: 0.7}),
    Task(TaskConfig("proxy", "o_orig", 1.0), {0: 0.75, 1: 0.75}),
])
QUERY_B = Cascade([                        # same stage-0 signature as A,
    Task(TaskConfig("proxy", "sur_1", 0.25), {0: 0.9, 1: 0.9}),
    Task(TaskConfig("proxy", "sur_1", 1.0), {0: 0.8, 1: 0.8}),
])                                         # different thresholds + stage 1


def test_cross_query_packing_merges_launches(engine, docs):
    """Two registered queries whose stages share a (backend, bucket,
    cached_len, op, f_len) signature merge into ONE launch, with
    per-query preds/confs/$ identical to isolated engines."""
    ids = _same_bucket_ids(docs, 2)
    sub = {d: docs[d] for d in ids}
    ref_a = engine.run(QUERY_A, sub)                  # isolated baselines
    ref_b = engine.run(QUERY_B, sub)

    server = CascadeServer(engine.backends, OPS, n_classes=2, batch_size=8)
    server.reset()
    ha, hb = server.register(QUERY_A), server.register(QUERY_B)
    for j, d in enumerate(ids):
        ha.submit(d, sub[d], arrival=float(j))
        hb.submit(d, sub[d], arrival=float(j))
    server.step()
    # ONE launch carried stage-0 documents of BOTH queries
    assert server.stats().batches == 1
    assert server.stats(ha.query_id).batches == 1
    assert server.stats(hb.query_id).batches == 1
    assert server.stats(ha.query_id).stage_docs[0] == len(ids)
    assert server.stats(hb.query_id).stage_docs[0] == len(ids)

    while server.pending():
        server.step()
    res_a, res_b = ha.result(), hb.result()
    for res, ref in ((res_a, ref_a), (res_b, ref_b)):
        assert res.pred == ref.pred
        assert res.exit_stage == ref.exit_stage
        assert res.doc_cost == ref.doc_cost           # exact $ per document
        np.testing.assert_allclose(
            [res.conf[d] for d in ids], [ref.conf[d] for d in ids],
            atol=1e-6)
    # fewer launches than the two isolated sessions needed
    assert server.stats().batches \
        < ref_a.stats.batches + ref_b.stats.batches


def test_server_partitions_results_and_stats(engine, docs):
    """Doc ids are scoped per query; results, stats, and $ stay
    partitioned while the aggregate view counts each launch once."""
    ids = sorted(docs)[:4]
    sub = {d: docs[d] for d in ids}
    server = CascadeServer(engine.backends, OPS, n_classes=2, batch_size=4)
    server.reset()
    ha, hb = server.register(QUERY_A), server.register(QUERY_B)
    futs = [ha.submit(d, sub[d], arrival=float(j))
            for j, d in enumerate(ids)]
    for j, d in enumerate(ids):                       # same ids, no clash
        hb.submit(d, sub[d], arrival=float(j))
    polled_a = {}
    while server.pending():
        server.step()
        polled_a.update(ha.poll())
    res_a, res_b = ha.result(), hb.result()
    assert set(res_a.pred) == set(ids) == set(res_b.pred)
    assert polled_a == {d: (res_a.pred[d], res_a.conf[d],
                            res_a.exit_stage[d]) for d in ids}
    assert all(f.done and f.pred == res_a.pred[f.doc_id] for f in futs)
    assert res_a.cost == pytest.approx(sum(res_a.doc_cost.values()))
    # aggregate = per-query sums, but launches counted once
    agg = server.stats()
    assert sum(agg.stage_docs) == (sum(res_a.stats.stage_docs)
                                   + sum(res_b.stats.stage_docs))
    assert agg.batches < res_a.stats.batches + res_b.stats.batches
    assert server.occupancy() == pytest.approx(
        sum(agg.stage_docs) / agg.batches)
    assert agg.total_cost() == pytest.approx(res_a.cost + res_b.cost)
    # unregister frees one query's bookkeeping, the other survives, and
    # the server-wide launch history / packing metric do not shrink
    server.unregister(ha)
    assert ha.query_id not in server._handles
    assert hb.query_id in server._handles
    assert set(server.result(hb.query_id).pred) == set(ids)
    after = server.stats()
    assert after.batches == agg.batches
    assert sum(after.stage_docs) == sum(agg.stage_docs)
    assert server.occupancy() == pytest.approx(
        sum(agg.stage_docs) / agg.batches)


def test_doc_future_resolves(engine, docs):
    """handle.submit returns a DocFuture whose result() steps the server
    until that document resolves."""
    d0 = sorted(docs)[0]
    server = CascadeServer(engine.backends, OPS, n_classes=2, batch_size=4)
    server.reset()
    h = server.register(QUERY_A)
    fut = h.submit(d0, docs[d0])
    assert not fut.done
    pred, conf, stage = fut.result()
    assert fut.done and fut.pred == pred and fut.conf == conf
    assert fut.cost > 0
    assert h.result().pred == {d0: pred}


def test_engine_is_single_query_server(engine, docs):
    """The compatibility wrapper serves exactly one registered query and
    its results equal the server-API view of that query."""
    sub = {d: docs[d] for d in sorted(docs)[:3]}
    res = engine.run(LADDER, sub)
    assert set(res.doc_cost) == set(sub)
    assert res.cost == pytest.approx(sum(res.doc_cost.values()))
    assert engine.occupancy() == pytest.approx(
        sum(res.stats.stage_docs) / res.stats.batches)


def test_request_queue_head_of_line():
    """next_launch groups by static signature across stages and pops the
    group whose head request is oldest."""
    cfg = {0: ("proxy", "op_a", 0.25), 1: ("proxy", "op_b", 1.0)}
    q = RequestQueue()
    # veteran at stage 1 (oldest), two fresh arrivals at stage 0
    q.push(DocRequest(1, stage=1, arrival=0.0, seq=0,
                      tok_len={"proxy": 30}, cached={"proxy": 8}))
    q.push(DocRequest(2, stage=0, arrival=1.0, seq=1,
                      tok_len={"proxy": 30}))
    q.push(DocRequest(3, stage=0, arrival=2.0, seq=2,
                      tok_len={"proxy": 30}))
    first = q.next_launch(lambda r: cfg[r.stage], batch_size=8)
    assert first.doc_ids == (1,)                      # veteran first
    assert (first.op_id, first.cached_len, first.f_len) == ("op_b", 8, 32)
    second = q.next_launch(lambda r: cfg[r.stage], batch_size=8)
    assert second.doc_ids == (2, 3)                   # arrivals batched
    assert (second.op_id, second.cached_len) == ("op_a", 0)
    assert q.next_launch(lambda r: cfg[r.stage], batch_size=8) is None


def test_request_queue_merges_same_signature_across_stages():
    """Docs at different stage cursors with the same static signature share
    one launch (the stage index is bookkeeping, not a compiled shape)."""
    cfg = {0: ("proxy", "op_a", 1.0), 1: ("proxy", "op_a", 1.0)}
    q = RequestQueue()
    q.push(DocRequest(1, stage=1, arrival=0.0, seq=0, tok_len={"proxy": 20}))
    q.push(DocRequest(2, stage=0, arrival=1.0, seq=1, tok_len={"proxy": 20}))
    launch = q.next_launch(lambda r: cfg[r.stage], batch_size=8)
    assert launch.doc_ids == (1, 2)
    assert launch.stages == (1, 0)


def test_request_queue_merges_across_queries():
    """Requests from DIFFERENT queries (and different stages) share one
    launch when the per-query stage resolver lands them on the same static
    signature — the query id is bookkeeping, not a compiled shape."""
    cfgs = {0: {0: ("proxy", "op_a", 0.25)},
            1: {0: ("proxy", "op_x", 1.0), 1: ("proxy", "op_a", 0.25)}}
    q = RequestQueue()
    q.push(DocRequest(1, stage=0, arrival=0.0, seq=0, query_id=0,
                      tok_len={"proxy": 20}))
    q.push(DocRequest(2, stage=1, arrival=1.0, seq=1, query_id=1,
                      tok_len={"proxy": 20}))
    launch = q.next_launch(lambda r: cfgs[r.query_id][r.stage], batch_size=8)
    assert launch.doc_ids == (1, 2)                   # one mixed launch
    assert launch.op_id == "op_a"


def test_request_queue_largest_ready_group_policy():
    """policy=largest_ready_group picks the fullest group even when a
    smaller group holds the oldest head."""
    cfg = {0: ("proxy", "op_a", 1.0)}
    lone, pair = DocRequest(1, arrival=0.0, seq=0, tok_len={"proxy": 20}), [
        DocRequest(2, arrival=1.0, seq=1, tok_len={"proxy": 100}),
        DocRequest(3, arrival=2.0, seq=2, tok_len={"proxy": 100})]
    q = RequestQueue()
    for r in [lone] + pair:
        q.push(r)
    first = q.next_launch(lambda r: cfg[r.stage], batch_size=8,
                          policy=largest_ready_group)
    assert first.doc_ids == (2, 3)                    # fullest group wins
    second = q.next_launch(lambda r: cfg[r.stage], batch_size=8,
                           policy=largest_ready_group)
    assert second.doc_ids == (1,)
    # the default policy would have served the oldest head first
    for r in [lone] + pair:
        q.push(DocRequest(r.doc_id, arrival=r.arrival, seq=r.seq,
                          tok_len=dict(r.tok_len)))
    assert q.next_launch(lambda r: cfg[r.stage], batch_size=8).doc_ids \
        == (1,)


# ---------------------------------------------------------------------------
# Paged data plane: in-kernel slot lookup vs the gather/scatter stage step
# ---------------------------------------------------------------------------

from repro.models.runtime import Runtime  # noqa: E402

_PAGED_RT = Runtime(attn_impl="pallas_interpret", block_q=16, block_kv=16,
                    remat=False)


def _mk_paged_engine(paged, batch_size=4):
    """Two engines differing ONLY in the data plane: paged vs gather."""
    tokz = HashWordTokenizer(vocab_size=512)

    def be(name, seed):
        cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                          num_layers=2)
        m = LM(resolve(cfg, tp=1), _PAGED_RT)
        return LMBackend(
            name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
            tokenizer=tokz,
            rate_per_token=1.0 if name == "oracle" else 0.06,
            s_alloc=512, paged=paged)

    return CascadeEngine({"proxy": be("proxy", 1), "oracle": be("oracle", 2)},
                         OPS, n_classes=2, batch_size=batch_size)


# word counts straddle two buckets (32, 64); 50 makes the true fraction
# undershoot the padded one (ceil(50 * 0.25) = 13 < 16), so the op suffix
# decodes over positions holding LIVE document KV — the paged undo log's
# hard case
_PAGED_DOCS = {i: " ".join(f"w{i}x{j}" for j in range(n))
               for i, n in enumerate([20, 40, 28, 50, 12])}


def test_paged_engine_bitwise_parity_with_gather():
    """impl='pallas_interpret': the paged stage step (extend scatters the
    chunk in place, op suffix decodes over the arena behind the KV-window
    undo log) produces BITWISE identical preds/confs/per-doc $ to the
    PR-1 gather/scatter step — including an op-switch decode-only stage
    whose true fraction undershoots the cached padded fraction."""
    thr = {0: 2.0, 1: 2.0}       # impossible: every doc walks every stage
    ladder = Cascade([
        Task(TaskConfig("proxy", "sur_1", 0.25), thr),
        Task(TaskConfig("proxy", "o_orig", 0.25), thr),   # decode-only
        Task(TaskConfig("proxy", "o_orig", 0.5), thr),    # re-entry extend
    ])
    results = {}
    for paged in (False, True):
        eng = _mk_paged_engine(paged)
        assert eng.backends["proxy"].uses_paged_kv() == paged
        results[paged] = eng.run(ladder, _PAGED_DOCS)
    gather, paged = results[False], results[True]
    assert gather.pred == paged.pred
    assert gather.conf == paged.conf           # bitwise (python floats)
    assert gather.doc_cost == paged.doc_cost
    assert gather.cost == paged.cost
    assert gather.stats.batches == paged.stats.batches


def test_paged_op_suffix_leaves_arena_bitwise_pristine():
    """A decode-only op launch must not perturb the cached document rows:
    the undo log restores every dirtied position, so a second identical
    launch sees a bitwise-identical arena (same confidences out)."""
    eng = _mk_paged_engine(True)
    be = eng.backends["proxy"]
    d0 = 0
    toks = {d0: np.asarray(be.tokenizer.encode(_PAGED_DOCS[d0]), np.int32)}
    blen = bucket_len(len(toks[d0]))
    op = np.asarray(be.tokenizer.encode(OPS["o_orig"]), np.int32)
    be.run_stage([d0], toks, blen, 0.25, op, 2)       # prefill + op
    bucket_arena = be._arenas[blen]
    before = [np.asarray(l).copy()
              for l in jax.tree.leaves(bucket_arena.states)]
    _, c1, *_ = be.run_stage([d0], toks, blen, 0.25, op, 2)  # decode-only
    after = [np.asarray(l) for l in jax.tree.leaves(bucket_arena.states)]
    slot = be._doc_slot[d0][1]
    for b, a in zip(before, after):
        ax = 1 if b.ndim == 5 else 0          # scan-stacked vs tail leaves
        np.testing.assert_array_equal(np.take(b, [slot], ax),
                                      np.take(a, [slot], ax))
    _, c2, *_ = be.run_stage([d0], toks, blen, 0.25, op, 2)
    np.testing.assert_array_equal(c1, c2)


def test_paged_gather_bytes_accounting():
    """The copy-traffic model behind the benchmark's paged section: the
    gather step moves whole [B, s_alloc] rows per launch, the paged step
    only the op-suffix undo log."""
    eng = _mk_paged_engine(True)
    be = eng.backends["proxy"]
    g = be.gather_bytes_per_launch(64, 4)
    assert g == 4 * be.slot_nbytes(64)
    p = be.paged_copy_bytes_per_launch(64, 4, 6)
    s_alloc = be._s_alloc_for(64)
    assert p == 2 * 4 * 6 * (be.slot_nbytes(64) // s_alloc)
    assert p < g // 8                          # undo log is tiny vs rows
