"""Training substrate: optimizer, driver+checkpoint restart, data failover,
fault-tolerance logic."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer
from repro.config import resolve
from repro.configs import get_reduced
from repro.data.pipeline import DataPipeline, ShardPlan, SyntheticLMTask
from repro.distributed.fault import (HeartbeatMonitor, StragglerPolicy,
                                     plan_remesh)
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, schedule)
from repro.train.train_loop import TrainConfig, TrainDriver, make_train_step


def tiny_model():
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    return LM(resolve(cfg, tp=1), CPU_TEST), cfg


def test_schedule_warmup_and_decay():
    oc = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_frac=0.1)
    assert float(schedule(oc, jnp.asarray(0.0))) == 0.0
    assert float(schedule(oc, jnp.asarray(10.0))) == pytest.approx(1.0)
    assert float(schedule(oc, jnp.asarray(100.0))) == pytest.approx(0.1)


def test_adamw_moves_params_and_clips():
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = {"w": 100.0 * jnp.ones((4, 4)), "b": jnp.ones((4,))}
    st = init_opt_state(params)
    oc = OptimizerConfig(grad_clip=1.0, warmup_steps=0)
    p2, st2, m = adamw_update(oc, params, grads, st)
    assert float(m["grad_norm"]) > 1.0
    assert not np.allclose(np.asarray(p2["w"]), np.asarray(params["w"]))
    assert int(st2.step) == 1


def test_grad_accumulation_matches_full_batch():
    model, cfg = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    task = SyntheticLMTask(vocab_size=512, seq_len=32)
    batch = {k: jnp.asarray(v)
             for k, v in task.batch(0, 0, 0, 8).items()}
    st = init_opt_state(params)
    s1 = make_train_step(model, None, TrainConfig(accum_steps=1))
    s4 = make_train_step(model, None, TrainConfig(accum_steps=4))
    _, _, m1 = s1(params, st, batch)
    _, _, m4 = s4(params, st, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(
        float(m4["grad_norm"]), rel=1e-4)


def test_train_restart_from_checkpoint_is_seamless():
    """Train 6 steps straight == train 3, crash, restore, train 3 more."""
    model, cfg = tiny_model()
    params0 = model.init(jax.random.PRNGKey(1))
    opt0 = init_opt_state(params0)
    step = jax.jit(make_train_step(model, None, TrainConfig()))
    task = SyntheticLMTask(vocab_size=512, seq_len=32)
    plan = ShardPlan(n_shards=2, n_hosts=1)

    def fresh_iter():
        return iter(DataPipeline(task, plan, host=0, batch_per_shard=4))

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=5)
        drv = TrainDriver(step, checkpointer=ck, ckpt_every=3,
                          log_every=100, log_fn=lambda s: None)
        pA, oA, _ = drv.run(params0, opt0, fresh_iter(), 6)

        # crash-and-restore path
        drv2 = TrainDriver(step, checkpointer=Checkpointer(
            d + "_b", keep=5), ckpt_every=3, log_every=100,
            log_fn=lambda s: None)
        it = fresh_iter()
        pB, oB, _ = drv2.run(params0, opt0, it, 3)
        ck2 = drv2.checkpointer
        ck2.wait()
        restored = ck2.restore(3, {"params": params0, "opt": opt0})
        # data pipeline resumes deterministically at step 3
        it2 = fresh_iter()
        for _ in range(3):
            next(it2)
        pC, oC, _ = drv2.run(restored["params"], restored["opt"], it2, 6,
                             start_step=3)
        for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pC)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)


def test_checkpoint_keep_n_and_atomicity():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, {"x": jnp.arange(8.0)})
        ck.wait()
        assert ck.steps() == [3, 4]
        assert all(os.path.exists(os.path.join(d, f"step_{s:08d}.done"))
                   for s in (3, 4))


def test_shard_plan_failover_covers_all_shards():
    plan = ShardPlan(n_shards=8, n_hosts=4, redundancy=2)
    # all shards covered with host 2 dead
    covered = set()
    for h in (0, 1, 3):
        covered.update(plan.shards_for_host(h, dead_hosts=[2]))
    assert covered == set(range(8))


def test_data_determinism_across_hosts():
    task = SyntheticLMTask(vocab_size=128, seq_len=16)
    b1 = task.batch(0, 3, 7, 4)
    b2 = task.batch(0, 3, 7, 4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_heartbeat_dead_and_stragglers():
    t = [0.0]
    mon = HeartbeatMonitor(timeout_s=5.0, straggler_factor=2.0,
                           clock=lambda: t[0])
    for i in range(6):
        mon.beat("a")
        mon.beat("b")
        t[0] += 1.0
        if i % 2 == 0:
            mon.beat("c")     # c beats at half rate sometimes
    t[0] += 10.0
    assert "a" in mon.dead() and "b" in mon.dead()


def test_plan_remesh_degrades_gracefully():
    full = plan_remesh(512)
    assert full.shape == (2, 16, 16)
    one_pod = plan_remesh(511)
    assert one_pod.shape == (16, 16)
    partial = plan_remesh(100)
    assert partial.shape == (4, 16)
    assert partial.batch_scale == pytest.approx(4 / 16)
    assert plan_remesh(0) is None


def test_straggler_policy_migrates_from_slowest():
    pol = StragglerPolicy(slowdown_threshold=1.5)
    migrations = pol.migrations({0: 10.0, 1: 9.0, 2: 1.0})
    assert any(src == 2 for src, _ in migrations)
    assert not pol.migrations({0: 10.0, 1: 9.5, 2: 9.0})
