"""Hypothesis property tests for cascade-execution invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cost_model import CascadeCostModel
from repro.core.tasks import Cascade, Task, TaskConfig, TaskScores, run_cascade


def _random_world(seed, n, k_tasks, n_classes):
    rng = np.random.default_rng(seed)
    oracle = rng.integers(0, n_classes, n)
    tasks, scores = [], {}
    for i in range(k_tasks):
        cfg = TaskConfig("proxy" if i % 2 else "oracle", f"op{i}",
                         float(rng.choice([0.1, 0.25, 0.5, 1.0])))
        pred = rng.integers(0, n_classes, n)
        conf = rng.random(n)
        scores[cfg] = TaskScores(cfg, pred, conf)
        thr = {c: float(rng.random()) for c in range(n_classes)}
        tasks.append(Task(cfg, thr))
    cm = CascadeCostModel(rng.integers(50, 2000, n),
                          {f"op{i}": 20 for i in range(k_tasks)}
                          | {"o_orig": 40})
    return oracle, tasks, scores, cm


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(5, 80),
       k=st.integers(1, 5), c=st.integers(2, 4))
def test_every_doc_gets_exactly_one_exit(seed, n, k, c):
    oracle, tasks, scores, cm = _random_world(seed, n, k, c)
    res = run_cascade(Cascade(tasks), scores, oracle, cm, c)
    assert res.pred.shape == (n,)
    assert np.all((res.exit_stage >= 0) & (res.exit_stage <= k))
    # classified masks partition the non-oracle docs
    total = sum(m.sum() for m in res.per_task_classified)
    assert total + res.oracle_mask().sum() == n


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(5, 80),
       k=st.integers(1, 4), c=st.integers(2, 3))
def test_exit_prediction_consistency(seed, n, k, c):
    """A doc exiting at stage s carries exactly that task's prediction."""
    oracle, tasks, scores, cm = _random_world(seed, n, k, c)
    res = run_cascade(Cascade(tasks), scores, oracle, cm, c)
    for s, task in enumerate(tasks):
        mask = res.exit_stage == s
        if mask.any():
            np.testing.assert_array_equal(
                res.pred[mask], scores[task.config].pred[mask])
    np.testing.assert_array_equal(
        res.pred[res.oracle_mask()], oracle[res.oracle_mask()])


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(5, 60), c=st.integers(2, 3))
def test_raising_thresholds_is_monotone(seed, n, c):
    """Higher thresholds never let MORE docs exit at a stage."""
    oracle, tasks, scores, cm = _random_world(seed, n, 2, c)
    res_lo = run_cascade(Cascade(tasks), scores, oracle, cm, c)
    bumped = [Task(t.config, {cc: v + 0.2 for cc, v in t.thresholds.items()})
              for t in tasks]
    res_hi = run_cascade(Cascade(bumped), scores, oracle, cm, c)
    assert res_hi.oracle_mask().sum() >= res_lo.oracle_mask().sum()
    # and per-doc: anyone who reached the oracle before still does
    assert np.all(res_hi.exit_stage >= res_lo.exit_stage)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(5, 60), c=st.integers(2, 3))
def test_cost_nonnegative_and_bounded_by_worstcase(seed, n, c):
    oracle, tasks, scores, cm = _random_world(seed, n, 3, c)
    res = run_cascade(Cascade(tasks), scores, oracle, cm, c)
    assert np.all(res.cost >= 0)
    # worst case: every stage + the oracle, nothing cached
    zero = np.zeros(n, np.int64)
    worst = sum(cm.task_cost(t.config, zero)[0] for t in tasks) \
        + cm.task_cost(TaskConfig("oracle", "o_orig", 1.0), zero)[0]
    assert np.all(res.cost <= worst + 1e-9)
