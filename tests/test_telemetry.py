"""Serving-plane telemetry: ring-buffer bounds, histogram quantiles,
metric-registry caps, ServeStats merge coverage, counters-level bitwise
inertness, span well-formedness under chaos, launch-segment accounting,
idle-wait measurement, and the Perfetto/Prometheus exporters."""
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import CascadeEngine, CascadeServer, LMBackend
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.scheduler import (MERGE_STRATEGIES, TERMINAL_STATES,
                                     RetryPolicy, ServeStats)
from repro.serving.telemetry import (EV_FAULT, EV_LAUNCH, EV_SUBMIT,
                                     TERMINAL_EVENTS, Histogram,
                                     LaunchRecord, MetricRegistry, Telemetry,
                                     TraceBuffer, chrome_trace,
                                     write_chrome_trace)

OPS = {"o_orig": "does this overturn a lower court decision",
       "sur_1": "is a lower court mentioned"}
THR = {0: 0.7, 1: 0.7}
CASCADE = Cascade([
    Task(TaskConfig("proxy", "sur_1", 0.25), THR),
    Task(TaskConfig("proxy", "o_orig", 1.0), THR),
])


def _mk_model(seed):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    m = LM(resolve(cfg, tp=1), CPU_TEST)
    return m, m.init(jax.random.PRNGKey(seed))


@pytest.fixture(scope="module")
def models():
    return {"proxy": _mk_model(1), "oracle": _mk_model(2)}


@pytest.fixture(scope="module")
def docs():
    return {d.doc_id: d.text
            for d in generate_corpus(8, avg_lines=10, seed=7)}


def mk_backends(models, tokz=None):
    tokz = tokz or HashWordTokenizer(vocab_size=512)
    return {name: LMBackend(
        name=name, model=m, params=p, tokenizer=tokz,
        rate_per_token=1.0 if name == "oracle" else 0.06, s_alloc=512)
        for name, (m, p) in models.items()}


def mk_server(models, **kw):
    kw.setdefault("retry", RetryPolicy(max_retries=2, backoff_base=0.0))
    return CascadeServer(mk_backends(models), OPS, n_classes=2,
                         batch_size=4, **kw)


# ------------------------------------------------------------ trace buffer

def test_trace_buffer_drops_oldest_and_counts():
    buf = TraceBuffer(4)
    for i in range(4):
        buf.append(i)
    assert len(buf) == 4 and buf.dropped == 0 and buf.total == 4
    assert buf.items() == [0, 1, 2, 3]
    buf.append(4)                       # overwrites 0
    buf.append(5)                       # overwrites 1
    assert len(buf) == 4
    assert buf.dropped == 2
    assert buf.total == 6
    assert buf.items() == [2, 3, 4, 5]  # oldest-first surviving tail
    buf.clear()
    assert len(buf) == 0 and buf.dropped == 0 and buf.items() == []


def test_trace_buffer_rejects_zero_capacity():
    with pytest.raises(AssertionError):
        TraceBuffer(0)


# -------------------------------------------------------------- histogram

def test_histogram_quantiles_without_samples():
    h = Histogram()
    for v in (1e-4,) * 50 + (1e-2,) * 49 + (0.5,):
        h.observe(v)
    assert h.count == 100
    assert h.sum == pytest.approx(50 * 1e-4 + 49 * 1e-2 + 0.5)
    # bucket resolution is a factor of 2: quantiles land within the
    # observed value's bucket
    assert h.p50() <= 2e-4 * 2
    assert 1e-2 / 2 <= h.p99() <= 1e-2 * 2
    assert h.quantile(1.0) <= h.max_seen
    assert Histogram().p50() == 0.0


def test_histogram_overflow_bucket_uses_max_seen():
    h = Histogram(bounds=(1.0, math.inf))
    h.observe(100.0)
    assert h.quantile(0.99) <= 100.0
    assert h.max_seen == 100.0


# --------------------------------------------------------- metric registry

def test_registry_labels_and_snapshot():
    reg = MetricRegistry()
    reg.counter("hits", backend="proxy").inc()
    reg.counter("hits", backend="proxy").inc(2.0)
    reg.counter("hits", backend="oracle").inc()
    reg.gauge("depth").set(7)
    snap = reg.snapshot()
    assert snap["hits{backend=proxy}"] == 3.0
    assert snap["hits{backend=oracle}"] == 1.0
    assert snap["depth"] == 7.0
    assert reg.series_count() == 3


def test_registry_series_cap_overflows_to_sink():
    reg = MetricRegistry(max_series=2)
    reg.counter("c", k="a").inc()
    reg.counter("c", k="b").inc()
    sink = reg.counter("c", k="overflow_1")
    reg.counter("c", k="overflow_2").inc()
    assert reg.series_count() == 2
    assert reg.dropped_series == 2
    assert sink is reg._overflow["counter"]


def test_registry_kind_collision_asserts():
    reg = MetricRegistry()
    reg.counter("m")
    with pytest.raises(AssertionError):
        reg.gauge("m")


def test_prometheus_exposition_format():
    reg = MetricRegistry()
    reg.counter("serve_launches_total", backend="proxy").inc(3)
    reg.histogram("serve_wall_seconds").observe(0.5)
    text = reg.to_prometheus()
    assert "# TYPE serve_launches_total counter" in text
    assert 'serve_launches_total{backend="proxy"} 3.0' in text
    assert 'le="+Inf"' in text
    assert "serve_wall_seconds_count 1" in text
    # bucket counts are cumulative: the +Inf bucket equals the count
    inf_line = [ln for ln in text.splitlines() if 'le="+Inf"' in ln][-1]
    assert inf_line.endswith(" 1")


# ----------------------------------------------- ServeStats merge coverage

def test_merge_covers_every_numeric_field():
    """Satellite 1: ``merge_from`` walks ``dataclasses.fields``, so EVERY
    field must carry (or default to) a known strategy, and each strategy
    must actually propagate — a new counter can never silently drop."""
    src = ServeStats()
    src.record(0, 2, 10, 20, 0.5)
    src.record(1, 1, 5, 5, 0.25)
    src.latencies.extend([0.1, 0.2])
    for f in dataclasses.fields(ServeStats):
        kind = f.metadata.get("merge", "sum")
        assert kind in MERGE_STRATEGIES, f.name
        if kind in ("sum", "max") and not getattr(src, f.name):
            setattr(src, f.name, 3)
    src.batches = 99                    # shared: must NOT merge

    dst = ServeStats()
    dst.merge_from(src)
    for f in dataclasses.fields(ServeStats):
        kind = f.metadata.get("merge", "sum")
        got = getattr(dst, f.name)
        if kind == "shared":
            assert got == 0, f"{f.name} (shared) leaked through merge"
        elif kind == "stage":
            assert got == getattr(src, f.name), f.name
        else:
            assert got == getattr(src, f.name), f.name

    dst.merge_from(src)                 # second fold: sums double, max holds
    assert dst.evictions == 2 * src.evictions
    assert dst.retries == 2 * src.retries
    assert dst.arena_bytes_peak == src.arena_bytes_peak
    assert dst.stage_docs == [2 * v for v in src.stage_docs]
    assert dst.latencies == src.latencies * 2
    assert dst.batches == 0


def test_unannotated_field_defaults_to_sum():
    """A field added without ``_stat`` metadata merges as 'sum' instead of
    being skipped."""
    plain = dataclasses.make_dataclass(
        "PlainStats", [("new_counter", int, 0)], bases=(ServeStats,))
    a, b = plain(), plain()
    b.new_counter = 5
    a.merge_from(b)
    assert a.new_counter == 5


# ------------------------------------------- bitwise inertness of counters

def test_counters_level_is_bitwise_inert(models, docs):
    """Default-on ``level="counters"`` must not change preds, confs,
    per-document $, or the arena device state vs ``level="off"``."""
    outs, leaves = {}, {}
    for level in ("off", "counters"):
        eng = CascadeEngine(mk_backends(models), OPS, n_classes=2,
                            batch_size=4)
        eng.telemetry.level = level
        outs[level] = eng.run(CASCADE, docs)
        leaves[level] = [
            np.asarray(leaf)
            for name in sorted(eng.backends)
            for bucket in sorted(eng.backends[name]._arenas)
            for leaf in jax.tree_util.tree_leaves(
                eng.backends[name]._arenas[bucket].states)]
    a, b = outs["off"], outs["counters"]
    assert a.pred == b.pred
    assert a.conf == b.conf
    assert a.doc_cost == b.doc_cost
    assert len(leaves["off"]) == len(leaves["counters"])
    for la, lb in zip(leaves["off"], leaves["counters"]):
        assert np.array_equal(la, lb)


def test_level_off_records_nothing(models, docs):
    eng = CascadeEngine(mk_backends(models), OPS, n_classes=2, batch_size=4)
    eng.telemetry.level = "off"
    eng.run(CASCADE, docs)
    snap = eng.telemetry.snapshot()
    assert snap["counters"]["launch_records"] == 0
    assert snap["counters"]["metric_series"] == 0
    assert snap["counters"]["events_total"] == 0


# ------------------------------------------------- spans + launch timeline

def _chaos_drain(models, level="trace"):
    srv = mk_server(models)
    srv.telemetry.level = level
    inj = FaultInjector(FaultPlan(seed=23, launch_failure_p=0.3, nan_p=0.2,
                                  latency_spike_p=0.1, spike_s=1e-4,
                                  arena_loss_at=3)).install(srv)
    docs = {d.doc_id: d.text
            for d in generate_corpus(8, avg_lines=10, seed=7)}
    handles = [srv.register(CASCADE), srv.register(CASCADE)]
    futs = {}
    for k, h in enumerate(handles):
        for j, d in enumerate(sorted(docs)[k::2]):
            futs[(h.query_id, d)] = h.submit(d, docs[d], arrival=float(j))
    srv.drain()
    return srv, inj, futs


def test_spans_well_formed_under_chaos(models):
    srv, inj, futs = _chaos_drain(models)
    assert all(f.done and f.status in TERMINAL_STATES
               for f in futs.values())
    report = srv.telemetry.validate_spans(require_terminal=True)
    assert report["ok"], report["violations"]
    assert report["checked"] == len(futs)
    spans = srv.telemetry.spans()
    assert len(spans) == len(futs)
    kinds = {e[2] for evs in spans.values() for e in evs}
    assert EV_SUBMIT in kinds and EV_LAUNCH in kinds
    if sum(inj.counts.values()) - inj.counts["arena_losses"] > 0:
        assert EV_FAULT in kinds       # injections land in doc spans
    # terminal event kinds match the scheduler's terminal statuses
    for (qid, d), f in futs.items():
        rid = srv._ids[(qid, d)]
        assert spans[rid][-1][2] == f.status
        assert spans[rid][-1][2] in TERMINAL_EVENTS


def test_launch_segments_sum_to_wall(models):
    srv, _, _ = _chaos_drain(models, level="counters")
    tm = srv.telemetry
    recs = [r for r in tm.launches.items() if r.ok]
    assert recs, "chaos drain recorded no launches"
    for r in recs:
        total = r.sched_s + r.host_s + r.dispatch_s + r.device_s
        assert total == pytest.approx(r.wall_s, rel=0.05), r
        assert r.width >= r.batch > 0
        assert 0.0 < r.occupancy <= 1.0
    assert tm.segments_sum_ok()
    snap = srv.telemetry_snapshot()
    assert snap["counters"]["segments_sum_ok"] is True
    assert snap["counters"]["launch_records"] == tm.launch_total
    assert snap["server"]["launches"] == srv._launches
    tl = snap["timeline"]
    assert tl["wall_s"] == pytest.approx(
        tl["sched_s"] + tl["host_s"] + tl["dispatch_s"] + tl["device_s"],
        rel=0.05)
    assert tl["host_overhead_s"] == tl["host_s"] + tl["dispatch_s"]


def test_trace_ring_overflow_skips_truncated_spans(models):
    srv = mk_server(models)
    srv.telemetry.level = "trace"
    srv.telemetry.events = TraceBuffer(8)        # tiny ring: force drops
    docs = {d.doc_id: d.text
            for d in generate_corpus(6, avg_lines=10, seed=7)}
    h = srv.register(CASCADE)
    for j, d in enumerate(sorted(docs)):
        h.submit(d, docs[d], arrival=float(j))
    srv.drain()
    tm = srv.telemetry
    assert tm.events.dropped > 0
    assert len(tm.events) == 8
    assert tm.events.total == tm.events.dropped + len(tm.events)
    report = tm.validate_spans(require_terminal=True)
    assert report["ok"], report["violations"]    # truncated spans skipped
    assert report["checked"] < len(docs)


def test_counters_level_skips_span_events(models, docs):
    srv = mk_server(models)                      # default level="counters"
    assert srv.telemetry.enabled and not srv.telemetry.tracing
    h = srv.register(CASCADE)
    for j, d in enumerate(sorted(docs)[:4]):
        h.submit(d, docs[d], arrival=float(j))
    srv.drain()
    tm = srv.telemetry
    assert tm.events.total == 0                  # no span events
    assert tm.launch_total > 0                   # timeline still recorded
    snap = tm.registry.snapshot()
    assert any(k.startswith("serve_launches_total") for k in snap)
    assert any(k.startswith("serve_docs_terminal_total") for k in snap)


def test_reset_clears_telemetry(models, docs):
    srv = mk_server(models)
    h = srv.register(CASCADE)
    h.submit(0, docs[0])
    srv.drain()
    assert srv.telemetry.launch_total > 0
    srv.reset()
    assert srv.telemetry.launch_total == 0
    assert srv.telemetry.registry.series_count() == 0


# ------------------------------------------------------------- idle wait

def test_idle_wait_sleeps_eligible_interval_and_is_measured(models, docs):
    srv = mk_server(models, retry=RetryPolicy(max_retries=3,
                                              backoff_base=0.02))
    # seed 8 fails the very first launch: the retried doc backs off and
    # drain must sleep the eligible interval out (measured)
    inj = FaultInjector(FaultPlan(seed=8, launch_failure_p=0.5))
    inj.install(srv)
    h = srv.register(CASCADE)
    for j, d in enumerate(sorted(docs)[:4]):
        h.submit(d, docs[d], arrival=float(j))
    srv.drain()
    assert inj.counts["launch_failures"] > 0
    tm = srv.telemetry
    assert tm.idle_wait_s > 0.0                  # drain slept, measured
    assert tm.idle_wait_s == pytest.approx(
        tm.snapshot()["timeline"]["idle_wait_s"])
    assert tm.registry.snapshot()[
        "serve_idle_wait_seconds_total"] == pytest.approx(tm.idle_wait_s)


def test_idle_wait_cap_bounds_single_sleep(models):
    srv = mk_server(models, idle_wait_cap=0.01,
                    retry=RetryPolicy(max_retries=1, backoff_base=10.0))
    # no eligible work, one request backing off far in the future
    h = srv.register(CASCADE)
    f = h.submit(0, "some words here", arrival=0.0)
    req = srv._requests[srv._ids[(h.query_id, 0)]]
    req.not_before = __import__("time").perf_counter() + 30.0
    import time
    t0 = time.perf_counter()
    srv._idle_wait()
    assert time.perf_counter() - t0 < 1.0        # capped, not 30 s
    assert 0.0 < srv.telemetry.idle_wait_s < 1.0
    req.not_before = 0.0
    srv.drain()
    assert f.done


# -------------------------------------------------------------- exporters

def test_chrome_trace_layout(models, tmp_path):
    srv, _, futs = _chaos_drain(models)
    path = tmp_path / "trace.json"
    write_chrome_trace(srv.telemetry, str(path))
    trace = json.loads(path.read_text())
    evs = trace["traceEvents"]
    assert trace["displayTimeUnit"] == "ms"
    procs = {e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"}
    assert {"backend:proxy"} <= procs
    assert any(p.startswith("query:") for p in procs)
    slices = [e for e in evs if e["ph"] == "X"]
    launches = [e for e in slices if e.get("cat") == "launch"]
    spans = [e for e in slices if e.get("cat") == "span"]
    segs = [e for e in slices if e.get("cat") == "segment"]
    assert launches and spans and segs
    assert len(spans) == len(futs)               # one slice per document
    for e in launches:
        assert {"launch", "batch", "width", "occupancy",
                "copy_bytes"} <= set(e["args"])
        assert e["ts"] >= 0 and e["dur"] >= 0
    # per-launch segment slices tile the launch slice
    seg_names = {e["name"] for e in segs}
    assert seg_names == {"sched", "host", "dispatch", "device"}
    insts = [e for e in evs if e["ph"] == "i"]
    assert any(e["name"] == "submit" for e in insts)
    assert any(e["name"] in TERMINAL_EVENTS for e in insts)


def test_chrome_trace_empty_telemetry():
    trace = chrome_trace(Telemetry(level="trace"))
    assert trace["traceEvents"] == []


def test_launch_record_derived_properties():
    r = LaunchRecord(index=0, ts_start=0.0, batch=3, width=4,
                     cached_len=64, f_len=64)
    assert r.occupancy == 0.75
    assert r.decode_only
    r2 = LaunchRecord(index=1, ts_start=0.0, cached_len=0, f_len=64)
    assert not r2.decode_only and r2.occupancy == 0.0


def test_decode_launch_roofline_helpers():
    from repro.launch.roofline import (HBM_BW, bandwidth_utilization,
                                       decode_launch_bytes)
    b = decode_launch_bytes(params_bytes=1e9, kv_bytes_per_step=1e6, steps=2)
    assert b == pytest.approx(2 * (1e9 + 1e6))
    assert bandwidth_utilization(HBM_BW, 1.0) == pytest.approx(1.0)
    assert bandwidth_utilization(1e9, 0.0) == 0.0
