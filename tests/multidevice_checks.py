"""Multi-device correctness checks (run under 8 forced host devices).

Invoked by tests/test_multidevice.py in a subprocess so the main pytest
process keeps its single real CPU device.  Prints one "PASS <name>" line
per check; any exception fails the subprocess.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.distributed.collectives import (compressed_psum,            # noqa: E402
                                           matmul_ag_overlap,
                                           ring_all_gather,
                                           ring_reduce_scatter,
                                           sp_decode_attention)
from repro.kernels import ref   # noqa: E402

assert len(jax.devices()) == 8

mesh = jax.make_mesh((4, 2), ("data", "model"))


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: jax.shard_map (>=0.5, check_vma kw) vs
    jax.experimental.shard_map (0.4.x, check_rep kw)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def check_ring_all_gather():
    x = jnp.arange(32.0).reshape(8, 4)

    def body(xl):
        return ring_all_gather(xl, "data", axis=0)

    out = shard_map(body, mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None))(x)
    # every shard holds the full concat -> output tiled 4x along axis 0
    out_np = np.asarray(out)
    np.testing.assert_allclose(out_np[:8], np.asarray(x))
    print("PASS ring_all_gather")


def check_ring_reduce_scatter():
    x = jnp.arange(64.0).reshape(8, 8)

    def body(xl):
        return ring_reduce_scatter(xl, "model", axis=1)

    out = shard_map(body, mesh=mesh, in_specs=P(None, "model"),
                        out_specs=P(None, "model"))(x)
    # reference: reduce over model shards then scatter along axis 1
    a, b = np.asarray(x)[:, :4], np.asarray(x)[:, 4:]
    ref_rs = a + b              # each half reduces to the same sum
    out_np = np.asarray(out)
    # shard 0 holds chunk 0 of the sum, shard 1 chunk 1
    np.testing.assert_allclose(out_np[:, :2], ref_rs[:, :2])
    np.testing.assert_allclose(out_np[:, 2:4]. T.T, ref_rs[:, 2:4])
    print("PASS ring_reduce_scatter")


def check_sp_decode_attention():
    B, H, KV, S, Dh = 1, 4, 2, 64, 8
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, Dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, Dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, Dh))
    kv_len = jnp.asarray([40], jnp.int32)
    out = sp_decode_attention(q, k, v, kv_len, mesh=mesh,
                              sm_scale=Dh ** -0.5, axis="data")
    expected = ref.decode_reference(q, k, v, kv_len=kv_len,
                                    sm_scale=Dh ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-5, rtol=1e-4)
    print("PASS sp_decode_attention")


def check_compressed_psum():
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 16))

    def body(xl):
        red, err = compressed_psum(xl, "data")
        return red

    out = shard_map(body, mesh=mesh, in_specs=P("data", None),
                        out_specs=P("data", None))(x)
    # reference: mean over the 4 data shards
    ref_mean = np.asarray(x).reshape(4, 2, 16).mean(axis=0)
    out_np = np.asarray(out)[:2]
    np.testing.assert_allclose(out_np, ref_mean, atol=0.05)
    print("PASS compressed_psum")


def check_matmul_ag_overlap():
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 8, 6))
    w = jax.random.normal(jax.random.PRNGKey(5), (6, 10))

    def body(xl, w):
        return matmul_ag_overlap(xl, w, "data")

    out = shard_map(body, mesh=mesh, in_specs=(P(None, "data", None), P()),
                        out_specs=P(None, None, None))(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                               atol=1e-4, rtol=1e-4)
    print("PASS matmul_ag_overlap")


def check_moe_ep_matches_tp_dense():
    from repro.models.moe import init_moe, moe_apply_ep_a2a, \
        moe_apply_tp_dense
    mesh4 = jax.make_mesh((4, 2), ("data", "model"))
    d, f, E = 16, 32, 4
    params = init_moe(jax.random.PRNGKey(6), d, f, E, jnp.float32)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(7), (8, 4, d))
    y_dense, aux_d = moe_apply_tp_dense(params, x, top_k=2,
                                        capacity_factor=8.0)
    with mesh4:
        y_ep, aux_e = moe_apply_ep_a2a(
            params, x, top_k=2, capacity_factor=8.0, mesh=mesh4,
            dp_spec=P("data", None, None))
    # with generous capacity both drop nothing BUT dispatch order differs
    # between the global (dense) and per-shard (EP) capacity pools — compare
    # where both routed (no drops at cf=8 with T>=E*cap... assert close)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-3)
    print("PASS moe_ep_matches_tp_dense")


def check_sharded_train_step():
    """One sharded train step on a 4x2 mesh == unsharded reference."""
    from repro.config import resolve
    from repro.configs import get_reduced
    from repro.models.model import LM
    from repro.models.runtime import Runtime
    from repro.train.optimizer import init_opt_state
    from repro.train.train_loop import TrainConfig, make_train_step
    from repro.distributed.sharding import tree_pspecs

    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2, num_heads=4, num_kv_heads=2)
    rcfg = resolve(cfg, tp=2)
    m_ref = LM(rcfg, Runtime(attn_impl="naive", remat=False))
    params = m_ref.init(jax.random.PRNGKey(8))
    opt = init_opt_state(params)
    from repro.data.pipeline import SyntheticLMTask
    batch = {k: jnp.asarray(v) for k, v in
             SyntheticLMTask(512, 32).batch(0, 0, 0, 8).items()}
    _, _, met_ref = make_train_step(m_ref, None, TrainConfig())(
        params, opt, batch)

    m_sh = LM(rcfg, Runtime(attn_impl="naive", remat=False, mesh=mesh))
    pspecs = tree_pspecs(m_sh.param_specs(), mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.tree.map(jax.device_put, params, pshard)
    step = jax.jit(make_train_step(m_sh, mesh, TrainConfig()))
    with mesh:
        _, _, met_sh = step(params_sh, opt, batch)
    np.testing.assert_allclose(float(met_sh["loss"]), float(met_ref["loss"]),
                               atol=1e-4, rtol=1e-4)
    print("PASS sharded_train_step")


def check_checkpoint_reshard():
    """Save under one sharding, restore under another mesh layout."""
    import tempfile
    from repro.checkpoint.checkpoint import Checkpointer
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 16))
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, {"x": xs})
        ck.wait()
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        tgt = {"x": NamedSharding(mesh2, P("model", None))}
        restored = ck.restore(1, {"x": x}, shardings=tgt)
        np.testing.assert_allclose(np.asarray(restored["x"]), np.asarray(x))
        assert restored["x"].sharding.spec == P("model", None)
    print("PASS checkpoint_reshard")


def check_elastic_remesh_training():
    """Full elastic-scaling path: train on a 2x2x2 'multi-pod' mesh,
    checkpoint, kill a pod, restore onto the surviving 2x2 mesh with
    resharded state + data-pipeline failover, keep training."""
    import tempfile
    from repro.config import resolve
    from repro.configs import get_reduced
    from repro.checkpoint.checkpoint import Checkpointer
    from repro.data.pipeline import DataPipeline, ShardPlan, SyntheticLMTask
    from repro.distributed.fault import plan_remesh
    from repro.distributed.sharding import tree_pspecs
    from repro.models.model import LM
    from repro.models.runtime import Runtime
    from repro.train.optimizer import init_opt_state
    from repro.train.train_loop import TrainConfig, make_train_step

    big = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2, num_heads=4, num_kv_heads=2)
    rcfg = resolve(cfg, tp=2)

    def sharded(params, mesh):
        ps = tree_pspecs(LM(rcfg, Runtime(mesh=mesh)).param_specs(), mesh)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), ps,
                          is_leaf=lambda x: isinstance(x, P))
        return jax.tree.map(jax.device_put, params, sh)

    m_big = LM(rcfg, Runtime(attn_impl="naive", remat=False, mesh=big))
    params = sharded(m_big.init(jax.random.PRNGKey(0)), big)
    opt = init_opt_state(params)
    task = SyntheticLMTask(512, 32)
    plan = ShardPlan(n_shards=4, n_hosts=2)
    pipe = DataPipeline(task, plan, host=0, batch_per_shard=4)
    step_big = jax.jit(make_train_step(m_big, big, TrainConfig()))
    with big:
        for _ in range(2):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            params, opt, met = step_big(params, opt, batch)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(2, {"params": params, "opt": opt})
        ck.wait()
        # pod failure: 4 chips survive -> remesh plan
        rp = plan_remesh(4, old_dp=4)
        assert rp is not None and rp.chips == 4
        small = jax.make_mesh((2, 2), ("data", "model"))
        m_small = LM(rcfg, Runtime(attn_impl="naive", remat=False,
                                   mesh=small))
        ps = tree_pspecs(m_small.param_specs(), small)
        sh = {"params": jax.tree.map(
            lambda s: NamedSharding(small, s), ps,
            is_leaf=lambda x: isinstance(x, P)), "opt": None}
        restored = ck.restore(2, {"params": params, "opt": opt},
                              shardings=None)
        params2 = sharded(restored["params"], small)
        pipe2 = pipe.with_failures([1])     # shard failover
        step_small = jax.jit(make_train_step(m_small, small, TrainConfig()))
        with small:
            batch = {k: jnp.asarray(v) for k, v in next(pipe2).items()}
            p3, o3, met3 = step_small(params2, restored["opt"], batch)
        assert np.isfinite(float(met3["loss"]))
    print("PASS elastic_remesh_training")


if __name__ == "__main__":
    check_ring_all_gather()
    check_ring_reduce_scatter()
    check_sp_decode_attention()
    check_compressed_psum()
    check_matmul_ag_overlap()
    check_moe_ep_matches_tp_dense()
    check_sharded_train_step()
    check_checkpoint_reshard()
    check_elastic_remesh_training()
    print("ALL_MULTIDEVICE_OK")
