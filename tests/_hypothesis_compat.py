"""Optional-hypothesis shim.

The property tests use ``hypothesis`` when it is installed; environments
without it (minimal CI images, the kernel-toolchain container) must still
collect and run every example-based test in the same modules.  Importing
``given/settings/st`` from here yields the real decorators when available
and skip-marking stand-ins otherwise, so property tests report as skipped
instead of breaking collection for the whole module.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on minimal images
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Placeholder accepted anywhere a hypothesis strategy is built."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    class _StrategiesModule:
        def __getattr__(self, name):
            return _Strategy()

    st = _StrategiesModule()

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
