"""Core algorithm tests: Alg 2 thresholds, Alg 4 greedy + MSSC reduction,
WSR estimator validity/power, Alg 3/5 guarantee, cost model properties.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.assembly import (brute_force_mssc, greedy_assembly,
                                 greedy_mssc, mssc_instance_to_scores)
from repro.core.cost_model import CascadeCostModel, OptimizationCost, \
    break_even_docs
from repro.core.estimator import hoeffding_certify, wsr_certify, wsr_wealth
from repro.core.adjust import adjust_thresholds, build_shift_lists, \
    thresholds_at_shift
from repro.core.tasks import (Cascade, Task, TaskConfig, TaskScores,
                              run_cascade)
from repro.core.thresholds import find_task_thresholds, select_class_threshold


# ---------------------------------------------------------------- Alg 2 ----

def test_select_class_threshold_meets_alpha():
    rng = np.random.default_rng(0)
    conf = rng.random(200)
    correct = rng.random(200) < conf          # higher conf -> more correct
    t = select_class_threshold(conf, correct, alpha=0.8)
    assert t is not None
    kept = conf >= t
    assert correct[kept].mean() >= 0.8


def test_select_class_threshold_is_lowest():
    conf = np.asarray([0.2, 0.4, 0.6, 0.8, 0.9, 0.95, 0.99, 0.995])
    correct = np.asarray([0, 0, 1, 1, 1, 1, 1, 1], bool)
    t = select_class_threshold(conf, correct, alpha=0.9)
    # suffix from 0.4 has acc 6/7 < .9; from 0.6 acc 6/6 = 1.0 -> t = 0.6
    assert t == pytest.approx(0.6)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(20, 300), alpha=st.floats(0.5, 0.95),
       seed=st.integers(0, 100))
def test_threshold_property_kept_set_accuracy(n, alpha, seed):
    rng = np.random.default_rng(seed)
    conf = rng.random(n)
    correct = rng.random(n) < np.clip(conf + 0.2, 0, 1)
    t = select_class_threshold(conf, correct, alpha)
    if t is not None:
        kept = conf >= t
        assert correct[kept].mean() >= alpha


def test_find_task_thresholds_discards_weak_tasks():
    rng = np.random.default_rng(1)
    n = 100
    oracle = rng.integers(0, 2, n)
    # random predictions, uninformative confidence -> should be discarded
    s = TaskScores(TaskConfig("proxy", "bad", 1.0),
                   rng.integers(0, 2, n), rng.random(n) * 0.5)
    task = find_task_thresholds(s, oracle, 2, alpha=0.95, g=0.5)
    assert task is None


# ------------------------------------------------------- Alg 4 + MSSC ----

def test_mssc_reduction_costs_match():
    universe = list(range(6))
    sets = [{0, 1, 2}, {2, 3}, {3, 4, 5}, {0, 5}]
    tasks, scores, oracle_pred, cm = mssc_instance_to_scores(universe, sets)
    # cascade cost of an ordering == MSSC objective of that ordering
    order = [0, 2, 1, 3]
    casc = Cascade([tasks[i] for i in order])
    res = run_cascade(casc, scores, oracle_pred, cm, 2)
    # manual MSSC objective
    uncovered = set(universe)
    cost = 0
    for pos, si in enumerate(order, start=1):
        gained = sets[si] & uncovered
        cost += pos * len(gained)
        uncovered -= gained
    assert res.total_cost() == pytest.approx(cost)


def test_greedy_mssc_within_4x_of_optimum():
    rng = np.random.default_rng(3)
    for trial in range(10):
        universe = set(range(8))
        sets = [set(rng.choice(8, size=rng.integers(1, 5), replace=False))
                for _ in range(5)]
        if set().union(*sets) != universe:
            sets.append(universe - set().union(*sets) or {0})
        _, g_cost = greedy_mssc(universe, sets)
        opt = brute_force_mssc(universe, sets)
        if opt > 0:
            assert g_cost <= 4 * opt


def test_greedy_assembly_never_exceeds_oracle_cost():
    universe = list(range(10))
    sets = [{0, 1, 2, 3}, {4, 5}, {6, 7, 8, 9}, {1, 9}]
    tasks, scores, oracle_pred, cm = mssc_instance_to_scores(universe, sets)
    casc, trace = greedy_assembly(tasks, scores, oracle_pred, cm, 2,
                                  alpha=0.0)
    res = run_cascade(casc, scores, oracle_pred, cm, 2)
    oracle_only = run_cascade(Cascade([]), scores, oracle_pred, cm, 2)
    assert res.total_cost() <= oracle_only.total_cost() + 1e-9


# --------------------------------------------------------------- WSR ----

def test_wsr_false_positive_rate_bounded():
    """Under H0 (true acc < target) certify rate must be <= delta."""
    rng = np.random.default_rng(4)
    target, delta, n = 0.9, 0.25, 120
    fp = sum(wsr_certify((rng.random(n) < 0.88).astype(float), target, delta)
             for _ in range(300))
    assert fp / 300 <= delta + 0.05       # small simulation slack


def test_wsr_certifies_clearly_good_cascades():
    rng = np.random.default_rng(5)
    ok = sum(wsr_certify((rng.random(120) < 0.98).astype(float), 0.9, 0.25)
             for _ in range(100))
    assert ok / 100 >= 0.95


def test_wsr_tighter_than_hoeffding():
    rng = np.random.default_rng(6)
    w = h = 0
    for _ in range(50):
        x = (rng.random(100) < 0.97).astype(float)
        w += wsr_certify(x, 0.9, 0.25)
        h += hoeffding_certify(x, 0.9, 0.25)
    assert w > h + 10              # WSR is strictly more powerful
    assert w >= 0.6 * 50           # and certifies most draws at n=100


def test_wsr_wealth_nonnegative():
    rng = np.random.default_rng(7)
    for _ in range(20):
        x = (rng.random(50) < rng.random()).astype(float)
        w = wsr_wealth(x, 0.9, 0.25)
        assert np.all(w > 0)


# ------------------------------------------------------------ Alg 3/5 ----

def _toy_backend(n, seed, acc=0.93):
    rng = np.random.default_rng(seed)
    oracle = rng.integers(0, 2, n)
    p_doc = np.where(rng.random(n) < 0.8, 0.99, 0.55)
    pred = np.where(rng.random(n) < p_doc, oracle, 1 - oracle)
    conf = np.clip(p_doc + 0.1 * rng.standard_normal(n), 0.5, 1.0)
    cfg = TaskConfig("proxy", "o_orig", 1.0)
    return cfg, TaskScores(cfg, pred, conf), oracle


def test_threshold_shift_is_monotone_conservative():
    cfg, scores, oracle = _toy_backend(200, 8)
    task = Task(cfg, {0: 0.6, 1: 0.6})
    casc = Cascade([task])
    lists = build_shift_lists(casc, {cfg: scores}, 2, s_max=5)
    prev = None
    for s in range(6):
        th = thresholds_at_shift(lists, s)[0]
        if prev is not None:
            assert th[0] >= prev[0] - 1e-12 or np.isinf(th[0])
        prev = th
    # s=0 is the original threshold
    assert thresholds_at_shift(lists, 0)[0][0] == pytest.approx(0.6)


def test_adjust_guarantee_failure_rate():
    """Pr[final accuracy < alpha] <= delta over repeated runs."""
    alpha, delta = 0.85, 0.25
    failures = runs = 0
    for seed in range(40):
        cfg, scores, oracle = _toy_backend(300, 100 + seed)
        n = len(oracle)
        tr, va = np.arange(n // 2), np.arange(n // 2, n)
        tr_scores = {cfg: TaskScores(cfg, scores.pred[tr], scores.conf[tr])}
        va_scores = {cfg: TaskScores(cfg, scores.pred[va], scores.conf[va])}
        cm = CascadeCostModel(np.full(len(va), 100), {"o_orig": 10})
        task = Task(cfg, {0: 0.6, 1: 0.6})
        res = adjust_thresholds(
            Cascade([task]), tr_scores, va_scores, oracle[va], cm, 2,
            alpha, delta, rng=np.random.default_rng(seed))
        if res.cascade is None:
            continue                      # oracle-only is always safe
        runs += 1
        # fresh i.i.d. "deployment" sample
        cfg2, s2, o2 = _toy_backend(500, 5000 + seed)
        out = run_cascade(res.cascade, {cfg: s2},
                          o2, CascadeCostModel(np.full(500, 100),
                                               {"o_orig": 10}), 2)
        if out.accuracy(o2) < alpha:
            failures += 1
    assert runs > 10
    assert failures / runs <= delta + 0.1


# --------------------------------------------------------- cost model ----

@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 50), f1=st.sampled_from([0.1, 0.25, 0.5, 1.0]),
       f2=st.sampled_from([0.1, 0.25, 0.5, 1.0]), seed=st.integers(0, 20))
def test_prefix_caching_saves(n, f1, f2, seed):
    """Same-model two-stage cost <= sum of independent costs."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(50, 5000, n)
    cm = CascadeCostModel(toks, {"a": 20, "b": 30, "o_orig": 60})
    c1 = TaskConfig("proxy", "a", f1)
    c2 = TaskConfig("proxy", "b", f2)
    exit_all_late = np.full(n, 2)
    chained = cm.cascade_cost([c1, c2], exit_all_late)
    # independent (no shared cache): run each from scratch
    zero = np.zeros(n, np.int64)
    ind1, _ = cm.task_cost(c1, zero)
    ind2, _ = cm.task_cost(c2, zero)
    oracle_cfg = TaskConfig("oracle", "o_orig", 1.0)
    ind3, _ = cm.task_cost(oracle_cfg, zero)
    assert np.all(chained <= ind1 + ind2 + ind3 + 1e-9)


def test_optimization_cost_formulas():
    oc = OptimizationCost(n_dev=200, avg_doc_tokens=2000, prompt_tokens=60,
                          fractions=(0.1, 0.25, 0.5, 1.0))
    assert oc.c_eval() > 0 and oc.c_doc() > 0 and oc.c_agent() > 0
    lite = OptimizationCost(n_dev=200, avg_doc_tokens=2000, prompt_tokens=60,
                            fractions=(0.1, 0.25, 0.5, 1.0), lite=True)
    assert lite.c_eval() < oc.c_eval()
    assert oc.model_cascade_cost() < lite.total()
    assert break_even_docs(10.0, 0.5, 1.0) == pytest.approx(20.0)
    assert break_even_docs(10.0, 2.0, 1.0) == np.inf
