"""Fault-tolerant serving plane: injection harness determinism, retry/
backoff isolation, deadlines, quarantine/escalation, circuit breaker,
arena-loss recovery, journal warm restart, watchdog, submit validation,
eviction-under-retry interplay, and exact $-accounting via the ledger."""
import math

import jax
import numpy as np
import pytest

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.tasks import Cascade, Task, TaskConfig
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import (CascadeServer, LMBackend, RequestJournal,
                                  ServerStalledError)
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.scheduler import (FAILED, RESOLVED, TERMINAL_STATES,
                                     TIMED_OUT, RetryPolicy)


def _mk_backend(name, seed, tokz, **kw):
    cfg = get_reduced("llama3_2_1b", dtype="float32", vocab_size=512,
                      num_layers=2)
    rcfg = resolve(cfg, tp=1)
    m = LM(rcfg, CPU_TEST)
    return LMBackend(
        name=name, model=m, params=m.init(jax.random.PRNGKey(seed)),
        tokenizer=tokz,
        rate_per_token=1.0 if name == "oracle" else 0.06, s_alloc=512, **kw)


OPS = {"o_orig": "does this overturn a lower court decision",
       "sur_1": "is a lower court mentioned"}

THR = {0: 0.7, 1: 0.7}
IMPOSSIBLE = {0: 2.0, 1: 2.0}

CASCADE = Cascade([
    Task(TaskConfig("proxy", "sur_1", 0.25), THR),
    Task(TaskConfig("proxy", "o_orig", 1.0), THR),
])
LADDER = Cascade([
    Task(TaskConfig("proxy", "o_orig", 0.25), IMPOSSIBLE),
    Task(TaskConfig("proxy", "o_orig", 1.0), IMPOSSIBLE),
])


@pytest.fixture(scope="module")
def backends():
    tokz = HashWordTokenizer(vocab_size=512)
    return {"proxy": _mk_backend("proxy", 1, tokz),
            "oracle": _mk_backend("oracle", 2, tokz)}


@pytest.fixture(scope="module")
def docs():
    return {d.doc_id: d.text
            for d in generate_corpus(8, avg_lines=10, seed=7)}


def mk_server(backends, **kw):
    for be in backends.values():
        be.reset()
    kw.setdefault("retry", RetryPolicy(max_retries=2, backoff_base=0.0))
    return CascadeServer(dict(backends), OPS, n_classes=2, batch_size=4,
                         **kw)


def _assert_ledger_exact(srv):
    """Replaying the billing ledger per query reproduces cost(qid)
    EXACTLY (same float additions in the same order)."""
    per_q = {qid: 0.0 for qid in srv._handles}
    for _, qid, _, cost in srv.ledger():
        per_q[qid] += cost
    for qid, total in per_q.items():
        assert total == srv.cost(qid)


# ---------------------------------------------------------------- injector

def test_injector_schedule_is_seed_deterministic():
    plan = FaultPlan(seed=5, launch_failure_p=0.3, nan_p=0.2,
                     latency_spike_p=0.1)
    a, b = FaultInjector(plan), FaultInjector(plan)
    assert [a.draw() for _ in range(64)] == [b.draw() for _ in range(64)]
    assert a.calls == 64


def test_faulty_backend_forwards_attributes(backends):
    inj = FaultInjector(FaultPlan(seed=0))
    proxy = inj.wrap(backends["proxy"])
    assert proxy.name == "proxy"
    assert proxy.rate_per_token == backends["proxy"].rate_per_token
    proxy.host_overhead_s = 1.25           # setattr forwards to the inner
    assert backends["proxy"].host_overhead_s == 1.25
    backends["proxy"].host_overhead_s = 0.0


# ---------------------------------------------------------- submit checks

def test_submit_validation(backends, docs):
    srv = mk_server(backends)
    h = srv.register(CASCADE)
    with pytest.raises(ValueError, match="empty or"):
        h.submit(0, "")
    with pytest.raises(ValueError, match="empty or"):
        h.submit(0, "  \n\t ")
    text = next(iter(docs.values()))
    h.submit(0, text)
    with pytest.raises(ValueError, match="already submitted"):
        h.submit(0, text)
    h2 = srv.register(CASCADE)
    h2.submit(0, text)              # doc ids are scoped per query
    srv.drain()


# ------------------------------------------------- launch failure + retry

def test_failed_launch_retries_solo_and_resolves(backends, docs):
    srv = mk_server(backends)
    h = srv.register(CASCADE)
    inj = FaultInjector(FaultPlan(seed=3, launch_failure_p=1.0))
    inj.install(srv)
    futs = [h.submit(d, docs[d], arrival=float(i))
            for i, d in enumerate(sorted(docs)[:3])]
    assert srv.step() == []                 # packed launch fails
    assert inj.counts["launch_failures"] == 1
    assert h.stats.retries == 3             # every member re-enqueued
    assert all(not f.done for f in futs)    # ... but nobody failed
    inj.plan = FaultPlan(seed=3)            # heal the backend
    # poisoned-cohort isolation: survivors retry in SINGLETON groups
    launch = srv._queue.next_launch(srv._stage_of, srv.batch_size)
    assert len(launch.doc_ids) == 1
    srv._queue.push(srv._requests[launch.doc_ids[0]])
    res = h.drain()
    assert all(f.status == RESOLVED for f in futs)
    assert set(res.pred) == {d for d in sorted(docs)[:3]}
    _assert_ledger_exact(srv)


def test_retries_exhausted_resolves_failed(backends, docs):
    srv = mk_server(backends)
    h = srv.register(CASCADE)
    inj = FaultInjector(FaultPlan(seed=1, launch_failure_p=1.0))
    inj.install(srv)
    futs = [h.submit(d, docs[d]) for d in sorted(docs)[:2]]
    res = h.drain()                         # terminates, never hangs
    assert all(f.done and f.status == FAILED for f in futs)
    assert all("launch failed" in f.error for f in futs)
    assert h.stats.failures == 2
    assert res.pred == {}                   # no RESOLVED documents
    assert set(res.status.values()) == {FAILED}
    assert srv.stats().breaker_trips >= 1   # persistent failures trip it
    with pytest.raises(RuntimeError, match="failed"):
        futs[0].result()


def test_deadline_resolves_timed_out(backends, docs):
    srv = mk_server(backends)
    h = srv.register(CASCADE)
    d0, d1 = sorted(docs)[:2]
    late = h.submit(d0, docs[d0], deadline_s=0.0)     # expires immediately
    ok = h.submit(d1, docs[d1])
    res = h.drain()
    assert late.status == TIMED_OUT and late.error == "deadline exceeded"
    assert ok.status == RESOLVED
    assert h.stats.timeouts == 1
    assert res.status[d0] == TIMED_OUT and d0 not in res.pred
    with pytest.raises(RuntimeError, match="timed_out"):
        late.result()


# ------------------------------------------------------------- quarantine

def test_nan_quarantine_retries_solo_then_resolves(backends, docs):
    srv = mk_server(backends)
    h = srv.register(CASCADE)
    inj = FaultInjector(FaultPlan(seed=2, nan_p=1.0))
    inj.install(srv)
    d0 = sorted(docs)[0]
    fut = h.submit(d0, docs[d0])
    srv.step()                              # NaN conf -> quarantined
    assert h.stats.quarantines == 1 and not fut.done
    inj.plan = FaultPlan(seed=2)            # heal
    h.drain()
    assert fut.status == RESOLVED
    _assert_ledger_exact(srv)               # the NaN launch is still billed


def test_persistent_nan_escalates_then_fails(backends, docs):
    srv = mk_server(backends)
    h = srv.register(CASCADE)
    inj = FaultInjector(FaultPlan(seed=2, nan_p=1.0))
    inj.install(srv)
    d0 = sorted(docs)[0]
    fut = h.submit(d0, docs[d0])
    srv.step()                              # quarantine 1: solo retry
    srv.step()                              # quarantine 2: escalate to final
    final = len(h.stages) - 1
    assert srv._requests[srv._ids[(h.query_id, d0)]].stage == final
    inj.plan = FaultPlan(seed=2)            # oracle now healthy
    h.drain()
    assert fut.status == RESOLVED and fut.exit_stage == final
    # and with the oracle ALSO emitting NaN, the document fails cleanly
    srv2 = mk_server(backends)
    h2 = srv2.register(CASCADE)
    FaultInjector(FaultPlan(seed=2, nan_p=1.0)).install(srv2)
    fut2 = h2.submit(d0, docs[d0])
    h2.drain()
    assert fut2.status == FAILED
    assert "non-finite" in fut2.error
    assert h2.stats.quarantines == 3


# -------------------------------------------------------- circuit breaker

def test_breaker_reroutes_sick_backend_to_next_stage(backends, docs):
    srv = mk_server(backends, breaker_threshold=2, breaker_cooldown=64,
                    retry=RetryPolicy(max_retries=3, backoff_base=0.0))
    h = srv.register(CASCADE)
    inj = FaultInjector(FaultPlan(seed=4, launch_failure_p=1.0))
    srv.backends["proxy"] = inj.wrap(srv.backends["proxy"])   # proxy only
    futs = [h.submit(d, docs[d]) for d in sorted(docs)[:4]]
    res = h.drain()
    final = len(h.stages) - 1
    assert all(f.status == RESOLVED for f in futs)
    assert all(s == final for s in res.exit_stage.values())   # via oracle
    assert h.stats.breaker_trips >= 1
    assert srv.stats().breaker_trips == srv._breaker_trips
    # the sick backend's stages were BILLED as the oracle stage
    assert res.stats.stage_cost[final] > 0
    _assert_ledger_exact(srv)


# ------------------------------------------------------------- arena loss

def test_arena_loss_replays_eviction_and_rebills_prefill(backends, docs):
    sub = {d: docs[d] for d in sorted(docs)[:4]}
    srv = mk_server(backends)
    h = srv.register(LADDER)
    for i, d in enumerate(sorted(sub)):
        h.submit(d, sub[d], arrival=float(i))
    clean = h.drain()
    assert srv.stats().recovered_docs == 0
    cost_clean = srv.cost(h.query_id)

    srv2 = mk_server(backends)
    h2 = srv2.register(LADDER)
    inj = FaultInjector(FaultPlan(seed=9, arena_loss_at=1))
    inj.install(srv2)
    futs = [h2.submit(d, sub[d], arrival=float(i))
            for i, d in enumerate(sorted(sub))]
    res = h2.drain()
    assert inj.counts["arena_losses"] == 1
    assert h2.stats.recovered_docs > 0
    assert all(f.status == RESOLVED for f in futs)
    assert res.pred == clean.pred           # recovery changes $, not answers
    # survivors re-prefilled from scratch: the lost cache is re-billed
    assert srv2.cost(h2.query_id) > cost_clean
    _assert_ledger_exact(srv2)


# -------------------------------------------------------- journal restart

def test_journal_recovery_restores_and_resubmits(backends, docs):
    srv = mk_server(backends, journal=RequestJournal())
    h = srv.register(CASCADE)
    sub = sorted(docs)[:6]
    for i, d in enumerate(sub):
        h.submit(d, docs[d], arrival=float(i))
    def _done():
        return {d: (srv._requests[srv._ids[(h.query_id, d)]].pred,
                    srv._requests[srv._ids[(h.query_id, d)]].cost)
                for d in sub
                if srv._requests[srv._ids[(h.query_id, d)]].done}

    while not _done():                      # partial progress, then "crash"
        srv.step()
    journal = srv.journal
    done_before = _done()
    assert 0 < len(done_before) < len(sub)  # some resolved, some not

    srv2 = mk_server(backends, journal=RequestJournal())
    h2 = srv2.register(CASCADE)
    futs = srv2.recover(journal)
    assert set(d for _, d in futs) == set(sub)
    for d, (pred, cost) in done_before.items():
        fut = futs[(h2.query_id, d)]
        assert fut.done and fut.pred == pred and fut.cost == cost
    assert h2.stats.recovered_docs == len(sub) - len(done_before)
    res = h2.drain()
    assert all(futs[(h2.query_id, d)].status in TERMINAL_STATES
               for d in sub)
    assert set(res.status) == set(sub)
    _assert_ledger_exact(srv2)
    # the new server's OWN journal is complete: a second crash recovers too
    assert len(srv2.journal.unresolved()) == 0


# --------------------------------------------------------------- watchdog

def test_watchdog_raises_on_stall_with_stuck_listing(backends, docs):
    srv = mk_server(backends, stall_limit=5)
    h = srv.register(CASCADE)
    d0 = sorted(docs)[0]
    fut = h.submit(d0, docs[d0])
    srv._requests[srv._ids[(h.query_id, d0)]].not_before = math.inf
    with pytest.raises(ServerStalledError) as ei:
        srv.drain()
    assert ei.value.stuck == [(h.query_id, d0, 0, 0, math.inf)]
    assert not fut.done


def test_finite_backoff_is_not_a_stall(backends, docs):
    srv = mk_server(backends, stall_limit=2,
                    retry=RetryPolicy(max_retries=2, backoff_base=0.01,
                                      backoff_cap=0.01))
    h = srv.register(CASCADE)
    inj = FaultInjector(FaultPlan(seed=6, launch_failure_p=1.0))
    inj.install(srv)
    fut = h.submit(sorted(docs)[0], docs[sorted(docs)[0]])
    h.drain()                               # sleeps out backoffs, no stall
    assert fut.status == FAILED


# ------------------------------------------------- eviction under retry

def test_eviction_during_backoff_rebills_prefill_once():
    tokz = HashWordTokenizer(vocab_size=512)
    bks = {"proxy": _mk_backend("proxy", 1, tokz, slot_budget=1),
           "oracle": _mk_backend("oracle", 2, tokz)}
    srv = CascadeServer(bks, OPS, n_classes=2, batch_size=4,
                        retry=RetryPolicy(max_retries=2, backoff_base=0.0))
    corpus = {d.doc_id: d.text
              for d in generate_corpus(2, avg_lines=10, seed=11)}
    da, db = sorted(corpus)
    ha = srv.register(LADDER)
    hb = srv.register(LADDER)
    fa = ha.submit(da, corpus[da], arrival=0.0)
    srv.step()                              # A runs stage 0, caches f=0.25
    rid = srv._ids[(ha.query_id, da)]
    assert srv._requests[rid].cached["proxy"] > 0
    inj = FaultInjector(FaultPlan(seed=8, launch_failure_p=1.0))
    inj.install(srv)
    srv.step()                              # A's stage-1 launch fails
    assert srv._requests[rid].retries == 1
    inj.plan = FaultPlan(seed=8)            # heal
    # B arrives with priority and evicts A (slot_budget=1) mid-retry
    fb = hb.submit(db, corpus[db], arrival=-1.0)
    srv.step()
    assert srv._requests[rid].evictions == 1
    assert srv._requests[rid].cached["proxy"] == 0
    assert srv._requests[rid].retries == 1  # eviction preserves retry count
    srv.drain()
    assert fa.status == RESOLVED and fb.status == RESOLVED
    # A's stage-1 re-prefill was billed exactly once: the full document
    # (cache lost) plus the op suffix, as NEW tokens
    toks_a = len(tokz.encode(corpus[da]))
    op_len = len(tokz.encode(OPS["o_orig"]))
    assert ha.stats.stage_new_tokens[1] == toks_a + op_len
    assert ha.stats.stage_cached_tokens[1] == 0
    assert ha.stats.retries == 1 and ha.stats.evictions == 1
    _assert_ledger_exact(srv)


# ----------------------------------------------- fault-free path is inert

def test_fault_free_path_matches_pre_fault_engine(backends, docs):
    """With no injector, no deadlines, and default policies, the new
    control flow adds nothing: results and $ match a plain run."""
    srv = mk_server(backends)
    h = srv.register(CASCADE)
    for i, d in enumerate(sorted(docs)):
        h.submit(d, docs[d], arrival=float(i))
    res = h.drain()
    st = h.stats
    assert st.retries == st.quarantines == st.timeouts == 0
    assert st.failures == st.breaker_trips == st.recovered_docs == 0
    assert set(res.status.values()) == {RESOLVED}
    assert srv._stalled_steps == 0
    _assert_ledger_exact(srv)
