"""End-to-end construction pipeline tests on the calibrated simulator."""
import numpy as np
import pytest

from repro.core.pipeline import (BuildConfig, build_task_cascade,
                                 evaluate_on, model_cascade,
                                 restructure_top25)
from repro.core.simulation import WORKLOADS, make_workload


@pytest.fixture(scope="module")
def enron():
    w = make_workload("enron", 600)
    return w.subset(np.arange(200)), w.subset(np.arange(200, 600))


def test_simulation_is_deterministic():
    w1 = make_workload("court", 100)
    w2 = make_workload("court", 100)
    from repro.core.tasks import TaskConfig
    c = TaskConfig("proxy", "o_orig", 0.5)
    s1, s2 = w1.eval_config(c), w2.eval_config(c)
    np.testing.assert_array_equal(s1.pred, s2.pred)
    np.testing.assert_array_equal(s1.conf, s2.conf)
    np.testing.assert_array_equal(w1.oracle_pred, w2.oracle_pred)


def test_task_cascade_beats_model_cascade_on_enron(enron):
    dev, test = enron
    r_mc = evaluate_on(test, model_cascade(dev, 0.9))
    r_tc = evaluate_on(test, build_task_cascade(dev, BuildConfig(seed=0)))
    assert r_tc["total_cost"] < r_mc["total_cost"]
    assert r_tc["accuracy"] >= 0.9 - 0.03


def test_oracle_only_is_most_expensive(enron):
    dev, test = enron
    r_tc = evaluate_on(test, build_task_cascade(dev, BuildConfig(seed=0)))
    assert r_tc["total_cost"] < r_tc["oracle_cost"]


def test_guarantee_variant_meets_target(enron):
    dev, test = enron
    out = build_task_cascade(dev, BuildConfig(guarantee=True, seed=0))
    r = evaluate_on(test, out)
    assert r["accuracy"] >= 0.9 - 0.02   # delta=0.25 single draw; small slack


def test_lite_variant_cheaper_optimization():
    """Lite: proxy-only surrogate candidates -> fewer configs evaluated."""
    w = make_workload("court", 300)
    dev = w.subset(np.arange(150))
    full = build_task_cascade(dev, BuildConfig(seed=1))
    w2 = make_workload("court", 300)
    dev2 = w2.subset(np.arange(150))
    lite = build_task_cascade(dev2, BuildConfig(seed=1, lite=True))
    n_oracle_full = sum(1 for c in full.candidate_configs
                        if c.model == "oracle" and c.operation != "o_orig")
    n_oracle_lite = sum(1 for c in lite.candidate_configs
                        if c.model == "oracle" and c.operation != "o_orig")
    assert n_oracle_lite == 0 and n_oracle_full > 0


def test_no_surrogates_variant_only_uses_o_orig():
    w = make_workload("legal", 300)
    dev = w.subset(np.arange(150))
    out = build_task_cascade(dev, BuildConfig(use_surrogates=False, seed=0))
    assert all(t.config.operation == "o_orig" for t in out.cascade.tasks)


def test_no_filtering_variant_full_docs_only():
    w = make_workload("legal", 300, reorder_mode="none")
    dev = w.subset(np.arange(150))
    out = build_task_cascade(dev, BuildConfig(fractions=(1.0,), seed=0))
    assert all(t.config.fraction == 1.0 for t in out.cascade.tasks)


def test_restructure_top25_is_two_stage(enron):
    dev, test = enron
    out = restructure_top25(dev, 0.9)
    assert len(out.cascade.tasks) <= 1
    r = evaluate_on(test, out)
    assert r["total_cost"] > 0


def test_every_workload_builds():
    for name in WORKLOADS:
        w = make_workload(name, 240)
        dev = w.subset(np.arange(120))
        out = build_task_cascade(dev, BuildConfig(n_a=1, n_s=3, seed=0))
        r = evaluate_on(w.subset(np.arange(120, 240)), out)
        assert r["accuracy"] > 0.5
        assert np.isfinite(r["total_cost"])
