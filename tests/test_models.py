"""Per-arch smoke tests (reduced configs) + serve-path consistency.

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward + one train step on CPU, asserting output shapes and
finiteness.  The cascade primitive (prefill -> extend == full prefill) is
checked for every non-MoE arch (MoE capacity dropping is order-dependent
by design; those assert class-level agreement instead).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import resolve
from repro.configs import ARCHS, get_reduced
from repro.models.model import LM
from repro.models.runtime import CPU_TEST, Runtime
from repro.models.whisper import WhisperModel


def make_tiny(arch, **over):
    cfg = get_reduced(arch, dtype="float32", **over)
    rcfg = resolve(cfg, tp=1)
    if cfg.family == "audio":
        return WhisperModel(rcfg, CPU_TEST), cfg
    return LM(rcfg, CPU_TEST), cfg


def tiny_batch(cfg, B=2, S=24, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"tokens": jax.random.randint(k, (B, S), 9, cfg.vocab_size)}
    s_total = S
    if cfg.frontend_stub == "vision_patches":
        batch["patch_emb"] = 0.02 * jax.random.normal(
            k, (B, cfg.frontend_len, cfg.d_model))
        s_total += cfg.frontend_len
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(s_total)[None, :, None], (B, s_total, 3)
        ).astype(jnp.int32)
    if cfg.frontend_stub == "audio_frames":
        batch["frame_emb"] = 0.02 * jax.random.normal(
            k, (B, cfg.encoder_seq_len, cfg.d_model))
    batch["labels"] = jax.random.randint(k, (B, s_total), 9, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    model, cfg = make_tiny(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    logits, _ = model.forward(params, batch)
    B, S_total = batch["labels"].shape
    assert logits.shape[0] == B and logits.shape[1] == S_total
    assert logits.shape[2] >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if a not in ("whisper_base", "qwen2_vl_2b")])
def test_prefill_extend_matches_full(arch):
    model, cfg = make_tiny(arch)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 9,
                              cfg.vocab_size)
    full_logits, _ = model.prefill(params, {"tokens": toks}, s_alloc=S + 8)
    half = S // 2
    _, st = model.prefill(params, {"tokens": toks[:, :half]}, s_alloc=S + 8)
    ext_logits, _ = model.extend(params, {"tokens": toks[:, half:]}, st,
                                 q_offset=half)
    if cfg.moe is not None:
        # capacity-dropping is batch-order dependent; require argmax match
        assert int(jnp.sum(jnp.argmax(full_logits, -1)
                           != jnp.argmax(ext_logits, -1))) <= B // 2
    else:
        np.testing.assert_allclose(np.asarray(ext_logits),
                                   np.asarray(full_logits),
                                   atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_runs(arch):
    model, cfg = make_tiny(arch)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 2, 16
    batch = tiny_batch(cfg, B=B, S=S)
    batch.pop("labels")
    if cfg.family == "audio":
        logits, st = model.prefill(params, batch, s_alloc=S + 4)
    else:
        if "positions3" in batch:
            batch.pop("positions3")
            batch.pop("patch_emb")
        logits, st = model.prefill(params, {"tokens": batch["tokens"]},
                                   s_alloc=S + 4)
    nxt = jnp.argmax(logits, -1)
    logits2, st2 = model.decode_step(params, nxt, st,
                                     jnp.full((B,), S, jnp.int32))
    assert logits2.shape == logits.shape
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_matches_teacher_forcing_dense():
    """Greedy decode logits == teacher-forced forward logits (llama)."""
    model, cfg = make_tiny("llama3_2_1b", num_layers=2)
    params = model.init(jax.random.PRNGKey(4))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 9,
                              cfg.vocab_size)
    flog, _ = model.forward(params, {"tokens": toks})
    plog, st = model.prefill(params, {"tokens": toks[:, :S]}, s_alloc=S + 4)
    np.testing.assert_allclose(np.asarray(plog), np.asarray(flog[:, S - 1]),
                               atol=2e-5, rtol=1e-4)
    dlog, _ = model.decode_step(params, toks[:, S], st,
                                jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dlog), np.asarray(flog[:, S]),
                               atol=2e-5, rtol=1e-4)


def test_sliding_window_ring_cache_decode():
    """Local-attention ring cache decode == full-cache reference (gemma3)."""
    model, cfg = make_tiny("gemma3_27b", num_layers=6, sliding_window=8)
    params = model.init(jax.random.PRNGKey(6))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S + 1), 9,
                              cfg.vocab_size)
    flog, _ = model.forward(params, {"tokens": toks})
    _, st = model.prefill(params, {"tokens": toks[:, :S]}, s_alloc=S + 4)
    dlog, _ = model.decode_step(params, toks[:, S], st,
                                jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(dlog), np.asarray(flog[:, S]),
                               atol=3e-5, rtol=1e-3)


def test_mlstm_chunkwise_matches_recurrent():
    from repro.models import ssm
    B, T, H, dh = 2, 32, 2, 8
    key = jax.random.PRNGKey(8)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, T, H, dh))
    k = jax.random.normal(ks[1], (B, T, H, dh))
    v = jax.random.normal(ks[2], (B, T, H, dh))
    li = jax.random.normal(ks[3], (B, T, H))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, T, H)) + 1.0)
    state = ssm.init_mlstm_state(B, H, dh)
    h_seq, st_seq = ssm.mlstm_recurrent_ref(q, k, v, li, lf, state)
    h_chk, st_chk = ssm.mlstm_chunk(q, k, v, li, lf, state, chunk=8)
    np.testing.assert_allclose(np.asarray(h_chk), np.asarray(h_seq),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_chk["C"]),
                               np.asarray(st_seq["C"]), atol=1e-4, rtol=1e-3)


def test_rglru_scan_matches_step_by_step():
    from repro.models import ssm
    d, dr, B, T = 16, 16, 2, 12
    p = ssm.init_rglru(jax.random.PRNGKey(9), d, dr, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(10), (B, T, d))
    y_full, st_full = ssm.rglru_apply(p, x)
    st = None
    ys = []
    for t in range(T):
        y, st = ssm.rglru_apply(p, x[:, t:t + 1], state=st, mode="step")
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(st_full["h"]),
                               atol=1e-4, rtol=1e-3)
