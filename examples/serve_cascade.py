"""Full data-plane integration: real documents, real JAX models, a task
cascade built FROM engine scores and executed BY the engine.

    PYTHONPATH=src python examples/serve_cascade.py

Pipeline (mirrors Figure 2 of the paper, end to end on CPU):
  1. generate a synthetic text corpus with planted relevance;
  2. fit the §4 document restructurer (oracle line ranges -> granularity ->
     JAX relevance classifier) and reorder every document;
  3. evaluate candidate task configs (2 models x 2 operations x fractions)
     by running the proxy/oracle LMs through the serving engine on the dev
     split — confidences come off the LM heads' class tokens;
  4. Alg 2 thresholds + Alg 4 greedy assembly over those scores;
  5. serve the test split MULTI-TENANT: one ``CascadeServer`` owns the
     backends, arenas, and the global request queue, and two registered
     queries (the assembled cascade plus a strict-threshold variant of
     it) stream the same feed concurrently through the
     register -> submit -> step/poll -> result lifecycle.  Documents from
     both queries that share a static launch signature merge into ONE
     launch (cross-query packing over shared KV arenas); per-query
     latency (p50/p99), cost vs oracle-only, and cache hit rate come out
     of each handle's own stats;
  6. replay the same feed under INJECTED FAULTS (seeded launch failures,
     NaN confidences, one arena loss) to show the failure model: every
     document still reaches a terminal state — RESOLVED, FAILED, or
     TIMED_OUT — via solo retries with backoff, non-finite-confidence
     quarantine (solo retry, then escalate to the final stage), and
     eviction-path arena recovery; then crash the server mid-flight and
     warm-restart a fresh one from its write-ahead request journal;
  7. re-serve the cascade on PREFIX-SHARING bf16 arenas: each operation
     prefix prefills once per (backend, op, bucket) into a pinned shared
     arena row aliased by every document's block table, and the KV
     stores at half an f32 row (``kv_dtype='bfloat16'``) — more live
     documents per byte of HBM, same billing contract;
  8. record a Perfetto trace of a two-tenant chaos run (span events,
     launch timeline, metric registry);
  9. gate the tree with the RSA linter (``python -m repro.analysis``)
     and replay the chaos feed under the runtime ARENA SANITIZER
     (``ARENA_SANITIZE=1`` / ``LMBackend.sanitize=True``): every
     launch's read/write row sets are bracketed, so slot-aliasing
     races raise ``ArenaRaceError`` instead of corrupting KV;
 10. re-serve the feed with OVERLAPPED AHEAD-OF-TIME DISPATCH
     (``inflight=4``): ``step()`` enqueues up to four jitted launches
     before blocking, syncing a ticket only when the scheduler needs
     its confidences for routing — preds/confs/$ stay bitwise those of
     the depth-1 run while the device-wait drops behind the in-flight
     window (the printed overlap-hidden fraction).

The data plane underneath is PAGED on Pallas runtimes: each document owns
one slot row of a persistent per-bucket KV arena, the per-launch slot ids
ride into the kernels through scalar-prefetch SMEM, and decode/extend read
``k_arena[slot]`` blocks in place — no [B, S] gather copy per launch (the
demo's CPU runtime uses the bitwise-identical gather reference plane; see
``serving/engine.py``).

Models are tiny untrained LMs (this is a mechanics/integration demo —
"accuracy" is agreement with the oracle MODEL, exactly the paper's alpha
definition).
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.config import resolve
from repro.configs import get_reduced
from repro.core.assembly import greedy_assembly
from repro.core.cost_model import CascadeCostModel
from repro.core.restructure import DocumentRestructurer, SyntheticOracle
from repro.core.tasks import Cascade, TaskConfig, TaskScores, run_cascade
from repro.core.thresholds import filter_tasks
from repro.data.documents import generate_corpus
from repro.data.tokenizer import HashWordTokenizer
from repro.launch.serve import poisson_arrivals, warm_arena
from repro.models.model import LM
from repro.models.runtime import CPU_TEST
from repro.serving.engine import (CascadeEngine, CascadeServer, LMBackend,
                                  RequestJournal)
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.scheduler import RESOLVED, RetryPolicy
from repro.serving.telemetry import write_chrome_trace

OPS = {
    "o_orig": "does this opinion overturn a lower court decision",
    "sur_court": "is any lower court mentioned overturn reversed vacated",
    "sur_affirm": "does it say affirmed upheld sustained",
}
FRACTIONS = (0.25, 1.0)


def main():
    t0 = time.time()
    print("1. corpus + restructuring")
    docs = generate_corpus(28, n_classes=2, avg_lines=16, seed=11)
    restr = DocumentRestructurer(OPS["o_orig"]).fit(
        docs[:12], SyntheticOracle(noise=0.1))
    reordered = {d.doc_id: restr.reorder(d).text for d in docs}
    dev_ids = [d.doc_id for d in docs[:12]]
    test_ids = [d.doc_id for d in docs[12:]]
    print(f"   granularity={restr.granularity} lines, "
          f"classifier F1={restr.f1:.2f}")

    print("2. backends (tiny untrained proxy + oracle LMs)")
    tokz = HashWordTokenizer(vocab_size=512)

    def mk(name, arch, seed, rate):
        cfg = get_reduced(arch, dtype="float32", vocab_size=512,
                          num_layers=2)
        m = LM(resolve(cfg, tp=1), CPU_TEST)
        return LMBackend(name=name, model=m,
                         params=m.init(jax.random.PRNGKey(seed)),
                         tokenizer=tokz, rate_per_token=rate, s_alloc=1024)

    backends = {"proxy": mk("proxy", "llama3_2_1b", 1, 0.15e-6),
                "oracle": mk("oracle", "qwen3_1_7b", 2, 2.50e-6)}
    engine = CascadeEngine(backends, OPS, n_classes=2, batch_size=4)

    print("3. candidate evaluation on the dev split (engine-backed)")
    dev_docs = {i: reordered[i] for i in dev_ids}
    # oracle reference predictions (the alpha target)
    oracle_ref = engine.run(Cascade([]), dev_docs)
    oracle_pred = np.asarray([oracle_ref.pred[i] for i in dev_ids])

    configs = [TaskConfig(m, o, f)
               for m in ("proxy",) for o in OPS for f in FRACTIONS
               if not (o == "o_orig" and f == 1.0 and m == "oracle")]
    scores = {}
    for cfg in configs:
        # direct single-stage scoring: run one stage with no thresholds
        be = engine.backends[cfg.model]
        be.reset()
        import math
        toks = {i: np.asarray(be.tokenizer.encode(dev_docs[i]), np.int32)
                for i in dev_ids}
        from repro.serving.scheduler import make_buckets
        lens = {i: len(toks[i]) for i in dev_ids}
        pred = np.zeros(len(dev_ids), np.int64)
        conf = np.zeros(len(dev_ids))
        pos = {i: k for k, i in enumerate(dev_ids)}
        for blen, ids in make_buckets(dev_ids, lens, 4):
            p, c, *_ = be.run_stage(
                ids, toks, blen, cfg.fraction,
                np.asarray(be.tokenizer.encode(OPS[cfg.operation]),
                           np.int32), 2)
            for j, d in enumerate(ids):
                pred[pos[d]], conf[pos[d]] = p[j], c[j]
        scores[cfg] = TaskScores(cfg, pred, conf)
    doc_tokens = np.asarray(
        [len(tokz.encode(reordered[i])) for i in dev_ids])
    cm = CascadeCostModel(doc_tokens, {o: len(tokz.encode(t))
                                       for o, t in OPS.items()},
                          rates={"proxy": 0.15e-6, "oracle": 2.50e-6})

    print("4. Alg 2 thresholds + Alg 4 greedy assembly")
    eligible = filter_tasks(list(scores.values()), oracle_pred, 2,
                            alpha=0.85, g=0.10)
    cascade, trace = greedy_assembly(eligible, scores, oracle_pred, cm, 2,
                                     alpha=0.85)
    print(f"   eligible tasks: {len(eligible)}; assembled: "
          f"{[t.config.key() for t in cascade.tasks]}")

    print("5. multi-tenant serving: two queries, one CascadeServer")
    # Data plane: every document holds one slot row of a persistent
    # per-bucket KV arena; launches address rows by slot id.  On Pallas
    # runtimes the ids ride in scalar-prefetch SMEM and the kernels DMA
    # arena blocks in place (paged attention — zero row-copy bytes per
    # decode launch); this CPU demo uses the gather reference plane,
    # which is bitwise-identical by construction.
    test_docs = {i: reordered[i] for i in test_ids}
    # a second tenant: the same task configs under stricter thresholds —
    # distinct query, yet every launch signature (and compiled step, and
    # arena slot pool) is shared with the first
    strict = cascade.with_thresholds([
        {c: min(v + 0.10, 1.0) for c, v in t.thresholds.items()}
        for t in cascade.tasks])
    # the engine doubles as the server's warm-up driver: compile every
    # launch signature streaming can produce before the timed session
    warm_arena(engine, cascade, test_docs, engine.batch_size)

    # lifecycle: (a) register each query -> QueryHandle ...
    server = engine            # a CascadeEngine IS a CascadeServer
    server.reset()
    h_main = server.register(cascade, accuracy_target=0.85)
    h_strict = server.register(strict, accuracy_target=0.95)
    print(f"   registered query {h_main.query_id} (alpha>=0.85) and query "
          f"{h_strict.query_id} (alpha>=0.95) on one server")

    # ... (b) submit each tenant's feed (same docs, no id collision —
    # document ids are scoped per query) ...
    arrivals = poisson_arrivals(sorted(test_docs), rate=8.0, seed=3)
    wall0 = time.perf_counter()
    for d in sorted(test_docs):
        h_main.submit(d, test_docs[d], arrival=arrivals[d])
        h_strict.submit(d, test_docs[d], arrival=arrivals[d])

    # ... (c) step the shared queue and poll each handle for ITS results
    polled = {h_main.query_id: {}, h_strict.query_id: {}}
    while server.pending():
        server.step()
        for h in (h_main, h_strict):
            polled[h.query_id].update(h.poll())
    wall = time.perf_counter() - wall0
    res, res_strict = h_main.result(), h_strict.result()
    assert polled[h_main.query_id].keys() == res.pred.keys()
    occupancy, launches = server.occupancy(), server.stats().batches

    # engine.run() below resets the server session (the results/stats
    # captured above stay valid — they are materialized per query)
    oracle_only = engine.run(Cascade([]), test_docs)
    agree = np.mean([res.pred[i] == oracle_only.pred[i] for i in test_ids])
    stats = res.stats
    print(f"   served 2x{len(test_ids)} docs in {wall:.1f}s; "
          f"occupancy {occupancy:.2f} docs/launch")
    print(f"   query {h_main.query_id}: latency "
          f"p50 {1e3 * stats.latency_quantile(0.5):.0f} ms / "
          f"p99 {1e3 * stats.latency_quantile(0.99):.0f} ms; "
          f"cost ${res.cost * 1e3:.4f}m vs oracle-only "
          f"${oracle_only.cost * 1e3:.4f}m "
          f"({res.cost / oracle_only.cost:.2f}x)")
    print(f"   query {h_strict.query_id} (strict): cost "
          f"${res_strict.cost * 1e3:.4f}m; oracle fall-through "
          f"{np.mean([s == len(strict.tasks) for s in res_strict.exit_stage.values()]):.0%}"
          f" vs {np.mean([s == len(cascade.tasks) for s in res.exit_stage.values()]):.0%}")
    print(f"   agreement with oracle: {agree:.1%}; "
          f"KV cache hit rate {stats.cache_hit_rate():.1%}; "
          f"launches {launches}")
    print("6. failure model: injected faults, terminal states, warm restart")
    # The serving plane guarantees every submitted document reaches a
    # TERMINAL state (RESOLVED / FAILED / TIMED_OUT) under launch
    # failures (failed launches re-enqueue members solo with backoff),
    # non-finite confidences (quarantine: solo retry, then escalate to
    # the final stage), sick backends (circuit breaker routes around
    # them), and arena loss (slots released, documents re-prefill via
    # the eviction path).  backoff_base=0.0 keeps the replay instant and
    # the launch schedule a pure function of the chaos seed.
    for be in backends.values():
        be.reset()
    chaos = CascadeServer(backends, OPS, n_classes=2, batch_size=4,
                          retry=RetryPolicy(max_retries=2, backoff_base=0.0),
                          journal=RequestJournal())
    h_chaos = chaos.register(cascade)
    inj = FaultInjector(FaultPlan(seed=5, launch_failure_p=0.25, nan_p=0.2,
                                  arena_loss_at=3)).install(chaos)
    feed = sorted(test_docs)[:8]
    for k, d in enumerate(feed):
        h_chaos.submit(d, test_docs[d], arrival=float(k))
    # "crash" the server after a few steps: the write-ahead journal has
    # every submission, so a FRESH server re-registers the same query and
    # recovers — resolved docs restore verbatim (no re-execution, $ carried
    # over), in-flight docs are resubmitted from their original arrivals.
    for _ in range(4):
        chaos.step()
    crashed_journal = chaos.journal
    print(f"   pre-crash: {len(crashed_journal.resolutions)} of {len(feed)} "
          f"docs terminal after 4 steps under injected faults "
          f"({inj.counts['launch_failures']} launch failures, "
          f"{inj.counts['nan_confidences']} NaN confidences, "
          f"{inj.counts['arena_losses']} arena losses)")
    for be in backends.values():
        be.reset()
    warm = CascadeServer(backends, OPS, n_classes=2, batch_size=4,
                         retry=RetryPolicy(max_retries=2, backoff_base=0.0),
                         journal=RequestJournal())
    warm.register(cascade)
    FaultInjector(FaultPlan(seed=5, nan_p=0.2)).install(warm)
    futures = warm.recover(crashed_journal)
    warm.drain()
    statuses = [f.status for f in futures.values()]
    chaos_stats = warm.stats()
    print(f"   recovered server: {len(futures)} docs -> "
          f"{sum(s == RESOLVED for s in statuses)} RESOLVED, "
          f"{sum(s != RESOLVED for s in statuses)} FAILED/TIMED_OUT; "
          f"retries={chaos_stats.retries} "
          f"quarantines={chaos_stats.quarantines} "
          f"recovered_docs={chaos_stats.recovered_docs} "
          f"(every submitted doc is terminal: "
          f"{all(f.done for f in futures.values())})")

    print("7. prefix sharing + bf16 arenas: more live docs per HBM byte")
    # The op-first plane (``prefix_sharing=True``) prefills each
    # operation's tokens ONCE per (backend, op, bucket) into a pinned
    # shared arena row; every document's block table aliases it (COW on
    # ragged remainders), so the per-document prefill shrinks by the op
    # length.  ``kv_dtype='bfloat16'`` stores the arena at half an f32
    # row, dequantized at read.  Billing follows the token-accounting
    # contract, not the physical work: on same-op fraction ladders the $
    # is EXACTLY the doc-before-op plane's (an op SWITCH re-prefills, by
    # construction — the doc's KV attends to the op prefix).
    def mk_shared(name, arch, seed, rate, kv_dtype="bfloat16"):
        cfg = get_reduced(arch, dtype="float32", vocab_size=512,
                          num_layers=2)
        m = LM(resolve(cfg, tp=1), CPU_TEST)
        return LMBackend(name=name, model=m,
                         params=m.init(jax.random.PRNGKey(seed)),
                         tokenizer=tokz, rate_per_token=rate, s_alloc=1024,
                         prefix_sharing=True, kv_dtype=kv_dtype)

    shared_be = {"proxy": mk_shared("proxy", "llama3_2_1b", 1, 0.15e-6),
                 "oracle": mk_shared("oracle", "qwen3_1_7b", 2, 2.50e-6)}
    shared_eng = CascadeEngine(shared_be, OPS, n_classes=2, batch_size=4)
    res_shared = shared_eng.run(cascade, test_docs)
    sst = res_shared.stats
    bucket = 1024
    # same-geometry comparison: prefix sharing rounds the row length to a
    # block multiple, so the f32 reference row must share that layout
    probe_f32 = mk_shared("proxy", "llama3_2_1b", 1, 0.15e-6, kv_dtype=None)
    b_f32 = probe_f32.slot_nbytes(bucket)
    b_bf16 = shared_be["proxy"].slot_nbytes(bucket)
    assert b_bf16 == b_f32 // 2                 # stored dtype is billed
    assert sst.prefix_hits > 0                  # docs aliased shared rows
    print(f"   prefix_hits={sst.prefix_hits} cow_copies={sst.cow_copies} "
          f"arena_bytes_peak={sst.arena_bytes_peak / 1e6:.1f}MB; "
          f"slot row {b_f32 / 1e6:.2f}MB f32 -> {b_bf16 / 1e6:.2f}MB bf16")
    print(f"   cost ${res_shared.cost * 1e3:.4f}m vs f32 private "
          f"${res.cost * 1e3:.4f}m (same-op ladders bill identically; "
          f"this cascade's op switches re-prefill)")

    print("8. telemetry: Perfetto trace of a two-tenant chaos run")
    # Telemetry is on by default at level="counters" (metric registry +
    # launch timeline, bitwise inert to the data plane); level="trace"
    # additionally records per-document span events — submit, every
    # launch ridden, escalations, retries, injected faults, quarantine,
    # the terminal state — into a bounded ring.  The Chrome trace-event
    # export lays launches (with their sched/host/dispatch/device
    # segments) on per-backend tracks and doc spans on per-query tracks.
    for be in backends.values():
        be.reset()
    traced = CascadeServer(backends, OPS, n_classes=2, batch_size=4,
                           retry=RetryPolicy(max_retries=2,
                                             backoff_base=0.0))
    traced.telemetry.level = "trace"
    FaultInjector(FaultPlan(seed=5, launch_failure_p=0.25, nan_p=0.2,
                            arena_loss_at=3)).install(traced)
    t_main = traced.register(cascade)
    t_strict = traced.register(strict)
    for k, d in enumerate(feed):
        t_main.submit(d, test_docs[d], arrival=float(k))
        t_strict.submit(d, test_docs[d], arrival=float(k))
    traced.drain()
    snap = traced.telemetry_snapshot()
    tl = snap["timeline"]
    trace_path = "serve_trace.json"
    write_chrome_trace(traced.telemetry, trace_path)
    print(f"   {snap['counters']['events_total']} span events over "
          f"{snap['spans']['checked']} doc spans, "
          f"{snap['counters']['launch_records']} launch records "
          f"({snap['counters']['failed_launch_records']} failed); "
          f"spans well-formed: {snap['spans']['ok']}")
    print(f"   wall decomposition: sched {1e3 * tl['sched_s']:.1f} ms | "
          f"host {1e3 * tl['host_s']:.1f} ms | dispatch "
          f"{1e3 * tl['dispatch_s']:.1f} ms | device "
          f"{1e3 * tl['device_s']:.1f} ms; mean launch gap "
          f"{tl['mean_launch_gap_ms']:.2f} ms")
    print(f"   wrote {trace_path} — open at https://ui.perfetto.dev "
          f"(one track per backend with launch+segment slices, one per "
          f"query with per-document span slices)")

    print("9. static analysis + sanitized chaos drain")
    # The repo-specific AST linter (rules RSA001-RSA005: jit signature
    # hygiene, Pallas conventions, donation safety, merge metadata,
    # wall-clock/RNG in jit — catalogue in ``repro.analysis.__doc__``)
    # gates the tree against the committed suppression baseline, and the
    # runtime arena sanitizer replays the chaos feed with every launch's
    # read/write row sets bracketed: slot-aliasing races, pinned-prefix
    # writes outside COW, and use-after-release raise ``ArenaRaceError``
    # instead of corrupting KV silently.  The sanitizer is host-side
    # shadow state only — preds/confs/$ are bitwise those of step 6.
    from repro.analysis import lint as rsa_lint
    rc = rsa_lint.main(["src/repro"])
    assert rc == 0, "linter found new violations (see output above)"
    for be in backends.values():
        be.reset()
        be.sanitize = True          # or ARENA_SANITIZE=1 in the env
        be._sanitizer = None
    sane = CascadeServer(backends, OPS, n_classes=2, batch_size=4,
                         retry=RetryPolicy(max_retries=2,
                                           backoff_base=0.0))
    FaultInjector(FaultPlan(seed=5, launch_failure_p=0.25, nan_p=0.2,
                            arena_loss_at=3)).install(sane)
    s_main = sane.register(cascade)
    for k, d in enumerate(feed):
        s_main.submit(d, test_docs[d], arrival=float(k))
    sane.drain()
    sans = [b._sanitizer for b in backends.values()
            if b._sanitizer is not None]
    checks = sum(s.checks for s in sans)
    assert checks > 0 and sum(s.violations for s in sans) == 0
    print(f"   linter clean vs baseline; sanitized chaos drain: "
          f"{checks} launch brackets, "
          f"{sum(s.rows_checked for s in sans)} row memberships, "
          f"0 violations")
    for be in backends.values():
        be.sanitize = None          # leave the demo backends env-driven

    print("10. overlapped dispatch: four launches in flight")
    # ``dispatch_group`` enqueues the jitted stage step WITHOUT blocking
    # (JAX async dispatch) and returns a ticket; the completion loop
    # calls ``block_until_ready`` only when the scheduler needs that
    # launch's confidences for stage routing.  Depth may only change
    # WHEN the host blocks, never what it computes — so the whole feed
    # replays bitwise against step 5's query while the gap between
    # consecutive enqueues collapses.
    overlap_res = {}
    for depth in (1, 4):
        for be in backends.values():
            be.reset()
        deep = CascadeServer(backends, OPS, n_classes=2, batch_size=4,
                             inflight=depth)
        h_deep = deep.register(cascade)
        for d in sorted(test_docs):
            h_deep.submit(d, test_docs[d], arrival=arrivals[d])
        deep.drain()
        overlap_res[depth] = (h_deep.result(), deep.telemetry_snapshot())
    r1, (rk, snapk) = overlap_res[1][0], overlap_res[4]
    assert rk.pred == r1.pred and rk.conf == r1.conf
    assert rk.doc_cost == r1.doc_cost
    tl1, tlk = overlap_res[1][1]["timeline"], snapk["timeline"]
    print(f"   max_inflight={snapk['server']['max_inflight']} "
          f"(window 4); preds/confs/$ bitwise equal to inflight=1")
    print(f"   overlap-hidden fraction "
          f"{tl1['overlap_hidden_frac']:.1%} -> "
          f"{tlk['overlap_hidden_frac']:.1%}; mean launch gap "
          f"{tl1['mean_launch_gap_ms']:.2f} ms -> "
          f"{tlk['mean_launch_gap_ms']:.2f} ms")

    print(f"done in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
