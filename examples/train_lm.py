"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoints -> crash -> elastic restart.

    PYTHONPATH=src python examples/train_lm.py               # ~2 min tiny run
    PYTHONPATH=src python examples/train_lm.py --full        # ~100M params

The default run proves the full loop on CPU: a small llama-family model
learns a synthetic pattern task (loss drops from ~6.2 to <4), checkpoints
every 50 steps, then we simulate a host failure — the driver restores the
latest checkpoint, the data pipeline fails the dead host's shards over to
survivors deterministically, and training resumes bit-exact.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.config import resolve
from repro.configs import get_config, get_reduced
from repro.checkpoint.checkpoint import Checkpointer
from repro.data.pipeline import DataPipeline, ShardPlan, SyntheticLMTask
from repro.models.model import LM
from repro.models.runtime import Runtime
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_loop import TrainConfig, TrainDriver, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (slow on CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt_dir", default=None)
    args = ap.parse_args()

    if args.full:
        cfg = get_reduced(args.arch, d_model=768, num_layers=12,
                          num_heads=12, num_kv_heads=4, d_ff=2048,
                          head_dim=64, vocab_size=50304, dtype="float32")
    else:
        cfg = get_reduced(args.arch, vocab_size=2048, dtype="float32",
                          num_layers=4, d_model=256, d_ff=512)
    rcfg = resolve(cfg, tp=1)
    model = LM(rcfg, Runtime(attn_impl="xla", remat=False))
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"arch={cfg.name} reduced: {n_params / 1e6:.1f}M params")

    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tc = TrainConfig(opt=OptimizerConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps))
    step = jax.jit(make_train_step(model, None, tc))

    task = SyntheticLMTask(vocab_size=cfg.vocab_size, seq_len=args.seq)
    plan = ShardPlan(n_shards=4, n_hosts=2, redundancy=2)
    pipe = DataPipeline(task, plan, host=0,
                        batch_per_shard=args.batch // 2)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    ck = Checkpointer(ckpt_dir, keep=3)
    driver = TrainDriver(step, checkpointer=ck, ckpt_every=50, log_every=20)

    half = args.steps // 2
    print(f"\n-- phase 1: train to step {half}, checkpointing --")
    params, opt, hist1 = driver.run(params, opt, iter(pipe), half)
    ck.wait()

    print("\n-- simulated failure: host 1 dies; restore latest checkpoint --")
    latest = ck.latest_step()
    restored = ck.restore(latest, {"params": params, "opt": opt})
    failover = pipe.with_failures([1])
    failover.step = latest
    print(f"restored step {latest}; host 0 now serves shards "
          f"{plan.shards_for_host(0, [1])} (was {plan.shards_for_host(0)})")

    print("\n-- phase 2: resume training after failover --")
    params, opt, hist2 = driver.run(
        restored["params"], restored["opt"], failover, args.steps,
        start_step=latest)

    losses = [l for _, l in hist1 + hist2]
    print(f"\nloss: first {losses[0]:.3f} -> last {losses[-1]:.3f} "
          f"({'DECREASED ok' if losses[-1] < losses[0] else 'NO PROGRESS'})")
    print(f"checkpoints kept: {ck.steps()} (dir {ckpt_dir})")


if __name__ == "__main__":
    main()
