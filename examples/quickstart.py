"""Quickstart: build a task cascade on a calibrated workload and compare
against the model-cascade baseline + oracle-only.

    PYTHONPATH=src python examples/quickstart.py [workload]
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.pipeline import (BuildConfig, build_task_cascade,
                                 evaluate_on, model_cascade)
from repro.core.simulation import WORKLOADS, make_workload


def main():
    name = sys.argv[1] if len(sys.argv) > 1 else "court"
    assert name in WORKLOADS, f"pick one of {list(WORKLOADS)}"
    w = make_workload(name, 1000)
    rng = np.random.default_rng(0)
    perm = rng.permutation(1000)
    dev, test = w.subset(perm[:200]), w.subset(perm[200:])

    print(f"== workload: {name} (dev 200 docs / test 800 docs) ==\n")
    oracle_cost = test.cost_model().oracle_only_cost()
    print(f"oracle-only cost:          ${oracle_cost:8.2f}")

    mc = evaluate_on(test, model_cascade(dev, alpha=0.9))
    print(f"2-model cascade:           ${mc['total_cost']:8.2f}   "
          f"acc {mc['accuracy']:.1%}")

    out = build_task_cascade(dev, BuildConfig(alpha=0.9, seed=0))
    tc = evaluate_on(test, out)
    print(f"task cascade:              ${tc['total_cost']:8.2f}   "
          f"acc {tc['accuracy']:.1%}   "
          f"({tc['total_cost'] / mc['total_cost']:.2f}x the model cascade)")

    print(f"\ncascade ({len(out.cascade.tasks)} tasks + oracle fallthrough):")
    for i, t in enumerate(out.cascade.tasks):
        m, o, f = t.config.key()
        ths = {c: round(v, 3) for c, v in t.thresholds.items()}
        print(f"  {i + 1}. {m:7s} op={o:24s} fraction={f:<5} thresholds={ths}")
    print(f"  {len(out.cascade.tasks) + 1}. oracle  op=o_orig "
          f"                  fraction=1.0   (terminal)")
    print(f"\ndocs escaping to the oracle: {tc['oracle_frac']:.1%}")


if __name__ == "__main__":
    main()
