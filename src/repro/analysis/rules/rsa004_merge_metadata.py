"""RSA004 — stats dataclasses must carry per-field merge metadata.

``ServeStats.merge_from`` dispatches on each field's declared merge
strategy (``scheduler._stat``: sum / max / concat / stage / shared).  A
field added without metadata would silently fall through to the default
strategy and corrupt multi-tenant aggregation — per-query stats are
merged into the server aggregate and into ``_departed`` on unregister.

The rule applies to every ``@dataclass`` that defines ``merge_from`` or
whose name ends in ``Stats``: each annotated field must be assigned a
``_stat(...)`` (the repo helper) or a ``field(...)`` whose ``metadata``
dict carries a ``"merge"`` key.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from . import _common as c

RULE_ID = "RSA004"
SUMMARY = ("dataclasses with merge_from (or *Stats names) must declare a "
           "merge strategy on every field")


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        name = c.dotted(dec if not isinstance(dec, ast.Call) else dec.func)
        if name in ("dataclass", "dataclasses.dataclass"):
            return True
    return False


def _has_merge_metadata(value: ast.AST) -> bool:
    if not isinstance(value, ast.Call):
        return False
    name = (c.dotted(value.func) or "").split(".")[-1]
    if name == "_stat":                     # scheduler helper: _stat(merge)
        return True
    if name == "field":
        meta = c.keyword(value, "metadata")
        if isinstance(meta, ast.Dict):
            return any(isinstance(k, ast.Constant) and k.value == "merge"
                       for k in meta.keys)
        return meta is not None             # dynamic metadata: trust it
    return False


def check(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Tuple[int, int, str]]:
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef) or not _is_dataclass(cls):
            continue
        has_merge = any(isinstance(n, c.FuncDef) and n.name == "merge_from"
                        for n in cls.body)
        if not (has_merge or cls.name.endswith("Stats")):
            continue
        for node in cls.body:
            if not isinstance(node, ast.AnnAssign) or \
                    not isinstance(node.target, ast.Name):
                continue
            fname = node.target.id
            if fname.startswith("_"):
                continue
            ann = c.dotted(node.annotation) or ""
            if ann.endswith("ClassVar"):
                continue
            if node.value is None or not _has_merge_metadata(node.value):
                yield (node.lineno, node.col_offset,
                       f"field {cls.name}.{fname} lacks merge metadata "
                       f"(use _stat(<strategy>) or field(metadata="
                       f"{{'merge': ...}}) so merge_from knows how to "
                       f"aggregate it)")
