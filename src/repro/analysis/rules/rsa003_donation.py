"""RSA003 — donation safety.

``jax.jit(..., donate_argnums=(k,))`` (and Pallas
``input_output_aliases``) invalidates the donated operand's buffer at
the call: reading the same Python expression afterwards — before it is
rebound — observes freed (or aliased-output) memory.  The engine's
sanctioned pattern rebinds immediately::

    logits, new_states = self._step(params, arena.states, ...)
    arena.states = new_states          # donated expr rebound first

This rule tracks three donation sources to the call sites and flags any
Load of a donated argument expression after the call and before its
rebinding, within the same function body:

  * direct ``g = jax.jit(f, donate_argnums=...)`` then ``g(...)``;
  * factory functions that *return* a donating ``jax.jit`` (including
    the ``kwargs["donate_argnums"] = ...; jax.jit(f, **kwargs)`` idiom)
    whose result is stored on an attribute (``self._step = self.
    _build_step()``) and called elsewhere in the module;
  * ``pl.pallas_call(..., input_output_aliases={k: j})(ops...)`` —
    operand ``k`` (offset by ``num_scalar_prefetch`` when a
    PrefetchScalarGridSpec is in scope) aliases an output.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from . import _common as c

RULE_ID = "RSA003"
SUMMARY = ("donated buffers (donate_argnums / input_output_aliases) must "
           "not be read after the donating call before rebinding")


def _const_positions(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """Evaluate a donate_argnums value if it is a literal int/tuple."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
            else:
                return None
        return tuple(out)
    return None


def _jit_donations(call: ast.Call, scope: ast.AST) -> Optional[Tuple[int, ...]]:
    """Donated positions of a jax.jit call, following the
    ``kwargs["donate_argnums"] = ...; jax.jit(f, **kwargs)`` idiom."""
    if not c._is_jit_name(c.dotted(call.func)):
        return None
    val = c.keyword(call, "donate_argnums")
    if val is not None:
        return _const_positions(val)
    starred = [kw.value for kw in call.keywords if kw.arg is None]
    for star in starred:
        if not isinstance(star, ast.Name):
            continue
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and \
                    isinstance(node.targets[0], ast.Subscript) and \
                    isinstance(node.targets[0].value, ast.Name) and \
                    node.targets[0].value.id == star.id and \
                    isinstance(node.targets[0].slice, ast.Constant) and \
                    node.targets[0].slice.value == "donate_argnums":
                return _const_positions(node.value)
    return None


def _donated_handles(tree: ast.AST) -> Dict[str, Tuple[int, ...]]:
    """Map callee last-segment name -> donated positions.

    Covers ``g = jax.jit(..)`` (name ``g``), ``self.attr = jax.jit(..)``
    (name ``attr``), and factory indirection: a function whose return
    value is a donating jit, stored via ``X = <...>.factory()``.
    """
    handles: Dict[str, Tuple[int, ...]] = {}
    factories: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if isinstance(node, c.FuncDef):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Return) and \
                        isinstance(sub.value, ast.Call):
                    pos = _jit_donations(sub.value, node)
                    if pos:
                        factories[node.name] = pos
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            pos = _jit_donations(node.value, tree)
            if pos:
                for t in node.targets:
                    name = t.id if isinstance(t, ast.Name) else (
                        t.attr if isinstance(t, ast.Attribute) else None)
                    if name:
                        handles[name] = pos
    # factory results: X = obj.factory()  /  X = factory()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fname = (c.dotted(node.value.func) or "").split(".")[-1]
            if fname in factories:
                for t in node.targets:
                    name = t.id if isinstance(t, ast.Name) else (
                        t.attr if isinstance(t, ast.Attribute) else None)
                    if name:
                        handles[name] = factories[fname]
    return handles


class _ExprUse(ast.NodeVisitor):
    """Ordered (kind, lineno, col) uses of a target expression inside one
    statement, loads-before-stores for Assign (RHS evaluates first)."""

    def __init__(self, expr: str):
        self.expr = expr
        self.uses: List[Tuple[str, int, int]] = []

    def _match(self, node: ast.AST) -> bool:
        try:
            return ast.unparse(node) == self.expr
        except Exception:
            return False

    def visit_Assign(self, node: ast.Assign) -> None:
        self.visit(node.value)
        for t in node.targets:
            self._visit_store_target(t)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self._match(node.target):        # aug-assign READS the target
            self.uses.append(("load", node.lineno, node.col_offset))
        self.visit(node.value)

    def _visit_store_target(self, t: ast.AST) -> None:
        if self._match(t):
            self.uses.append(("store", t.lineno, t.col_offset))
            return
        # a subscript/attribute store on a PREFIX of the expr still reads
        # the base object; a store to an unrelated target may still load
        # the expr on its index — walk children as loads
        for child in ast.iter_child_nodes(t):
            self.visit(child)

    def generic_visit(self, node: ast.AST) -> None:
        if self._match(node):
            ctx = getattr(node, "ctx", None)
            kind = "store" if isinstance(ctx, (ast.Store, ast.Del)) \
                else "load"
            self.uses.append((kind, node.lineno, node.col_offset))
            return                          # don't double-count children
        super().generic_visit(node)


def _stmts_after(body: List[ast.stmt], stmt: ast.stmt) -> List[ast.stmt]:
    for i, s in enumerate(body):
        if s is stmt or any(sub is stmt for sub in ast.walk(s)):
            return body[i + 1:]
    return []


def _check_call(call: ast.Call, positions: Tuple[int, ...],
                fn: ast.AST, stmt: ast.stmt
                ) -> Iterator[Tuple[int, int, str]]:
    callee = c.dotted(call.func) or "<call>"
    for pos in positions:
        if pos >= len(call.args):
            continue
        arg = call.args[pos]
        try:
            expr = ast.unparse(arg)
        except Exception:
            continue
        if isinstance(arg, ast.Constant):
            continue
        rebound = False
        for later in _stmts_after(fn.body, stmt):
            uses = _ExprUse(expr)
            uses.visit(later)
            for kind, line, col in uses.uses:
                if kind == "store":
                    rebound = True
                    break
                yield (line, col,
                       f"{expr!r} is donated to {callee}() "
                       f"(donate position {pos}) at line "
                       f"{call.lineno} and read here before being "
                       f"rebound — the buffer is invalid after "
                       f"donation")
            if rebound:
                break


def check(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Tuple[int, int, str]]:
    c.annotate_parents(tree)
    handles = _donated_handles(tree)

    for fn in ast.walk(tree):
        if not isinstance(fn, c.FuncDef):
            continue
        for stmt in fn.body:
            for call in ast.walk(stmt):
                if not isinstance(call, ast.Call):
                    continue
                # donated jit handle call:  self._step(...)
                last = (c.dotted(call.func) or "").split(".")[-1]
                if last in handles:
                    yield from _check_call(call, handles[last], fn, stmt)
                # immediate pallas_call alias:  pl.pallas_call(...)(a, b)
                if isinstance(call.func, ast.Call):
                    inner = call.func
                    nm = c.dotted(inner.func) or ""
                    if nm.endswith("pallas_call"):
                        alias = c.keyword(inner, "input_output_aliases")
                        if isinstance(alias, ast.Dict):
                            pos = tuple(
                                k.value for k in alias.keys
                                if isinstance(k, ast.Constant)
                                and isinstance(k.value, int))
                            if pos:
                                yield from _check_call(call, pos, fn, stmt)
