"""RSA005 — no wall-clock or host-RNG calls inside jitted/kernel bodies.

A jitted function body (or Pallas kernel) executes at TRACE time: a
``time.perf_counter()`` / ``np.random...`` / ``random...`` call inside
one evaluates once during tracing and is then a frozen constant in the
compiled step — timing that never ticks, randomness that never
re-samples, and a value that silently changes on every recompile.
Host-side timing belongs around the jitted call (the engine's
``host``/``dispatch``/``device`` segments); randomness inside traced
code must come from ``jax.random`` keys threaded as arguments.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from . import _common as c

RULE_ID = "RSA005"
SUMMARY = ("no time.*/datetime.*/np.random.*/random.* calls inside jitted "
           "or Pallas-kernel bodies (they freeze at trace time)")

_BANNED_EXACT = {
    "time.time", "time.perf_counter", "time.monotonic", "time.time_ns",
    "time.perf_counter_ns", "time.monotonic_ns", "time.process_time",
    "datetime.datetime.now", "datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today", "date.today",
}
_BANNED_PREFIX = ("np.random.", "numpy.random.", "random.")


def _banned(name: str) -> bool:
    return name in _BANNED_EXACT or \
        any(name.startswith(p) for p in _BANNED_PREFIX)


def check(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Tuple[int, int, str]]:
    bodies = [fn for fn, _ in c.jitted_functions(tree)]
    bodies += list(c.pallas_kernels(tree))
    seen = set()
    for fn in bodies:
        if id(fn) in seen:
            continue
        seen.add(id(fn))
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = c.dotted(node.func)
            if name and _banned(name):
                yield (node.lineno, node.col_offset,
                       f"{name}() inside jitted/kernel body {fn.name!r}: "
                       f"evaluates once at trace time and freezes into "
                       f"the compiled step (hoist to the host side, or "
                       f"thread jax.random keys / timestamps as "
                       f"arguments)")
