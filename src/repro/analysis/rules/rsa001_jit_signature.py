"""RSA001 — jit-signature hygiene.

Jitted stage steps retrace on every new static-argument *value* and on
every identity change of a captured Python object, so two patterns turn
into silent recompiles (or, worse, silently stale numerics when the
capture mutates in place):

  * a **mutable default argument** on a jitted function — the default's
    identity is baked into the trace, and in-place mutation after
    tracing never re-enters the compiled step;
  * **closure capture of mutable enclosing-scope state** (a list/dict/
    set built in the enclosing function, especially one that is mutated
    there) — the trace reads the capture once; later mutations are
    invisible, and rebinding forces a retrace per rebind.

The engine's sanctioned pattern captures only immutable handles
(``model = self.model``) and threads everything else through traced
arguments or hashable ``static_argnames``.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from . import _common as c

RULE_ID = "RSA001"
SUMMARY = ("jitted functions must not take mutable default args or close "
           "over mutable enclosing-scope state (silent recompiles / stale "
           "traces)")


def _bound_names(fn: ast.AST) -> set:
    """Names bound inside ``fn`` (params + assignments + imports)."""
    names = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        names.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, c.FuncDef) and node is not fn:
            names.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
    return names


def _mutated_names(scope: ast.AST) -> set:
    """Names mutated in-place in ``scope``: augassign, subscript store,
    or a mutating method call (append/extend/update/...)."""
    mutators = {"append", "extend", "insert", "update", "add", "pop",
                "popitem", "clear", "remove", "setdefault", "discard"}
    out = set()
    for node in ast.walk(scope):
        if isinstance(node, ast.AugAssign) and \
                isinstance(node.target, ast.Name):
            out.add(node.target.id)
        elif isinstance(node, ast.Subscript) and \
                isinstance(node.ctx, (ast.Store, ast.Del)) and \
                isinstance(node.value, ast.Name):
            out.add(node.value.id)
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in mutators and \
                isinstance(node.func.value, ast.Name):
            out.add(node.func.value.id)
    return out


def check(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Tuple[int, int, str]]:
    c.annotate_parents(tree)
    for fn, _jit in c.jitted_functions(tree):
        # (a) mutable default arguments
        defaults = list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            if c.is_mutable_value(d):
                yield (d.lineno, d.col_offset,
                       f"jitted function {fn.name!r} has a mutable "
                       f"default argument (identity is baked into the "
                       f"trace; mutation never re-enters the step)")
        # (b) closure capture of mutable enclosing-scope bindings
        enclosing = c.enclosing_functions(fn)
        if not enclosing:
            continue
        bound = _bound_names(fn)
        mutable_outer = {}
        mutated_outer = set()
        for scope in enclosing:
            mutated_outer |= _mutated_names(scope)
            for node in ast.walk(scope):
                if isinstance(node, ast.Assign) and \
                        c.is_mutable_value(node.value):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            mutable_outer[t.id] = node.lineno
        reported = set()
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)):
                continue
            name = node.id
            if name in bound or name in reported:
                continue
            if name in mutable_outer and name in mutated_outer:
                reported.add(name)
                yield (node.lineno, node.col_offset,
                       f"jitted function {fn.name!r} closes over "
                       f"{name!r}, a mutable container built at line "
                       f"{mutable_outer[name]} and mutated in the "
                       f"enclosing scope (the trace will not see the "
                       f"mutations)")
