"""Rule registry for the RSA linter (see ``repro.analysis.__doc__`` for
the full catalogue with violating examples).

A rule is a module exposing ``RULE_ID``, ``SUMMARY``, and
``check(tree, lines, path) -> Iterator[(line, col, message)]``.  The
driver (:mod:`repro.analysis.lint`) owns baseline matching and inline
suppression; rules just report.
"""
from __future__ import annotations

from . import (rsa001_jit_signature, rsa002_pallas_conventions,
               rsa003_donation, rsa004_merge_metadata, rsa005_wallclock)

ALL_RULES = (
    rsa001_jit_signature,
    rsa002_pallas_conventions,
    rsa003_donation,
    rsa004_merge_metadata,
    rsa005_wallclock,
)

RULE_IDS = tuple(r.RULE_ID for r in ALL_RULES)
