"""RSA002 — Pallas kernel conventions.

Three conventions the repo's scalar-prefetch kernels rely on:

  * **BlockSpec index maps must be pure index arithmetic** — no
    ``jnp.``/``jax.lax.`` calls.  An index map runs at block-dispatch
    time; a traced op inside it either fails to lower or silently
    materializes per-block work the grid cost model never sees.
  * **Scalar-prefetch operands come first** in the kernel signature:
    under ``PrefetchScalarGridSpec(num_scalar_prefetch=N, ...)`` the
    first ``N`` kernel parameters are the SMEM scalar refs (slot ids,
    kv lengths, block tables) — an array ref (``q_ref``/``k_ref``/...)
    in those positions means the kernel is reading SMEM scalars as
    VMEM blocks.
  * **Grid dims are derived, not literal**: a hard-coded grid extent
    (``grid=(8, ...)``) silently truncates or over-runs when block
    shapes change; extents must come from block-shape divisibility
    (``S // block_kv``) or operand shapes.
"""
from __future__ import annotations

import ast
from typing import Iterator, List, Tuple

from . import _common as c

RULE_ID = "RSA002"
SUMMARY = ("BlockSpec index maps pure, scalar-prefetch refs declared "
           "before array refs, grid dims derived from block-shape "
           "divisibility (not integer literals)")

_TRACED_ROOTS = ("jnp", "jax", "lax", "np", "numpy")
_ARRAYISH_PARAMS = {"q_ref", "k_ref", "v_ref", "o_ref", "x_ref", "y_ref",
                    "acc_ref", "m_ref", "l_ref", "out_ref", "lhs_ref",
                    "rhs_ref"}


def _index_map_bodies(tree: ast.AST):
    """(callable_node, where) for every BlockSpec index map: lambdas /
    named local functions passed to ``pl.BlockSpec`` positionally or as
    ``index_map=``."""
    defs = c.defs_by_name(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = c.dotted(node.func) or ""
        if not name.endswith("BlockSpec"):
            continue
        cands = list(node.args[1:2])
        km = c.keyword(node, "index_map")
        if km is not None:
            cands.append(km)
        for cand in cands:
            if isinstance(cand, ast.Lambda):
                yield cand, "lambda"
            elif isinstance(cand, ast.Name):
                for fn in defs.get(cand.id, []):
                    yield fn, fn.name


def _flag_traced_ops(body: ast.AST, where: str
                     ) -> Iterator[Tuple[int, int, str]]:
    for node in ast.walk(body):
        if isinstance(node, ast.Call):
            name = c.dotted(node.func)
            if name and name.split(".")[0] in _TRACED_ROOTS:
                yield (node.lineno, node.col_offset,
                       f"traced op {name}() inside BlockSpec index map "
                       f"({where}); index maps must be pure index "
                       f"arithmetic")


def _grid_literals(call: ast.Call) -> Iterator[Tuple[int, int, str]]:
    grid = c.keyword(call, "grid")
    if not isinstance(grid, (ast.Tuple, ast.List)):
        return
    for dim in grid.elts:
        if isinstance(dim, ast.Constant) and isinstance(dim.value, int) \
                and dim.value > 1:
            yield (dim.lineno, dim.col_offset,
                   f"grid dim is the integer literal {dim.value}; derive "
                   f"it from block-shape divisibility (e.g. S // block) "
                   f"so block-size changes cannot desynchronize the grid")


def check(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Tuple[int, int, str]]:
    # (a) index-map purity
    seen = set()
    for body, where in _index_map_bodies(tree):
        if id(body) in seen:
            continue
        seen.add(id(body))
        yield from _flag_traced_ops(body, where)

    # (b) scalar-prefetch ordering + (c) literal grid dims
    kernels = list(c.pallas_kernels(tree))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = c.dotted(node.func) or ""
        if name.endswith("PrefetchScalarGridSpec"):
            yield from _grid_literals(node)
            n_pref = c.keyword(node, "num_scalar_prefetch")
            if isinstance(n_pref, ast.Constant) and \
                    isinstance(n_pref.value, int):
                n = n_pref.value
                for fn in kernels:
                    params = [a.arg for a in fn.args.args][:n]
                    bad = [p for p in params if p in _ARRAYISH_PARAMS]
                    if bad:
                        yield (fn.lineno, fn.col_offset,
                               f"kernel {fn.name!r}: array ref(s) "
                               f"{bad} among the first "
                               f"{n} parameters, which are the "
                               f"scalar-prefetch SMEM refs "
                               f"(num_scalar_prefetch={n}) — declare "
                               f"scalar refs before array refs")
        elif name.endswith("pallas_call"):
            yield from _grid_literals(node)
