"""Shared AST helpers for the RSA rules.

Everything here is pure-AST (no imports of the linted code): rules must
run on any checkout without executing it.  Resolution is heuristic by
design — a name passed to ``jax.jit`` is looked up among the function
definitions of the same module — and rules should prefer false
negatives over false positives (the baseline absorbs judgement calls,
it should not absorb noise).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

FuncDef = (ast.FunctionDef, ast.AsyncFunctionDef)


def dotted(node: ast.AST) -> Optional[str]:
    """``jax.lax.dot_general`` for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def annotate_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._rsa_parent = node            # type: ignore[attr-defined]


def enclosing_functions(node: ast.AST) -> List[ast.AST]:
    """Enclosing FunctionDefs, innermost first (requires
    ``annotate_parents``)."""
    out = []
    cur = getattr(node, "_rsa_parent", None)
    while cur is not None:
        if isinstance(cur, FuncDef):
            out.append(cur)
        cur = getattr(cur, "_rsa_parent", None)
    return out


def defs_by_name(tree: ast.AST) -> Dict[str, List[ast.AST]]:
    out: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, FuncDef):
            out.setdefault(node.name, []).append(node)
    return out


def _is_jit_name(name: Optional[str]) -> bool:
    return name in ("jax.jit", "jit", "pjit", "jax.pjit")


def jit_target(call: ast.Call) -> Optional[ast.AST]:
    """The function expression a ``jax.jit(...)`` call wraps, or None."""
    if _is_jit_name(dotted(call.func)) and call.args:
        return call.args[0]
    return None


def is_partial_of_jit(call: ast.Call) -> bool:
    name = dotted(call.func)
    return (name in ("functools.partial", "partial") and call.args
            and _is_jit_name(dotted(call.args[0])))


def scoped_defs(scope: ast.AST) -> Dict[str, ast.AST]:
    """FunctionDefs bound as bare names in ``scope``'s namespace: direct
    children, descending through control flow but NOT into nested
    function/class bodies (those bind in inner/attribute namespaces)."""
    out: Dict[str, ast.AST] = {}

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, FuncDef):
                out[child.name] = child
            elif isinstance(child, (ast.ClassDef, ast.Lambda)):
                continue
            else:
                walk(child)

    walk(scope)
    return out


def resolve_local(name_node: ast.AST, at: ast.AST,
                  _depth: int = 0) -> List[ast.AST]:
    """Resolve a function expression to FunctionDefs using LEXICAL scope
    at ``at`` (requires ``annotate_parents``).  Follows one level of
    aliasing through assignments (``step = a if cond else b``)."""
    if _depth > 2:
        return []
    if isinstance(at, ast.IfExp) or isinstance(name_node, ast.IfExp):
        node = name_node if isinstance(name_node, ast.IfExp) else at
        return (resolve_local(node.body, at, _depth + 1)
                + resolve_local(node.orelse, at, _depth + 1))
    if not isinstance(name_node, ast.Name):
        return []
    name = name_node.id
    scopes = enclosing_functions(at)
    # module scope last
    top = at
    while getattr(top, "_rsa_parent", None) is not None:
        top = top._rsa_parent                   # type: ignore[attr-defined]
    scopes = scopes + [top]
    for scope in scopes:
        defs = scoped_defs(scope)
        if name in defs:
            return [defs[name]]
        # nearest assignment alias: step = paged_step if c else gather_step
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == name
                    for t in node.targets):
                return resolve_local(node.value, node, _depth + 1)
    return []


def jitted_functions(tree: ast.AST) -> Iterator[Tuple[ast.AST, ast.Call]]:
    """Yield (FunctionDef, jit Call) pairs: decorated functions and
    functions referenced in a ``jax.jit(f, ...)`` call, resolved through
    the call site's LEXICAL scope (requires ``annotate_parents`` — the
    driver rules call it first)."""
    annotate_parents(tree)
    seen = set()
    for node in ast.walk(tree):
        if isinstance(node, FuncDef):
            for dec in node.decorator_list:
                if _is_jit_name(dotted(dec)):
                    if id(node) not in seen:
                        seen.add(id(node))
                        yield node, ast.Call(func=dec, args=[], keywords=[])
                elif isinstance(dec, ast.Call) and (
                        _is_jit_name(dotted(dec.func))
                        or is_partial_of_jit(dec)):
                    if id(node) not in seen:
                        seen.add(id(node))
                        yield node, dec
        elif isinstance(node, ast.Call):
            target = jit_target(node)
            if target is not None:
                for fn in resolve_local(target, node):
                    if id(fn) not in seen:
                        seen.add(id(fn))
                        yield fn, node


def pallas_kernels(tree: ast.AST) -> Iterator[ast.AST]:
    """FunctionDefs passed (possibly through ``functools.partial``) as
    the kernel argument of a ``pl.pallas_call(...)``."""
    defs = defs_by_name(tree)
    seen = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func) or ""
        if not name.endswith("pallas_call"):
            continue
        if not node.args:
            continue
        kern = node.args[0]
        if isinstance(kern, ast.Call):          # functools.partial(kernel, .)
            if dotted(kern.func) in ("functools.partial", "partial") \
                    and kern.args:
                kern = kern.args[0]
        if isinstance(kern, ast.Name):
            for fn in defs.get(kern.id, []):
                if id(fn) not in seen:
                    seen.add(id(fn))
                    yield fn
        elif isinstance(kern, FuncDef):
            if id(kern) not in seen:
                seen.add(id(kern))
                yield kern


def keyword(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


MUTABLE_DISPLAYS = (ast.List, ast.Dict, ast.Set, ast.ListComp, ast.DictComp,
                    ast.SetComp)
MUTABLE_FACTORIES = ("list", "dict", "set", "collections.defaultdict",
                     "defaultdict", "collections.OrderedDict",
                     "OrderedDict", "collections.deque", "deque",
                     "bytearray")


def is_mutable_value(node: ast.AST) -> bool:
    if isinstance(node, MUTABLE_DISPLAYS):
        return True
    if isinstance(node, ast.Call):
        return dotted(node.func) in MUTABLE_FACTORIES
    return False
