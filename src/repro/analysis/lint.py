"""AST linter driver for the repo-specific RSA rules.

``python -m repro.analysis [paths...]`` parses every ``*.py`` file under
the given paths (default: the ``repro`` package itself), runs each rule
in :mod:`repro.analysis.rules`, and diffs the findings against the
committed suppression baseline (``analysis/baseline.json``):

  * a finding **not** in the baseline is NEW -> printed, exit 1;
  * a baseline entry matching no finding is STALE (the violation was
    fixed — shrink the baseline) -> printed, exit 1;
  * otherwise exit 0.

Baseline entries are keyed by ``(rule, file, stripped line text)`` — not
line numbers — so unrelated edits that shift code do not invalidate the
baseline, while editing the flagged line itself surfaces the finding
again.  Every entry carries a one-line ``reason``.  Inline suppression:
a ``# lint: disable=RSA00X`` comment on the flagged line (``--list``
shows suppressed findings too).

Exit codes: 0 clean, 1 findings/stale baseline, 2 usage error.
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from .rules import ALL_RULES

_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=([A-Z0-9,\s]+)")
_PKG_ROOT = Path(__file__).resolve().parents[1]          # src/repro
_DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")


@dataclass(frozen=True)
class Finding:
    rule: str
    file: str            # posix path relative to the scanned root
    line: int            # 1-indexed
    col: int
    message: str
    line_text: str       # stripped source of the flagged line (baseline key)

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.file, self.line_text)

    def format(self) -> str:
        return f"{self.file}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


def _inline_suppressed(line_text: str, rule: str) -> bool:
    m = _DISABLE_RE.search(line_text)
    if not m:
        return False
    ids = {tok.strip() for tok in m.group(1).split(",")}
    return rule in ids or "ALL" in ids


def lint_source(src: str, rel_path: str) -> List[Finding]:
    """Run every rule over one file's source; returns findings with
    inline-suppressed ones already removed."""
    try:
        tree = ast.parse(src)
    except SyntaxError as exc:
        return [Finding("RSA000", rel_path, exc.lineno or 0, 0,
                        f"syntax error: {exc.msg}", "")]
    lines = src.splitlines()
    findings: List[Finding] = []
    for rule in ALL_RULES:
        for line, col, message in rule.check(tree, lines, rel_path):
            text = lines[line - 1].strip() if 0 < line <= len(lines) else ""
            if _inline_suppressed(text, rule.RULE_ID):
                continue
            findings.append(Finding(rule.RULE_ID, rel_path, line, col,
                                    message, text))
    findings.sort(key=lambda f: (f.file, f.line, f.col, f.rule))
    return findings


def iter_py_files(paths: Sequence[Path]) -> List[Tuple[Path, str]]:
    """Expand paths to (file, rel_name) pairs.  rel_name is relative to
    the directory argument the file came from (stable across checkouts),
    or the bare file name for file arguments."""
    out: List[Tuple[Path, str]] = []
    for p in paths:
        p = p.resolve()
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                out.append((f, f.relative_to(p).as_posix()))
        else:
            out.append((p, p.name))
    return out


def lint_paths(paths: Sequence[Path]) -> List[Finding]:
    findings: List[Finding] = []
    for f, rel in iter_py_files(paths):
        findings.extend(lint_source(f.read_text(), rel))
    return findings


# ---------------------------------------------------------------- baseline
def load_baseline(path: Path) -> List[Dict[str, str]]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    assert isinstance(data, dict) and "suppressions" in data, \
        f"{path}: baseline must be {{'suppressions': [...]}}"
    return data["suppressions"]


def save_baseline(path: Path, findings: Sequence[Finding],
                  reasons: Optional[Dict[Tuple[str, str, str], str]] = None
                  ) -> None:
    entries = []
    for f in findings:
        reason = (reasons or {}).get(f.key, "TODO: document this suppression")
        entries.append({"rule": f.rule, "file": f.file,
                        "line_text": f.line_text, "reason": reason})
    path.write_text(json.dumps(
        {"version": 1,
         "comment": "suppression baseline for `python -m repro.analysis`; "
                    "keys are (rule, file, stripped line text) so line "
                    "drift does not invalidate entries",
         "suppressions": entries}, indent=2) + "\n")


def diff_baseline(findings: Sequence[Finding],
                  baseline: Sequence[Dict[str, str]]
                  ) -> Tuple[List[Finding], List[Dict[str, str]], int]:
    """Returns (new findings, stale baseline entries, suppressed count)."""
    keys = {(e["rule"], e["file"], e["line_text"]): False for e in baseline}
    new: List[Finding] = []
    suppressed = 0
    for f in findings:
        if f.key in keys:
            keys[f.key] = True
            suppressed += 1
        else:
            new.append(f)
    stale = [e for e in baseline
             if not keys[(e["rule"], e["file"], e["line_text"])]]
    return new, stale, suppressed


# --------------------------------------------------------------------- CLI
def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific AST linter (rules RSA001-RSA005; "
                    "see repro.analysis.__doc__ for the catalogue)")
    ap.add_argument("paths", nargs="*", type=Path,
                    help=f"files/directories to lint "
                         f"(default: {_PKG_ROOT})")
    ap.add_argument("--baseline", type=Path, default=_DEFAULT_BASELINE,
                    help="suppression baseline JSON (default: the "
                         "committed analysis/baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from the current findings "
                         "(preserves reasons of surviving entries)")
    ap.add_argument("--list", action="store_true", dest="list_all",
                    help="also list baseline-suppressed findings")
    args = ap.parse_args(argv)

    paths = args.paths or [_PKG_ROOT]
    for p in paths:
        if not p.exists():
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2

    findings = lint_paths(paths)
    baseline = [] if args.no_baseline else load_baseline(args.baseline)
    new, stale, suppressed = diff_baseline(findings, baseline)

    if args.write_baseline:
        old_reasons = {(e["rule"], e["file"], e["line_text"]): e["reason"]
                       for e in baseline}
        save_baseline(args.baseline, findings, old_reasons)
        print(f"wrote {args.baseline} ({len(findings)} suppression(s))")
        return 0

    if args.list_all and suppressed:
        print(f"{suppressed} baseline-suppressed finding(s):")
        keys = {(e["rule"], e["file"], e["line_text"]) for e in baseline}
        for f in findings:
            if f.key in keys:
                print(f"  [baseline] {f.format()}")
    for f in new:
        print(f.format())
    for e in stale:
        print(f"stale baseline entry (violation fixed — remove it): "
              f"{e['rule']} {e['file']}: {e['line_text']!r}")
    if new or stale:
        print(f"\n{len(new)} new finding(s), {len(stale)} stale baseline "
              f"entr(ies); {suppressed} suppressed by "
              f"{args.baseline.name}")
        return 1
    print(f"analysis clean: {len(findings)} finding(s), all covered by "
          f"{args.baseline.name}" if findings else
          "analysis clean: no findings")
    return 0
