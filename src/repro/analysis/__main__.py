"""``python -m repro.analysis`` — run the RSA linter (see lint.py)."""
import sys

from .lint import main

if __name__ == "__main__":
    sys.exit(main())
