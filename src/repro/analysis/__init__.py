"""Serving-plane static analysis + runtime arena sanitizer.

Two halves, one correctness discipline: the paper guarantees cascade
*accuracy* within an error budget; this subsystem guarantees the data
plane that serves the cascades — slot arenas, block tables, donated
buffers, scalar-prefetch kernels — by making invariant violations CI
failures instead of silent wrong answers.

Static pass (``python -m repro.analysis``)
==========================================
AST linter over ``src/repro/`` with repo-specific rules, gated in CI
against the committed suppression baseline ``analysis/baseline.json``
(new findings and stale suppressions both fail).  Suppress a finding
either with a baseline entry (one-line ``reason`` required) or inline
with ``# lint: disable=RSA00X`` on the flagged line.

Rule catalogue
--------------
**RSA001 — jit-signature hygiene.**  Jitted stage steps must not take
mutable default arguments or close over mutable enclosing-scope state:
the capture's identity freezes into the trace (silent recompiles,
stale numerics).  Minimal violation::

    def build():
        memo = []                      # mutable, mutated below
        def step(x):
            return x + len(memo)       # RSA001: closure over memo
        memo.append(1)
        return jax.jit(step)

Fix: thread values as traced args or hashable ``static_argnames``;
capture only immutable handles (``model = self.model``).

**RSA002 — Pallas kernel conventions.**  (a) BlockSpec index maps must
be pure index arithmetic — no ``jnp.``/``jax.lax.`` calls; (b) under
``PrefetchScalarGridSpec(num_scalar_prefetch=N)`` the first ``N``
kernel parameters are SMEM scalar refs — array refs (``q_ref`` etc.)
must come after; (c) grid dims must be derived (``S // block_kv``),
not integer literals.  Minimal violations::

    pl.BlockSpec((1, b), lambda i, j: (jnp.mod(i, 4), j))   # RSA002a
    def kernel(q_ref, slots_ref, o_ref): ...                # RSA002b (N=1)
    pltpu.PrefetchScalarGridSpec(num_scalar_prefetch=1,
                                 grid=(4, 8))               # RSA002c

**RSA003 — donation safety.**  An argument donated through
``jax.jit(..., donate_argnums=...)`` or aliased through Pallas
``input_output_aliases`` is INVALID after the call; reading the same
expression before rebinding it observes freed memory.  Minimal
violation::

    step = jax.jit(f, donate_argnums=(0,))
    out = step(state, x)
    debug = state.sum()        # RSA003: donated `state` read after call
    state = out                # (rebinding first would be the fix)

**RSA004 — merge metadata on stats dataclasses.**  Any ``@dataclass``
defining ``merge_from`` (or named ``*Stats``) must declare a merge
strategy on every field (``scheduler._stat(...)`` or
``field(metadata={"merge": ...})``), else multi-tenant aggregation
silently mis-merges the new field.  Minimal violation::

    @dataclass
    class ServeStats:
        launches: int = 0      # RSA004: no merge strategy
        def merge_from(self, src): ...

**RSA005 — no wall-clock/RNG in jitted or kernel bodies.**
``time.*``, ``datetime.*``, ``np.random.*``, ``random.*`` inside a
jitted function or Pallas kernel evaluate once at trace time and
freeze into the compiled step.  Minimal violation::

    @jax.jit
    def step(x):
        return x * np.random.rand()    # RSA005: frozen at trace time

(``jax.random`` with threaded keys is the sanctioned source.)

Runtime half (``analysis/sanitizer.py``)
========================================
:class:`~repro.analysis.sanitizer.ArenaSanitizer` — per-row ownership
epochs over the KV arenas, active under ``ARENA_SANITIZE=1`` (or
``LMBackend.sanitize=True``).  Launches register read/write row sets;
overlapping in-flight writes, writes to pinned prefix rows outside the
COW path, and use-after-release raise
:class:`~repro.analysis.sanitizer.ArenaRaceError` naming rows, launch
signatures, and owning doc/query ids.  This is the gate ROADMAP item
2's overlapped dispatch must keep green before ``block_until_ready``
can be deferred.  The sanitizer is bitwise-inert: no device arrays, no
RNG, and its ``serve_sanitizer_checks_total`` counters live on a
private registry so the telemetry hub's gated series are unchanged.
"""
from __future__ import annotations

from .sanitizer import ArenaRaceError, ArenaSanitizer, env_enabled

__all__ = ["ArenaRaceError", "ArenaSanitizer", "env_enabled"]
