"""Runtime arena sanitizer: per-row ownership epochs for the KV arenas.

ROADMAP item 2 (ahead-of-time dispatch, K launches in flight, donated
arena buffers) rests on the claim that in-flight launches touching
disjoint arena rows cannot alias.  The serving engine today is
synchronous, so the claim is vacuously true — and therefore unchecked.
This module makes it checkable: when sanitizing is on
(``ARENA_SANITIZE=1`` in the environment, or ``LMBackend.sanitize=True``)
every launch registers its read/write row sets before dispatch
(``begin_launch``) and withdraws them after the device sync
(``end_launch``); slot lifecycle events (alloc / release / pin / unpin /
bucket retirement) keep a host-side shadow of row ownership.  Any of the
following raises :class:`ArenaRaceError` with both launch signatures,
the overlapping rows, and the owning doc/query ids:

  * overlapping in-flight **write/write** or **write/read** row sets;
  * a **write to a pinned** refcounted prefix row outside the COW path
    (``cow()`` context — prefix prefill and partial-block copies);
  * **use-after-release**: a launch addressing a row that is FREE, or a
    row of a retired bucket.

Row states::

    FREE --note_alloc--> LIVE --note_pin--> PINNED
      ^                   |  ^                 |
      +---note_release----+  +---note_unpin----+

``note_retire(bucket)`` drops every row of the bucket (the arena pytree
is gone); later references diagnose as use-after-retire.  Each
transition bumps the row's **epoch**, so a stale ticket naming a
recycled row is distinguishable from the row's new owner in the
diagnostic.

Inertness contract: the sanitizer is pure host-side Python over ids the
engine already computes — it never touches device arrays, RNG streams,
or the shared :class:`~repro.serving.telemetry.Telemetry` registry on
the clean path.  Its ``serve_sanitizer_checks_total`` /
``serve_sanitizer_rows_checked_total`` counters live on a private
per-sanitizer registry (``counters()``) and are mirrored into
``ServeStats.sanitizer_checks`` by the server, precisely so the hub's
metric series (gated exactly by ``benchmarks/check_regression.py``)
stay bitwise identical with sanitizing on or off.  Only a *violation*
(which aborts the launch anyway) emits into the hub: a
``serve_sanitizer_violations_total`` count plus an ``EV_SANITIZER``
span event per owning request when tracing.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, FrozenSet, Iterable, List, Optional,
                    Set, Tuple)

FREE = "free"
LIVE = "live"
PINNED = "pinned"


class ArenaRaceError(RuntimeError):
    """A launch's registered row sets violate arena ownership.

    Carries structured diagnostics beside the message: ``rows`` (the
    conflicting arena rows), ``bucket``, ``kind`` (``overlap`` /
    ``pinned_write`` / ``use_after_release`` / ``double_alloc`` /
    ``unregistered_rows``), and ``signatures`` (the launch signatures
    involved — two for overlaps, one otherwise).
    """

    def __init__(self, message: str, *, kind: str, bucket: Optional[int],
                 rows: Iterable[int], signatures: Tuple[Any, ...] = ()):
        super().__init__(message)
        self.kind = kind
        self.bucket = bucket
        self.rows = sorted(set(int(r) for r in rows))
        self.signatures = signatures


@dataclass
class _Row:
    state: str = FREE
    owner: Optional[int] = None     # doc id (server rid; < 0 = prefix row)
    op: Optional[str] = None        # pinning op for PINNED rows
    epoch: int = 0                  # bumped on every state transition


@dataclass
class _Ticket:
    launch_id: int
    bucket: int
    signature: Any
    reads: FrozenSet[int]
    writes: FrozenSet[int]
    scratch: Optional[int]


@dataclass
class ArenaSanitizer:
    """Shadow ownership tracker for one backend's bucket arenas."""

    backend: str = ""
    # optional diagnostics callback: doc id -> {"query": qid, "doc": ext}
    # (the CascadeServer installs one so races name the owning tenant)
    doc_info: Optional[Callable[[int], Any]] = None
    telemetry: Any = None           # violation reporting only (see module doc)
    checks: int = 0                 # launches bracketed (cumulative)
    rows_checked: int = 0           # row memberships validated (cumulative)
    kernel_checks: int = 0          # eager kernel-wrapper row sets validated
    violations: int = 0
    inflight_peak: int = 0          # max simultaneously-open brackets seen
    _rows: Dict[int, Dict[int, _Row]] = field(default_factory=dict)
    _retired: Set[int] = field(default_factory=set)
    _inflight: Dict[int, _Ticket] = field(default_factory=dict)
    _cow_depth: Dict[int, int] = field(default_factory=dict)
    _next_launch: int = 0

    # ------------------------------------------------------------ lifecycle
    def reset(self) -> None:
        """Forget all row state (arenas were reset); counters survive."""
        assert not self._inflight, \
            "sanitizer reset with launches in flight"
        self._rows.clear()
        self._retired.clear()
        self._cow_depth.clear()

    def counters(self) -> Dict[str, int]:
        """Private metric registry (kept OFF the shared telemetry hub so
        the hub's gated series are identical with sanitizing on/off)."""
        return {
            "serve_sanitizer_checks_total": self.checks,
            "serve_sanitizer_rows_checked_total": self.rows_checked,
            "serve_sanitizer_kernel_checks_total": self.kernel_checks,
            "serve_sanitizer_violations_total": self.violations,
            "serve_sanitizer_inflight_peak": self.inflight_peak,
        }

    def _bucket(self, bucket: int) -> Dict[int, _Row]:
        return self._rows.setdefault(bucket, {})

    def _row(self, bucket: int, row: int) -> _Row:
        return self._bucket(bucket).setdefault(row, _Row())

    # --------------------------------------------------- slot state changes
    def note_alloc(self, bucket: int, row: int, doc_id: int) -> None:
        """A slot was issued to ``doc_id`` (FREE -> LIVE)."""
        self._retired.discard(bucket)       # bucket is in use again
        r = self._row(bucket, row)
        if r.state != FREE:
            self._raise(
                "double_alloc", bucket, [row],
                f"row {row} issued to doc {doc_id} while {r.state} "
                f"(owner {self._owner_str(r)}, epoch {r.epoch})")
        r.state, r.owner, r.op = LIVE, doc_id, None
        r.epoch += 1

    def note_clear(self, bucket: int, row: int) -> None:
        """``BucketArena.clear_slot``: a row is being recycled.  Legal on
        FREE/LIVE rows that no in-flight launch holds; clearing a PINNED
        row or an in-flight row is a race."""
        r = self._bucket(bucket).get(row)
        if r is not None and r.state == PINNED:
            self._raise(
                "pinned_write", bucket, [row],
                f"row {row} (pinned for op {r.op!r}) cleared for reuse "
                f"while still a shared prefix row")
        holders = [t for t in self._inflight.values()
                   if t.bucket == bucket and
                   (row in t.reads or row in t.writes)]
        if holders:
            t = holders[0]
            self._raise(
                "overlap", bucket, [row],
                f"row {row} cleared while launch #{t.launch_id} "
                f"sig={t.signature!r} is in flight over it",
                signatures=(t.signature,))

    def note_release(self, bucket: int, row: int) -> None:
        """A document's slot returned to the free list (LIVE -> FREE)."""
        r = self._bucket(bucket).get(row)
        if r is None or r.state == FREE:
            self._raise(
                "use_after_release", bucket, [row],
                f"row {row} released twice (already free)")
        if r.state == PINNED:
            self._raise(
                "pinned_write", bucket, [row],
                f"row {row} released while pinned for op {r.op!r} "
                f"(unpin first)")
        self.note_clear(bucket, row)        # must not be in flight either
        r.state, r.owner, r.op = FREE, None, None
        r.epoch += 1

    def note_pin(self, bucket: int, row: int, op_id: str) -> None:
        """A LIVE row became a shared (refcounted) op-prefix row."""
        r = self._row(bucket, row)
        if r.state != LIVE:
            self._raise(
                "pinned_write", bucket, [row],
                f"row {row} pinned for op {op_id!r} while {r.state}")
        r.state, r.op = PINNED, op_id
        r.epoch += 1

    def note_unpin(self, bucket: int, row: int) -> None:
        """A prefix row's memo was dropped (PINNED -> LIVE; the caller
        releases the backing slot next)."""
        r = self._bucket(bucket).get(row)
        if r is None or r.state != PINNED:
            state = "unknown" if r is None else r.state
            self._raise(
                "use_after_release", bucket, [row],
                f"row {row} unpinned while {state}")
        r.state, r.op = LIVE, None
        r.epoch += 1

    def note_retire(self, bucket: int) -> None:
        """The bucket's arena pytree was dropped; every row dies with it."""
        for t in self._inflight.values():
            if t.bucket == bucket:
                self._raise(
                    "overlap", bucket, sorted(t.reads | t.writes),
                    f"bucket {bucket} retired while launch "
                    f"#{t.launch_id} sig={t.signature!r} is in flight",
                    signatures=(t.signature,))
        self._rows.pop(bucket, None)
        self._retired.add(bucket)

    @contextmanager
    def cow(self, bucket: int):
        """Legal-write window for pinned rows: op-prefix prefill and the
        partial-block copy-on-write read both happen inside this."""
        self._cow_depth[bucket] = self._cow_depth.get(bucket, 0) + 1
        try:
            yield self
        finally:
            self._cow_depth[bucket] -= 1

    def in_cow(self, bucket: int) -> bool:
        return self._cow_depth.get(bucket, 0) > 0

    # ------------------------------------------------------ launch brackets
    def begin_launch(self, bucket: int, signature: Any,
                     reads: Iterable[int], writes: Iterable[int],
                     scratch: Optional[int] = None) -> _Ticket:
        """Register one launch's row sets; raises on any violation.

        ``reads``/``writes`` are arena row ids (slots plus block-table
        columns; a pinned prefix row in ``reads`` is the legal
        shared-read).  ``scratch`` names the arena's scratch row, exempt
        from ownership (padding writes land there by design).  Returns a
        ticket for :meth:`end_launch` (use try/finally)."""
        w = frozenset(int(r) for r in writes) - {scratch}
        rd = frozenset(int(r) for r in reads) - {scratch}
        self.checks += 1
        self.rows_checked += len(w | rd)
        # 1. every addressed row must be LIVE or PINNED in this bucket
        dead = []
        for row in sorted(w | rd):
            r = self._bucket(bucket).get(row)
            if r is None or r.state == FREE:
                dead.append(row)
        if dead:
            why = ("bucket was retired"
                   if bucket in self._retired else "rows are free/unknown")
            self._raise(
                "use_after_release", bucket, dead,
                f"launch sig={signature!r} addresses released rows "
                f"{dead} ({why})", signatures=(signature,))
        # 2. writes to pinned prefix rows are legal only on the COW path
        pinned_w = [row for row in sorted(w)
                    if self._bucket(bucket)[row].state == PINNED]
        if pinned_w and not self.in_cow(bucket):
            ops = {row: self._bucket(bucket)[row].op for row in pinned_w}
            self._raise(
                "pinned_write", bucket, pinned_w,
                f"launch sig={signature!r} writes pinned prefix rows "
                f"{ops!r} outside the COW path", signatures=(signature,))
        # 3. overlap with in-flight launches: write/write or write/read
        for t in self._inflight.values():
            if t.bucket != bucket:
                continue
            ww = w & t.writes
            wr = (w & t.reads) | (rd & t.writes)
            clash = ww | wr
            if clash:
                kind = "write/write" if ww else "write/read"
                self._raise(
                    "overlap", bucket, clash,
                    f"in-flight {kind} overlap on rows "
                    f"{sorted(clash)}: launch sig={signature!r} vs "
                    f"launch #{t.launch_id} sig={t.signature!r}; "
                    f"owners: {self._owners_str(bucket, clash)}",
                    signatures=(signature, t.signature))
        ticket = _Ticket(self._next_launch, bucket, signature, rd, w, scratch)
        self._next_launch += 1
        self._inflight[ticket.launch_id] = ticket
        if len(self._inflight) > self.inflight_peak:
            self.inflight_peak = len(self._inflight)
        return ticket

    def end_launch(self, ticket: _Ticket) -> None:
        self._inflight.pop(ticket.launch_id, None)

    # ------------------------------------------------------- kernel bridge
    def kernel_hook(self) -> Callable[[str, Any, int], None]:
        """Hook for ``kernels.sanitize``: validates concrete slot /
        block-table row ids observed by the (eagerly-called) kernel
        wrappers against [0, n_rows] and, when launches are in flight,
        against their registered row sets."""
        def hook(where: str, rows: Any, n_rows: int) -> None:
            import numpy as np
            flat = set(int(r) for r in np.asarray(rows).ravel())
            self.kernel_checks += 1
            bad = sorted(r for r in flat if r < 0 or r > n_rows)
            if bad:
                self._raise(
                    "unregistered_rows", None, bad,
                    f"{where}: rows {bad} outside [0, {n_rows}]")
            if self._inflight:
                allowed: Set[int] = set()
                for t in self._inflight.values():
                    allowed |= t.reads | t.writes
                    if t.scratch is not None:
                        allowed.add(t.scratch)
                allowed.add(n_rows)         # scratch by convention
                unreg = sorted(flat - allowed)
                if unreg:
                    sigs = tuple(t.signature
                                 for t in self._inflight.values())
                    self._raise(
                        "unregistered_rows", None, unreg,
                        f"{where}: rows {unreg} not registered by any "
                        f"in-flight launch ({len(self._inflight)} "
                        f"in flight)", signatures=sigs)
        return hook

    # -------------------------------------------------------- diagnostics
    def _owner_str(self, r: _Row) -> str:
        if r.owner is None:
            return "none"
        extra = ""
        if self.doc_info is not None:
            info = self.doc_info(r.owner)
            if info is not None:
                extra = f" {info}"
        return f"doc {r.owner}{extra}"

    def _owners_str(self, bucket: int, rows: Iterable[int]) -> str:
        parts = []
        for row in sorted(rows):
            r = self._bucket(bucket).get(row) or _Row()
            parts.append(f"row {row} -> {self._owner_str(r)} "
                         f"[{r.state}, epoch {r.epoch}]")
        return "; ".join(parts)

    def _raise(self, kind: str, bucket: Optional[int], rows: Iterable[int],
               detail: str, signatures: Tuple[Any, ...] = ()) -> None:
        self.violations += 1
        msg = (f"arena sanitizer [{self.backend or 'backend'}"
               f"{'' if bucket is None else f'/bucket {bucket}'}] "
               f"{kind}: {detail}")
        tm = self.telemetry
        if tm is not None and getattr(tm, "enabled", False):
            tm.count("serve_sanitizer_violations_total", 1,
                     backend=self.backend or "unknown", kind=kind)
            if getattr(tm, "tracing", False):
                from ..serving.telemetry import EV_SANITIZER  # lazy import
                ts = time.perf_counter()
                owners = [] if bucket is None else [
                    self._bucket(bucket).get(r, _Row()).owner
                    for r in rows]
                for rid in {o for o in owners if o is not None and o >= 0}:
                    tm.event(rid, EV_SANITIZER, ts,
                             {"kind": kind, "rows": sorted(set(rows)),
                              "backend": self.backend})
        raise ArenaRaceError(msg, kind=kind, bucket=bucket, rows=rows,
                             signatures=signatures)


def env_enabled(env: Optional[Dict[str, str]] = None) -> bool:
    """Resolve the ``ARENA_SANITIZE`` environment switch ("", "0" = off)."""
    import os
    val = (env if env is not None else os.environ).get("ARENA_SANITIZE", "0")
    return val not in ("", "0")
