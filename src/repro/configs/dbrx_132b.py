"""dbrx-132b: 16-expert top-4 fine-grained MoE. [hf:databricks/dbrx-base]

EP REQUIRED: dense expert replication would need ~16.5 GB/chip for FFN
weights alone; experts shard over the 16-way ``data`` axis via shard_map
all-to-all, expert d_ff additionally sharded over ``model``.
"""
from ..config import ATTN_FULL, MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family=MOE,
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    head_dim=128,
    block_pattern=(ATTN_FULL,),
    moe=MoEConfig(num_experts=16, top_k=4, strategy="ep_a2a"),
    rope_theta=500_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
