"""recurrentgemma-2b: Griffin hybrid — RG-LRU + local attention, 1:2.
[arXiv:2402.19427; hf]

26 = 8 x (rec, rec, local-attn) + 2 rec tail; RG-LRU via associative scan,
2048-token sliding window on attention layers, MQA (kv=1, replicated —
pad_kv_to_tp=False).  Bounded state -> 500k decode supported.
"""
from ..config import ATTN_LOCAL, HYBRID, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family=HYBRID,
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=(RGLRU, RGLRU, ATTN_LOCAL),
    sliding_window=2048,
    embed_scale=True,
    pad_kv_to_tp=False,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
