"""minitron-4b: width/depth-pruned nemotron dense LM. [arXiv:2407.14679; hf]"""
from ..config import ATTN_FULL, DENSE, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family=DENSE,
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    head_dim=128,
    block_pattern=(ATTN_FULL,),
    # pure full attention: long_500k skipped (DESIGN.md)
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
