"""gemma3-27b: 62L dense, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt scaled; unverified]  62 = 10 x (5 local + 1 global)
superblocks + 2 local tail.  head_dim=128 explicit (d_model/heads != 128),
qk-norm, sqrt(d) embed scaling, 1024-token sliding window on local layers.
Oracle-class model in the task-cascade pairing.
"""
from ..config import ATTN_FULL, ATTN_LOCAL, DENSE, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family=DENSE,
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    block_pattern=(ATTN_LOCAL,) * 5 + (ATTN_FULL,),
    sliding_window=1024,
    qk_norm=True,
    embed_scale=True,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    # local layers bound KV; global layers run SP-KV sequence sharding,
    # so the 500k decode cell is supported (DESIGN.md long_500k notes).
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
