"""qwen3-1.7b: dense GQA with per-head q/k RMS norm. [hf:Qwen/Qwen3-8B; hf]"""
from ..config import ATTN_FULL, DENSE, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family=DENSE,
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151936,
    head_dim=128,
    block_pattern=(ATTN_FULL,),
    qk_norm=True,
    rope_theta=1_000_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
