"""whisper-base: encoder-decoder audio backbone. [arXiv:2212.04356]

Conv/mel frontend is a STUB (precomputed frame embeddings, 1500 frames);
6 bidirectional encoder layers + 6 decoder layers with cross-attention.
Decode shapes run the decoder only (encoder runs once at prefill).
"""
from ..config import ATTN_FULL, AUDIO, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family=AUDIO,
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    block_pattern=(ATTN_FULL,),
    act="gelu",
    encoder_layers=6,
    encoder_seq_len=1536,         # 1500 mel frames, padded to lane multiple
    frontend_stub="audio_frames",
    frontend_len=1536,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
