"""phi3.5-moe-42b-a6.6b: 16-expert top-2 MoE. [hf:microsoft/Phi-3.5-MoE]

Experts fit per data shard at this scale, so the default execution strategy
is ``tp_dense`` (expert d_ff sharded over model); the EP all-to-all strategy
is selectable for comparison (benchmarked in EXPERIMENTS.md).
"""
from ..config import ATTN_FULL, MOE, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family=MOE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    head_dim=128,
    block_pattern=(ATTN_FULL,),
    moe=MoEConfig(num_experts=16, top_k=2, strategy="tp_dense"),
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
