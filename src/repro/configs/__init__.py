"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full :class:`repro.config.ModelConfig`;
``get_reduced(name)`` returns the tiny same-family config used by CPU smoke
tests.  ``ARCHS`` lists all assigned ids.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..config import ModelConfig, reduced

ARCHS: List[str] = [
    "gemma3_27b",
    "minitron_4b",
    "qwen3_1_7b",
    "llama3_2_1b",
    "qwen2_vl_2b",
    "phi3_5_moe",
    "dbrx_132b",
    "whisper_base",
    "xlstm_350m",
    "recurrentgemma_2b",
]

# public ids (dashes) -> module names
ALIASES: Dict[str, str] = {
    "gemma3-27b": "gemma3_27b",
    "minitron-4b": "minitron_4b",
    "qwen3-1.7b": "qwen3_1_7b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "dbrx-132b": "dbrx_132b",
    "whisper-base": "whisper_base",
    "xlstm-350m": "xlstm_350m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def get_reduced(name: str, **overrides) -> ModelConfig:
    return reduced(get_config(name), **overrides)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
