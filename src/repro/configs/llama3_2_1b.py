"""llama3.2-1b: small llama3 dense LM — the default cascade proxy.
[hf:meta-llama/Llama-3.2-1B; unverified]"""
from ..config import ATTN_FULL, DENSE, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family=DENSE,
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=64,
    block_pattern=(ATTN_FULL,),
    rope_theta=500_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
