"""xlstm-350m: alternating mLSTM / sLSTM blocks. [arXiv:2405.04517]

d_ff=0: xLSTM blocks carry their own gating/projections, no separate MLP.
mLSTM runs chunkwise-parallel on TPU (MXU [L,L] tiles + chunk scan); sLSTM
is a true nonlinear recurrence and scans over time.  O(1)-state decode
makes the 500k long-context cell natural.
"""
from ..config import MLSTM, SLSTM, SSM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family=SSM,
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    block_pattern=(MLSTM, SLSTM),
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)
