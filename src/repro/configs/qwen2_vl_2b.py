"""qwen2-vl-2b: VLM backbone with M-RoPE. [arXiv:2409.12191; hf]

The vision frontend is a STUB: ``input_specs()`` provides 1024 precomputed
patch embeddings [B, 1024, d_model] prepended to the text tokens; 3-channel
(t, h, w) M-RoPE positions ride in ``positions3``.
"""
from ..config import ATTN_FULL, VLM, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family=VLM,
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    block_pattern=(ATTN_FULL,),
    mrope_sections=(16, 24, 24),     # frequency pairs per (t, h, w); sum=64
    rope_theta=1_000_000.0,
    frontend_stub="vision_patches",
    frontend_len=1024,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k"),
)
