"""Per-(arch x shape) input specs and sharded step builders for the dry-run.

``input_specs(arch, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input — no device allocation — following the
assigned shape table:

    train_4k      train_step(params, opt, batch)         B=256  S=4096
    prefill_32k   serve_prefill(params, batch)           B=32   S=32768
    decode_32k    serve_step(params, tok, states, pos)   B=128  KV=32768
    long_500k     serve_step ...                         B=1    KV=524288

``build_case`` assembles (fn, args ShapeDtypeStructs, in/out shardings)
for one cell on one mesh; ``launch/dryrun.py`` lowers and compiles it.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..config import SHAPES, ModelConfig, ResolvedConfig, resolve
from ..configs import get_config
from ..distributed.sharding import (batch_pspec, dp_axes, tree_pspecs,
                                    tree_shardings, zero_tree_pspecs)
from ..models.model import LM
from ..models.runtime import Runtime
from ..models.whisper import WhisperModel
from ..train.optimizer import OptState, OptimizerConfig, adamw_update, \
    init_opt_state
from ..train.train_loop import TrainConfig, make_train_step

I32 = jnp.int32
BF16 = jnp.bfloat16


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


def make_model(arch: str, mesh: Optional[Mesh], shape_name: str,
               attn_impl: str = "xla", n_rep_override: Optional[int] = None):
    import dataclasses
    cfg = get_config(arch)
    if n_rep_override is not None and cfg.family != "audio":
        p = len(cfg.block_pattern)
        tail = cfg.num_layers % p
        cfg = dataclasses.replace(
            cfg, num_layers=p * n_rep_override + tail)
    rcfg = resolve(cfg, tp=mesh.shape["model"] if mesh else 1)
    sp_decode = (shape_name == "long_500k")
    # §Perf iteration (gemma3/train_4k): dropping the sequence-parallel
    # activation constraint was REFUTED — without it XLA reverts to
    # vanilla-TP layouts (all-reduce = 2x the ag+rs volume: collective
    # 4.8 -> 8.9s) and materializes 286 GB/chip of temporaries (OOM).
    # SP-activations stays ON for training: half the collective volume
    # and 16x smaller saved activations, the textbook Megatron-v3 result.
    rt = Runtime(attn_impl=attn_impl, mesh=mesh, sp_decode=sp_decode,
                 sp_activations=(shape_name == "train_4k"),
                 remat=True, unroll_layers=(n_rep_override is not None))
    if cfg.family == "audio":
        return WhisperModel(rcfg, rt), rcfg
    return LM(rcfg, rt), rcfg


def _param_structs(model) -> Any:
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _batch_structs(rcfg: ResolvedConfig, shape_name: str) -> Dict[str, Any]:
    b = rcfg.base
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    d = {}
    if b.frontend_stub == "vision_patches":
        s_text = S - b.frontend_len
        d["tokens"] = jax.ShapeDtypeStruct((B, s_text), I32)
        d["patch_emb"] = jax.ShapeDtypeStruct((B, b.frontend_len, b.d_model),
                                              BF16)
        d["positions3"] = jax.ShapeDtypeStruct((B, S, 3), I32)
        d["labels"] = jax.ShapeDtypeStruct((B, S), I32)
    elif b.frontend_stub == "audio_frames":
        d["frame_emb"] = jax.ShapeDtypeStruct(
            (B, b.encoder_seq_len, b.d_model), BF16)
        d["tokens"] = jax.ShapeDtypeStruct((B, S), I32)
        d["labels"] = jax.ShapeDtypeStruct((B, S), I32)
    else:
        d["tokens"] = jax.ShapeDtypeStruct((B, S), I32)
        d["labels"] = jax.ShapeDtypeStruct((B, S), I32)
    return d


def _batch_pspecs(rcfg: ResolvedConfig, shape_name: str, mesh: Mesh
                  ) -> Dict[str, P]:
    b = rcfg.base
    sh = SHAPES[shape_name]
    dp = batch_pspec(mesh)[0] if sh.global_batch % dp_size(mesh) == 0 else None
    d = {}
    if b.frontend_stub == "vision_patches":
        d["tokens"] = P(dp, None)
        d["patch_emb"] = P(dp, None, None)
        d["positions3"] = P(dp, None, None)
        d["labels"] = P(dp, None)
    elif b.frontend_stub == "audio_frames":
        d["frame_emb"] = P(dp, None, None)
        d["tokens"] = P(dp, None)
        d["labels"] = P(dp, None)
    else:
        d["tokens"] = P(dp, None)
        d["labels"] = P(dp, None)
    return d


@dataclass
class DryRunCase:
    """Everything jax.jit needs for one (arch x shape x mesh) cell."""
    name: str
    fn: Any
    args: Tuple[Any, ...]               # ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def build_case(arch: str, shape_name: str, mesh: Mesh,
               attn_impl: str = "xla",
               n_rep_override: Optional[int] = None) -> DryRunCase:
    model, rcfg = make_model(arch, mesh, shape_name, attn_impl,
                             n_rep_override)
    sh = SHAPES[shape_name]
    param_structs = _param_structs(model)
    pspecs = tree_pspecs(model.param_specs(), mesh)
    pshard = jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                          is_leaf=lambda x: isinstance(x, P))

    if sh.kind == "train":
        opt_structs = jax.eval_shape(init_opt_state, param_structs)
        zspecs = zero_tree_pspecs(pspecs, param_structs, mesh)
        zshard = jax.tree.map(lambda p: NamedSharding(mesh, p), zspecs,
                              is_leaf=lambda x: isinstance(x, P))
        opt_shard = OptState(
            NamedSharding(mesh, P()),
            jax.tree.map(lambda s: s, zshard), zshard)
        batch = _batch_structs(rcfg, shape_name)
        bshard = {k: NamedSharding(mesh, v)
                  for k, v in _batch_pspecs(rcfg, shape_name, mesh).items()}
        # NOTE (§Perf iteration, phi3.5-moe/train_4k/multi): explicit int8
        # pod-hop gradient compression was REFUTED as a win under SPMD —
        # the shard_map wrapper forced an all-gather plus a redundant f32
        # all-reduce on already-reduced grads (collective term 117s vs 7s).
        # XLA's backward fuses the pod hop into the gradient all-reduce;
        # the primitive stays available for per-pod-backward deployments.
        tc = TrainConfig(compress_pod_grads=False)
        step = make_train_step(model, mesh, tc)
        metrics_shard = {"loss": NamedSharding(mesh, P()),
                         "grad_norm": NamedSharding(mesh, P()),
                         "lr": NamedSharding(mesh, P())}
        return DryRunCase(
            name=f"{arch}|{shape_name}",
            fn=step,
            args=(param_structs, opt_structs, batch),
            in_shardings=(pshard, opt_shard, bshard),
            out_shardings=(pshard, opt_shard, metrics_shard),
            donate_argnums=(0, 1),
        )

    if sh.kind == "prefill":
        batch = _batch_structs(rcfg, shape_name)
        batch.pop("labels")
        bshard = {k: NamedSharding(mesh, v)
                  for k, v in _batch_pspecs(rcfg, shape_name, mesh).items()
                  if k in batch}
        batch_sharded = sh.global_batch % dp_size(mesh) == 0
        st_specs = tree_pspecs(
            model.state_specs(batch_sharded=batch_sharded,
                              seq_sharded=False), mesh)
        st_shard = jax.tree.map(lambda p: NamedSharding(mesh, p), st_specs,
                                is_leaf=lambda x: isinstance(x, P))
        logits_shard = NamedSharding(
            mesh, P(batch_pspec(mesh)[0] if batch_sharded else None, "model"))

        def prefill_fn(params, batch):
            return model.prefill(params, batch, s_alloc=sh.seq_len)

        return DryRunCase(
            name=f"{arch}|{shape_name}",
            fn=prefill_fn,
            args=(param_structs, batch),
            in_shardings=(pshard, bshard),
            out_shardings=(logits_shard, st_shard),
        )

    # decode kinds (decode_32k / long_500k): one-token serve_step
    B = sh.global_batch
    batch_sharded = B % dp_size(mesh) == 0
    seq_sharded = (shape_name == "long_500k")
    st_structs = model.state_shapes(B, sh.seq_len)
    st_specs = tree_pspecs(
        model.state_specs(batch_sharded=batch_sharded,
                          seq_sharded=seq_sharded), mesh)
    st_shard = jax.tree.map(lambda p: NamedSharding(mesh, p), st_specs,
                            is_leaf=lambda x: isinstance(x, P))
    dp = batch_pspec(mesh)[0] if batch_sharded else None
    tok_shard = NamedSharding(mesh, P(dp))
    logits_shard = NamedSharding(mesh, P(dp, "model"))

    def decode_fn(params, tokens, states, pos):
        return model.decode_step(params, tokens, states, pos)

    return DryRunCase(
        name=f"{arch}|{shape_name}",
        fn=decode_fn,
        args=(param_structs,
              jax.ShapeDtypeStruct((B,), I32),
              st_structs,
              jax.ShapeDtypeStruct((B,), I32)),
        in_shardings=(pshard, tok_shard, st_shard, tok_shard),
        out_shardings=(logits_shard, st_shard),
        donate_argnums=(2,),
    )
