"""Training entry point.

    python -m repro.launch.train --arch llama3.2-1b [--reduced] --steps 100

On TPU hardware this builds the production mesh, shards params/opt-state
per the model's logical specs (+ZeRO-1), and runs the fault-tolerant
driver.  On CPU (default when fewer devices than requested mesh), it runs
the same code path on a host mesh with a reduced config.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..config import resolve
from ..configs import get_config, get_reduced
from ..checkpoint.checkpoint import Checkpointer
from ..data.pipeline import DataPipeline, ShardPlan, SyntheticLMTask
from ..distributed.sharding import tree_pspecs, zero_tree_pspecs
from ..models.model import LM
from ..models.runtime import Runtime
from ..models.whisper import WhisperModel
from ..train.optimizer import OptState, OptimizerConfig, init_opt_state
from ..train.train_loop import TrainConfig, TrainDriver, make_train_step
from .mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt_dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=max(n_dev, 1), model=1)
    cfg = get_reduced(args.arch, dtype="float32", vocab_size=2048) \
        if args.reduced else get_config(args.arch)
    rcfg = resolve(cfg, tp=mesh.shape["model"])
    rt = Runtime(attn_impl="xla", mesh=mesh, remat=False)
    model = LM(rcfg, rt) if cfg.family != "audio" else WhisperModel(rcfg, rt)

    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    pspecs = tree_pspecs(model.param_specs(), mesh)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    params = jax.tree.map(jax.device_put, params, pshard)

    tc = TrainConfig(accum_steps=args.accum, opt=OptimizerConfig(
        lr=1e-3, warmup_steps=10, total_steps=args.steps))
    step = jax.jit(make_train_step(model, mesh, tc), donate_argnums=(0, 1))

    task = SyntheticLMTask(vocab_size=cfg.vocab_size, seq_len=args.seq)
    pipe = DataPipeline(task, ShardPlan(n_shards=2, n_hosts=1), host=0,
                        batch_per_shard=args.batch // 2)
    ck = Checkpointer(args.ckpt_dir, keep=3)
    driver = TrainDriver(step, checkpointer=ck, ckpt_every=25, log_every=10)

    restored = driver.restore_latest(params, opt)
    start = 0
    if restored is not None:
        params, opt, start = restored
        print(f"resumed from checkpoint step {start}")
    driver.run(params, opt, iter(pipe), args.steps, start_step=start)
    print("training complete; checkpoints:", ck.steps())


if __name__ == "__main__":
    main()
