"""Three-term roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch x shape x mesh), all in seconds-per-step on TPU v5e:

    compute    = HLO_FLOPs_per_chip   / peak_FLOPs     (197 TFLOP/s bf16)
    memory     = HLO_bytes_per_chip   / HBM_bw         (819 GB/s)
    collective = coll_bytes_per_chip  / ICI_link_bw    (~50 GB/s/link)

``cost_analysis`` is per-chip under SPMD (all chips run the same program),
so the spec's HLO_FLOPs/(chips x peak) is exactly per-chip/peak.  The
collective bytes come from parsing the post-SPMD optimized HLO (operand
bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), with while-body counts recovered by the R=1/R=2
extrapolation in dryrun.py.

MODEL_FLOPS uses the paper-standard 6*N_active*D (train) or 2*N_active*D
(serve) with N from the LOGICAL architecture (unpadded) — the ratio
MODEL_FLOPS / HLO_FLOPs therefore exposes padding + remat + redundancy
waste.
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..config import SHAPES, resolve
from ..configs import get_config

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def decode_launch_bytes(params_bytes: float, kv_bytes_per_step: float,
                        steps: int = 1) -> float:
    """Structural HBM-traffic estimate of a decode-only serving launch.

    A decode step is memory-bound: each generated token streams the full
    parameter set plus the batch's live KV prefix from HBM.  ``steps``
    is the op-suffix length (one readout per suffix token).  Activations
    and the O(B) token writes are negligible against these two terms.
    """
    return steps * (float(params_bytes) + float(kv_bytes_per_step))


def bandwidth_utilization(bytes_moved: float, seconds: float,
                          bw: float = HBM_BW) -> float:
    """Fraction of the per-chip HBM roof a measured transfer achieved
    (``serving/telemetry.py`` calls this per decode launch with the
    ``block_until_ready`` device segment as ``seconds``)."""
    if seconds <= 0.0:
        return 0.0
    return (float(bytes_moved) / float(seconds)) / bw


def overlap_hidden_fraction(hidden_s: float, exposed_s: float) -> float:
    """Fraction of device time hidden behind host work by ahead-of-time
    dispatch: ``hidden / (hidden + exposed)``.

    ``hidden_s`` is the summed in-flight window (dispatch returned, sync
    not yet entered — the device computing while the host schedules
    other launches) and ``exposed_s`` the summed ``block_until_ready``
    waits the host actually paid.  0.0 at ``inflight=1`` (nothing
    overlaps), → 1.0 when completion never blocks.  Returns 0.0 when
    both terms are ~0 (no launches)."""
    total = float(hidden_s) + float(exposed_s)
    if total <= 0.0:
        return 0.0
    return float(hidden_s) / total


def logical_param_counts(arch: str) -> Dict[str, float]:
    """(total, active) parameter counts from the UNPADDED architecture."""
    cfg = get_config(arch)
    rcfg = resolve(cfg, tp=1)
    total = float(rcfg.param_count())
    active = float(rcfg.active_param_count())
    if cfg.family in ("ssm",):
        # xLSTM blocks: ~10 d^2 per mLSTM block, ~10 d^2 per sLSTM block
        d, l, v = cfg.d_model, cfg.num_layers, cfg.vocab_size
        total = active = l * 10 * d * d + v * d
    if cfg.family == "hybrid":
        d, l, v = cfg.d_model, cfg.num_layers, cfg.vocab_size
        n_rec = sum(1 for k in cfg.layer_kinds() if k == "rglru")
        n_att = cfg.num_layers - n_rec
        rec = 6 * d * d                      # in/gate/out + lru gates
        att = d * (cfg.num_heads + 2 * cfg.num_kv_heads
                   + cfg.num_heads) * (cfg.head_dim or d // cfg.num_heads)
        mlp = 3 * d * cfg.d_ff
        total = active = n_rec * (rec + mlp) + n_att * (att + mlp) + v * d
    return {"total": total, "active": active}


def model_flops(arch: str, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    n = logical_param_counts(arch)["active"]
    cfg = get_config(arch)
    tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
    mult = 6.0 if sh.kind == "train" else 2.0
    flops = mult * n * tokens
    if cfg.family == "audio" and sh.kind != "decode":
        # encoder pass (6 layers over encoder_seq_len frames)
        enc_n = logical_param_counts(arch)["total"] * 0.45
        flops += mult * enc_n * sh.global_batch * cfg.encoder_seq_len
    return flops


def analytic_memory_floor(arch: str, shape_name: str, devices: int) -> float:
    """Deploy-true HBM bytes/chip/step lower bound.

    The CPU-target HLO legalizes every bf16 dot by CONVERTING both operands
    to f32 (measured: 70% of `bytes accessed` on several cells is
    standalone converts) — TPU's MXU consumes bf16 directly, so the HLO
    memory term is a systematic upper bound.  This floor counts what a
    fused TPU lowering must move:

      params      1x read (serve) / 3x (train: fwd + bwd re-read + dW)
      activations C x B_loc*S*d*L*2B (C~4 serve, ~8 train with remat)
      KV cache    write once (prefill) / read once + slot write (decode)
      logits      ~3x B_loc*S*V_loc (train xent) / tiny at serve
      attention   visited-block kv re-reads (Pallas revisiting grid)
    """
    from ..config import ATTN_FULL, ATTN_LOCAL, ENC_ATTN
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    rcfg = resolve(cfg, tp=16)
    tp = 16
    dp = devices // tp
    b_loc = max(sh.global_batch // dp, 1)
    d, L = cfg.d_model, cfg.num_layers
    dh, hq, hkv = rcfg.head_dim, rcfg.padded_heads, rcfg.padded_kv_heads
    kv_chip = max(hkv // tp, 1) if hkv >= tp else hkv
    S = sh.seq_len
    params_bytes = 2.0 * rcfg.param_count() / tp
    if cfg.moe is not None:
        # experts sharded over data under EP; dense-TP keeps all per chip
        if cfg.moe.strategy == "ep_a2a":
            params_bytes = 2.0 * (rcfg.active_param_count() / tp
                                  + (rcfg.param_count()
                                     - rcfg.active_param_count()) / devices)
    kinds = cfg.layer_kinds()

    def attn_kv_io(seq_q: int) -> float:
        """Pallas revisiting-grid kv re-reads per chip (prefill/train)."""
        bq = bkv = 512
        total = 0.0
        for kind in kinds:
            if kind not in (ATTN_FULL, ATTN_LOCAL, ENC_ATTN):
                continue
            nq = max(seq_q // bq, 1)
            if kind == ATTN_LOCAL:
                per_q = min(cfg.sliding_window // bkv + 2, nq)
                pairs = nq * per_q
            else:
                pairs = nq * (nq + 1) // 2
            total += pairs * 2 * bkv * dh * 2.0 * b_loc * max(hq // tp, 1)
        return total

    if sh.kind == "train":
        act = 8.0 * L * b_loc * S * d * 2.0
        logits = 3.0 * b_loc * S * (rcfg.padded_vocab / tp) * 2.0
        if cfg.moe is not None:
            act *= (1 + cfg.moe.top_k * cfg.moe.capacity_factor)
        return 3.0 * params_bytes + act + logits + 3.5 * attn_kv_io(S)
    if sh.kind == "prefill":
        act = 4.0 * L * b_loc * S * d * 2.0
        kv_write = sum(
            2.0 * b_loc * (min(cfg.sliding_window, S)
                           if k == ATTN_LOCAL else S) * kv_chip * dh * 2.0
            for k in kinds if k in (ATTN_FULL, ATTN_LOCAL, ENC_ATTN))
        return params_bytes + act + kv_write + attn_kv_io(S)
    # decode: weights + full KV read per token
    kv_read = 0.0
    for k in kinds:
        if k == ATTN_LOCAL:
            s_here = min(cfg.sliding_window, S)
            kv_read += 2.0 * b_loc * s_here * kv_chip * dh * 2.0
        elif k in (ATTN_FULL, ENC_ATTN):
            s_here = S // dp if sh.global_batch < dp else S
            kv_read += 2.0 * b_loc * s_here * kv_chip * dh * 2.0
    return params_bytes + kv_read + 6.0 * L * b_loc * d * 2.0


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float            # deploy-true floor (see analytic_memory_floor)
    memory_hlo_s: float        # CPU-target HLO upper bound
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_global: float
    useful_ratio: float
    bound_step_s: float
    roofline_frac: float       # max-term / sum-of-terms lower bound quality
    note: str = ""

    def table_row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s:.2e} | {self.memory_s:.2e} | "
                f"{self.memory_hlo_s:.2e} | {self.collective_s:.2e} | "
                f"**{self.dominant}** | "
                f"{self.useful_ratio:.2f} | {self.roofline_frac:.2f} |")


SUGGESTIONS = {
    "compute": ("compute-bound: raise MFU via larger per-chip tiles / fewer "
                "pad heads / less remat recompute"),
    "memory": ("HBM-bound: shrink bytes moved — fuse softmax/xent, bf16 "
               "masters, windowed KV, or shard the dominant resident tensor"),
    "collective": ("ICI-bound: reshard to cut the dominant collective, "
                   "overlap it with compute, or compress the payload"),
}


def analyze(result: Dict) -> Optional[RooflineRow]:
    if not result.get("ok"):
        return None
    ex = result.get("extrapolated", result)
    chips = result["devices"]
    flops_pc = ex["flops"]                       # per-chip (SPMD program)
    bytes_pc = ex["bytes_accessed"]
    coll_pc = float(sum(ex.get("collective_bytes", {}).values()))
    compute_s = flops_pc / PEAK_FLOPS
    memory_hlo_s = bytes_pc / HBM_BW
    floor_bytes = analytic_memory_floor(result["arch"], result["shape"],
                                        chips)
    memory_s = min(max(floor_bytes / HBM_BW, 0.0), memory_hlo_s)
    collective_s = coll_pc / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(result["arch"], result["shape"])
    hlo_global = flops_pc * chips
    useful = mf / hlo_global if hlo_global else 0.0
    bound = max(terms.values())
    total = sum(terms.values())
    # roofline fraction: how close the binding term is to owning the step
    # (1.0 = perfectly overlapped single-bottleneck execution)
    frac = bound / total if total else 0.0
    return RooflineRow(
        arch=result["arch"], shape=result["shape"], mesh=result["mesh"],
        compute_s=compute_s, memory_s=memory_s, memory_hlo_s=memory_hlo_s,
        collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=useful, bound_step_s=bound, roofline_frac=frac,
        note=SUGGESTIONS[dominant])


HEADER = """| arch | shape | mesh | compute (s) | memory floor (s) | memory HLO-UB (s) | collective (s) | bottleneck | useful FLOP ratio | overlap-quality |
|------|-------|------|-------------|------------------|-------------------|----------------|------------|-------------------|-----------------|"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", required=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    with open(args.results) as f:
        results = json.load(f)
    if isinstance(results, dict):
        results = [results]
    lines = [HEADER]
    details = []
    for r in results:
        row = analyze(r)
        if row is None:
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | "
                         f"{r.get('mesh')} | FAILED | | | | | | |")
            continue
        lines.append(row.table_row())
        details.append(
            f"- **{row.arch} x {row.shape} ({row.mesh})** — dominant: "
            f"{row.dominant} ({row.bound_step_s:.2e}s); MODEL_FLOPS "
            f"{row.model_flops:.2e}, HLO {row.hlo_flops_global:.2e} "
            f"(useful ratio {row.useful_ratio:.2f}). {row.note}")
    text = "\n".join(lines) + "\n\n" + "\n".join(details) + "\n"
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
