"""Multi-pod AOT dry-run: lower + compile every (arch x shape x mesh) cell.

THE FIRST TWO LINES set up 512 placeholder host devices BEFORE any jax
import — jax locks the device count at first init.  Everything else in the
repo sees the real single CPU device.

Usage:
    python -m repro.launch.dryrun --arch gemma3-27b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--out results.json]

``--all`` runs every supported cell in subprocesses (compile-crash
isolation + parallelism) and aggregates a JSON report consumed by
``launch/roofline.py`` and EXPERIMENTS.md §Dry-run.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402

import jax               # noqa: E402

from ..config import SHAPES      # noqa: E402
from ..configs import ALIASES, ARCHS, get_config   # noqa: E402
from .mesh import make_production_mesh             # noqa: E402

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*(\([^)]*\)|\S+)")

BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
         "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1,
         "f8e5m2": 1, "s16": 2, "u16": 2}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


COLLECTIVE_OP_RE = re.compile(
    r"= *(?:\([^=]*?\)|\S+)? *"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def parse_collective_bytes(hlo_text: str):
    """Sum output+operand bytes of collective ops in an HLO dump, per kind.

    Matches BOTH single-output (`= f32[..] all-reduce(..)`) and
    tuple-output (`= (f32[..], ..) all-reduce(..)`) instruction forms and
    counts every shape token on the instruction line (the HloCostAnalysis
    operand+output convention — ~2x the wire payload for a simple AR).
    """
    out = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = COLLECTIVE_OP_RE.search(stripped)
        if not m or " = " not in stripped:
            continue
        kind = m.group(1)
        nbytes = 0
        for dt, dims in SHAPE_RE.findall(stripped):
            if dt not in BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * BYTES[dt]
        out[kind] = out.get(kind, 0) + nbytes
    return out


def _compile_case(case, mesh):
    """lower + compile one case; return (compiled, metrics dict)."""
    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            case.fn,
            in_shardings=case.in_shardings,
            out_shardings=case.out_shardings,
            donate_argnums=case.donate_argnums,
        )
        lowered = jitted.lower(*case.args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        # post-SPMD optimized HLO: pjit-inserted collectives are visible here
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    return {
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
    }


def run_one(arch: str, shape: str, mesh_kind: str, attn_impl: str = "xla"):
    """Compile the production (scanned) program + R=1/R=2 unrolled probes.

    XLA's HloCostAnalysis visits a while-loop body once, so the scanned
    superblock stack under-reports FLOPs/bytes/collectives by ~R.  The two
    unrolled probes give A = base + body and B = base + 2*body; the true
    totals are A + (R-1)*(B-A).  The production compile (memory analysis,
    shardings, compile success) is the deliverable; the probes only feed
    the roofline table.
    """
    from .specs import build_case
    from ..configs import get_config
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = get_config(arch)
    n_dev = 1
    for s in mesh.shape.values():
        n_dev *= s

    case = build_case(arch, shape, mesh, attn_impl)
    main = _compile_case(case, mesh)

    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "devices": n_dev,
        "ok": True, **main,
    }

    # cost extrapolation via unrolled probes (decoder-only archs with a
    # scanned superblock stack; whisper is already fully unrolled)
    if cfg.family != "audio":
        p = len(cfg.block_pattern)
        n_rep = cfg.num_layers // p
        if n_rep >= 2:
            a = _compile_case(build_case(arch, shape, mesh, attn_impl,
                                         n_rep_override=1), mesh)
            b = _compile_case(build_case(arch, shape, mesh, attn_impl,
                                         n_rep_override=2), mesh)

            def extrap(ka, kb):
                return ka + (n_rep - 1) * (kb - ka)

            coll = {}
            for kind in set(a["collective_bytes"]) | set(b["collective_bytes"]):
                coll[kind] = int(extrap(
                    a["collective_bytes"].get(kind, 0),
                    b["collective_bytes"].get(kind, 0)))
            result["extrapolated"] = {
                "flops": extrap(a["flops"], b["flops"]),
                "bytes_accessed": extrap(a["bytes_accessed"],
                                         b["bytes_accessed"]),
                "collective_bytes": coll,
                "probe_compile_s": [a["compile_s"], b["compile_s"]],
            }
    else:
        result["extrapolated"] = {
            "flops": main["flops"],
            "bytes_accessed": main["bytes_accessed"],
            "collective_bytes": main["collective_bytes"],
        }
    return result


def supported_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in cfg.supported_shapes:
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--attn", default="xla", choices=["xla", "stub"])
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    if args.all:
        cells = []
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for arch, shape in supported_cells():
            for mk in meshes:
                cells.append((arch, shape, mk))
        results = run_subprocesses(cells, args.jobs, args.timeout,
                                   attn=args.attn, partial_out=args.out)
        ok = sum(1 for r in results if r.get("ok"))
        print(f"\n=== dry-run: {ok}/{len(results)} cells compiled ===")
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
        sys.exit(0 if ok == len(results) else 1)

    res = run_one(args.arch, args.shape, args.mesh, attn_impl=args.attn)
    print(json.dumps(res, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


def run_subprocesses(cells, jobs: int, timeout: int, attn: str = "xla",
                     partial_out: str = None):
    """Run each cell as `python -m repro.launch.dryrun --arch ...` with
    bounded parallelism; collect JSON results."""
    import tempfile
    from concurrent.futures import ThreadPoolExecutor

    def run_cell(cell):
        arch, shape, mk = cell
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tf:
            out = tf.name
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mk,
               "--attn", attn, "--out", out]
        t0 = time.time()
        try:
            proc = subprocess.run(
                cmd, capture_output=True, text=True, timeout=timeout,
                env={**os.environ, "PYTHONPATH": os.environ.get(
                    "PYTHONPATH", "src")})
            if proc.returncode == 0:
                with open(out) as f:
                    r = json.load(f)
            else:
                r = {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                     "error": proc.stderr[-2000:]}
        except subprocess.TimeoutExpired:
            r = {"arch": arch, "shape": shape, "mesh": mk, "ok": False,
                 "error": f"timeout after {timeout}s"}
        finally:
            if os.path.exists(out):
                os.unlink(out)
        status = "OK " if r.get("ok") else "FAIL"
        print(f"[{status}] {arch:20s} {shape:12s} {mk:6s} "
              f"({time.time() - t0:.0f}s)", flush=True)
        return r

    results = []
    with ThreadPoolExecutor(max_workers=jobs) as ex:
        for r in ex.map(run_cell, cells):
            results.append(r)
            if partial_out:        # incremental flush (crash-resumable)
                with open(partial_out, "w") as f:
                    json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
