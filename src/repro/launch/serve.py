"""Serving entry point: continuous-batching cascade loop over an arrival
stream.

    python -m repro.launch.serve --docs 32 --rate 20 --batch 8

Simulates a production document feed: Poisson arrivals are submitted to
``serving.engine.CascadeEngine`` as they land on the wall clock, the
request loop packs cross-stage launches between arrivals, and per-document
latency (submit -> resolve) is reported as p50/p99 alongside throughput,
KV-cache hit rate, evictions, and arena bytes.  ``--slot-budget`` exercises
the arena memory-control path (preemption + re-prefill).

The module also exports the stream driver (``poisson_arrivals`` /
``drive_request_loop``) used by ``benchmarks/serve_engine.py``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Mapping, Optional, Tuple

import jax
import numpy as np

from ..config import resolve
from ..configs import get_reduced
from ..core.tasks import Cascade, Task, TaskConfig
from ..data.documents import generate_corpus
from ..data.tokenizer import HashWordTokenizer
from ..models.model import LM
from ..models.runtime import CPU_TEST
from ..serving.engine import CascadeEngine, EngineResult, LMBackend


def poisson_arrivals(doc_ids, rate: float, seed: int = 0
                     ) -> Dict[int, float]:
    """Arrival offsets (seconds from stream start) with exponential gaps."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(doc_ids))
    return dict(zip(doc_ids, np.cumsum(gaps)))


def drive_request_loop(
    engine: CascadeEngine,
    cascade: Cascade,
    docs: Mapping[int, str],
    arrivals: Mapping[int, float],
    oracle_model: str = "oracle",
) -> Tuple[EngineResult, float]:
    """Run one streaming session against the wall clock.

    Documents are submitted the moment their arrival offset elapses — i.e.
    mid-cascade, between launches, not at stage boundaries — and the
    engine steps whenever work is ready.  The *scheduled* arrival is
    passed as the latency anchor (``arrival_ts``), so recorded latencies
    include any queueing delay.  Returns (result, wall seconds).
    """
    engine.start(cascade, oracle_model)
    order = sorted(docs, key=lambda d: (arrivals[d], d))
    t0 = time.perf_counter()
    i = 0
    while i < len(order) or engine.pending():
        now = time.perf_counter() - t0
        while i < len(order) and arrivals[order[i]] <= now:
            d = order[i]
            engine.submit(d, docs[d], arrival=arrivals[d],
                          arrival_ts=t0 + arrivals[d])
            i += 1
        if engine.pending():
            engine.step()
        elif i < len(order):
            time.sleep(min(arrivals[order[i]] - now, 0.05))
    return engine.result(), time.perf_counter() - t0


def warm_arena(engine: CascadeEngine, cascade: Cascade,
               docs: Mapping[int, str], batch_size: int) -> None:
    """Compile every launch signature streaming can produce.

    The request loop dispatches partial groups as documents trickle in,
    so padded batch widths 1, 2, 4, ... up to ``batch_size`` all occur —
    a single full-batch ``run()`` only compiles full-width chunks and the
    first narrow launch would otherwise pay its XLA compile inside the
    timed/streamed pass.  Two subtleties make the warm runs deliberately
    maximal: (1) thresholds are forced IMPOSSIBLE so every warm doc walks
    every stage — real thresholds would let warm docs exit early and
    leave late-stage survivor groups uncompiled; (2) each width runs the
    WHOLE corpus, not a bucket-covering subset, because the arena pytree
    rides through the jitted step and its CAPACITY (grown by doubling
    with the live set) is part of the compiled shape — a subset warm
    stops at a smaller capacity and the measured pass recompiles
    everything the first time the arena doubles past it.
    """
    forced = Cascade([
        Task(t.config, {c: 2.0 for c in range(engine.n_classes)})
        for t in cascade.tasks])
    orig = engine.batch_size
    try:
        bs = 1
        while True:
            engine.batch_size = min(bs, batch_size)
            engine.run(forced, docs)
            if bs >= batch_size:
                break
            bs *= 2
    finally:
        engine.batch_size = orig


def build_engine(batch_size: int, slot_budget: Optional[int],
                 retire_after: int, proxy_arch: str = "llama3_2_1b",
                 oracle_arch: str = "qwen3_1_7b") -> CascadeEngine:
    """Tiny untrained proxy/oracle backends (mechanics demo, CPU-friendly)."""
    tokz = HashWordTokenizer(vocab_size=512)

    def mk(name, arch, seed, rate):
        cfg = get_reduced(arch, dtype="float32", vocab_size=512, num_layers=2)
        m = LM(resolve(cfg, tp=1), CPU_TEST)
        return LMBackend(name=name, model=m,
                         params=m.init(jax.random.PRNGKey(seed)),
                         tokenizer=tokz, rate_per_token=rate,
                         slot_budget=slot_budget, retire_after=retire_after)

    ops = {
        "o_orig": "does this opinion overturn a lower court decision",
        "sur_court": "is any lower court mentioned overturn reversed vacated",
    }
    backends = {"proxy": mk("proxy", proxy_arch, 1, 0.15e-6),
                "oracle": mk("oracle", oracle_arch, 2, 2.50e-6)}
    return CascadeEngine(backends, ops, n_classes=2, batch_size=batch_size)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=32)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean Poisson arrivals per second")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--slot-budget", type=int, default=None,
                    help="per-backend live-slot cap (eviction pressure)")
    ap.add_argument("--retire-after", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    engine = build_engine(args.batch, args.slot_budget, args.retire_after)
    cascade = Cascade([
        Task(TaskConfig("proxy", "sur_court", 0.25), {0: 0.6, 1: 0.6}),
        Task(TaskConfig("proxy", "o_orig", 1.0), {0: 0.65, 1: 0.65}),
    ])
    corpus = generate_corpus(args.docs, avg_lines=12, seed=args.seed)
    docs = {d.doc_id: d.text for d in corpus}
    arrivals = poisson_arrivals(sorted(docs), args.rate, args.seed)

    # warm pass compiles every launch signature; the timed pass streams
    warm_arena(engine, cascade, docs, args.batch)
    res, wall = drive_request_loop(engine, cascade, docs, arrivals)

    stats = res.stats
    n = len(res.pred)
    exits = [res.exit_stage[d] for d in res.pred]
    print(f"streamed {n} docs in {wall:.2f}s "
          f"({n / max(wall, 1e-9):.1f} docs/s; arrival rate {args.rate}/s)")
    print(f"latency p50 {1e3 * stats.latency_quantile(0.5):.0f} ms  "
          f"p99 {1e3 * stats.latency_quantile(0.99):.0f} ms")
    print(f"launches {stats.batches}; cache hit rate "
          f"{stats.cache_hit_rate():.1%}; evictions {stats.evictions}; "
          f"retired buckets {stats.retired_buckets}")
    print(f"exit stages: " + ", ".join(
        f"{s}:{exits.count(s)}" for s in sorted(set(exits))))
    print(f"cost ${res.cost * 1e3:.4f}m; arena bytes " + ", ".join(
        f"{m}={be.arena_nbytes():,}" for m, be in engine.backends.items()))


if __name__ == "__main__":
    main()
