"""Serving entry point: multi-tenant continuous-batching cascade serving
over concurrent arrival streams.

    python -m repro.launch.serve --docs 32 --rate 20 --batch 8 --tenants 2

Simulates a production document feed: each tenant registers its own
cascade on ONE shared ``serving.engine.CascadeServer`` and its Poisson
arrivals are submitted as they land on the wall clock.  The request loop
packs launches across stages AND across tenants (documents from different
queries that share a static signature ride one launch), and per-tenant
latency (submit -> resolve) is reported as p50/p99 alongside batch
occupancy, KV-cache hit rate, evictions, and shared arena bytes.
``--slot-budget`` / ``--byte-budget`` exercise the arena memory-control
paths (preemption + re-prefill; bytes or slots, whichever binds first).

The module also exports the stream drivers used by
``benchmarks/serve_engine.py``: ``poisson_arrivals``,
``drive_request_loop`` (single-query ``CascadeEngine``), and
``drive_server`` (N concurrent streams on one server).
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

from ..config import resolve
from ..configs import get_reduced
from ..core.tasks import Cascade, Task, TaskConfig
from ..data.documents import generate_corpus
from ..data.tokenizer import HashWordTokenizer
from ..models.model import LM
from ..models.runtime import CPU_TEST
from ..serving.engine import (CascadeEngine, CascadeServer, EngineResult,
                              LMBackend, QueryHandle)


def poisson_arrivals(doc_ids, rate: float, seed: int = 0
                     ) -> Dict[int, float]:
    """Arrival offsets (seconds from stream start) with exponential gaps."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(doc_ids))
    return dict(zip(doc_ids, np.cumsum(gaps)))


def drive_request_loop(
    engine: CascadeEngine,
    cascade: Cascade,
    docs: Mapping[int, str],
    arrivals: Mapping[int, float],
    oracle_model: str = "oracle",
) -> Tuple[EngineResult, float]:
    """Run one streaming session against the wall clock.

    Documents are submitted the moment their arrival offset elapses — i.e.
    mid-cascade, between launches, not at stage boundaries — and the
    engine steps whenever work is ready.  The *scheduled* arrival is
    passed as the latency anchor (``arrival_ts``), so recorded latencies
    include any queueing delay.  Returns (result, wall seconds).
    """
    engine.start(cascade, oracle_model)
    order = sorted(docs, key=lambda d: (arrivals[d], d))
    t0 = time.perf_counter()
    i = 0
    while i < len(order) or engine.pending():
        now = time.perf_counter() - t0
        while i < len(order) and arrivals[order[i]] <= now:
            d = order[i]
            engine.submit(d, docs[d], arrival=arrivals[d],
                          arrival_ts=t0 + arrivals[d])
            i += 1
        if engine.pending():
            engine.step()
        elif i < len(order):
            time.sleep(min(arrivals[order[i]] - now, 0.05))
    return engine.result(), time.perf_counter() - t0


def drive_server(
    server: CascadeServer,
    streams: Sequence[Tuple[QueryHandle, Mapping[int, str],
                            Mapping[int, float]]],
) -> Tuple[Dict[int, EngineResult], float]:
    """Run N concurrent query streams against the wall clock on ONE server.

    ``streams`` is ``[(handle, docs, arrivals), ...]`` — every handle must
    be registered on ``server``; arrival offsets share one time axis, so
    the streams genuinely interleave and documents from different queries
    merge into shared launches whenever their signatures agree.  The
    SCHEDULED arrival anchors each latency measurement (pre-submit
    queueing counts).  Returns ({query_id: EngineResult}, wall seconds).
    """
    events: List[Tuple[float, int, int, QueryHandle, str]] = []
    for handle, docs, arrivals in streams:
        for d in docs:
            events.append((arrivals[d], handle.query_id, d, handle, docs[d]))
    events.sort(key=lambda e: e[:3])
    t0 = time.perf_counter()
    i = 0
    while i < len(events) or server.pending():
        now = time.perf_counter() - t0
        while i < len(events) and events[i][0] <= now:
            arr, _, d, handle, text = events[i]
            handle.submit(d, text, arrival=arr, arrival_ts=t0 + arr)
            i += 1
        if server.pending():
            server.step()
        elif i < len(events):
            time.sleep(min(events[i][0] - now, 0.05))
    return ({h.query_id: h.result() for h, _, _ in streams},
            time.perf_counter() - t0)


def warm_arena(engine: CascadeEngine, cascade: Cascade,
               docs: Mapping[int, str], batch_size: int) -> None:
    """Compile every launch signature streaming can produce.

    The request loop dispatches partial groups as documents trickle in,
    so padded batch widths 1, 2, 4, ... up to ``batch_size`` all occur —
    a single full-batch ``run()`` only compiles full-width chunks and the
    first narrow launch would otherwise pay its XLA compile inside the
    timed/streamed pass.  Two subtleties make the warm runs deliberately
    maximal: (1) thresholds are forced IMPOSSIBLE so every warm doc walks
    every stage — real thresholds would let warm docs exit early and
    leave late-stage survivor groups uncompiled; (2) each width runs the
    WHOLE corpus, not a bucket-covering subset, because the arena pytree
    rides through the jitted step and its CAPACITY (grown by doubling
    with the live set) is part of the compiled shape — a subset warm
    stops at a smaller capacity and the measured pass recompiles
    everything the first time the arena doubles past it.
    """
    forced = Cascade([
        Task(t.config, {c: 2.0 for c in range(engine.n_classes)})
        for t in cascade.tasks])
    orig = engine.batch_size
    try:
        bs = 1
        while True:
            engine.batch_size = min(bs, batch_size)
            engine.run(forced, docs)
            if bs >= batch_size:
                break
            bs *= 2
    finally:
        engine.batch_size = orig


def build_engine(batch_size: int, slot_budget: Optional[int],
                 retire_after: int, proxy_arch: str = "llama3_2_1b",
                 oracle_arch: str = "qwen3_1_7b",
                 byte_budget: Optional[int] = None) -> CascadeEngine:
    """Tiny untrained proxy/oracle backends (mechanics demo, CPU-friendly).

    Returns a ``CascadeEngine`` — which IS a ``CascadeServer``, so callers
    can either drive the single-query compatibility API (``run``) or
    ``register`` several queries on it.
    """
    tokz = HashWordTokenizer(vocab_size=512)

    def mk(name, arch, seed, rate):
        cfg = get_reduced(arch, dtype="float32", vocab_size=512, num_layers=2)
        m = LM(resolve(cfg, tp=1), CPU_TEST)
        return LMBackend(name=name, model=m,
                         params=m.init(jax.random.PRNGKey(seed)),
                         tokenizer=tokz, rate_per_token=rate,
                         slot_budget=slot_budget, byte_budget=byte_budget,
                         retire_after=retire_after)

    ops = {
        "o_orig": "does this opinion overturn a lower court decision",
        "sur_court": "is any lower court mentioned overturn reversed vacated",
    }
    backends = {"proxy": mk("proxy", proxy_arch, 1, 0.15e-6),
                "oracle": mk("oracle", oracle_arch, 2, 2.50e-6)}
    return CascadeEngine(backends, ops, n_classes=2, batch_size=batch_size)


def tenant_cascades(n: int) -> List[Cascade]:
    """``n`` distinct query cascades that still OVERLAP in signatures.

    All tenants open with the same cheap surrogate screen (so their
    stage-0 launches merge), then diverge: even tenants escalate to the
    full-document original operation, odd tenants re-run the surrogate at
    full length with tighter thresholds.  The oracle fall-through is
    shared by construction.
    """
    out = []
    for k in range(n):
        if k % 2 == 0:
            out.append(Cascade([
                Task(TaskConfig("proxy", "sur_court", 0.25),
                     {0: 0.6, 1: 0.6}),
                Task(TaskConfig("proxy", "o_orig", 1.0), {0: 0.65, 1: 0.65}),
            ]))
        else:
            out.append(Cascade([
                Task(TaskConfig("proxy", "sur_court", 0.25),
                     {0: 0.6, 1: 0.6}),
                Task(TaskConfig("proxy", "sur_court", 1.0),
                     {0: 0.7, 1: 0.7}),
            ]))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=32,
                    help="documents per tenant stream")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean Poisson arrivals per second, per tenant")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tenants", type=int, default=2,
                    help="concurrent queries registered on one server")
    ap.add_argument("--slot-budget", type=int, default=None,
                    help="per-backend live-slot cap (eviction pressure)")
    ap.add_argument("--byte-budget", type=int, default=None,
                    help="per-backend arena byte cap (eviction pressure)")
    ap.add_argument("--retire-after", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", type=str, default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "measured serving pass (enables level='trace' "
                         "telemetry; open at https://ui.perfetto.dev)")
    args = ap.parse_args()

    server = build_engine(args.batch, args.slot_budget, args.retire_after,
                          byte_budget=args.byte_budget)
    if args.trace_out:
        server.telemetry.level = "trace"
    cascades = tenant_cascades(args.tenants)

    # one corpus, sliced into per-tenant streams on a shared time axis
    corpus = generate_corpus(args.docs * args.tenants, avg_lines=12,
                             seed=args.seed)
    docs = {d.doc_id: d.text for d in corpus}
    ids = sorted(docs)
    streams_docs = [{d: docs[d] for d in ids[k::args.tenants]}
                    for k in range(args.tenants)]

    # warm pass compiles every launch signature any tenant can produce
    # over the COMBINED corpus (arena capacity rides the compiled shape);
    # tenants sharing a cascade signature share one warm pass
    distinct = {tuple(t.config.key() for t in c.tasks): c for c in cascades}
    for cascade in distinct.values():
        warm_arena(server, cascade, docs, args.batch)

    server.reset()
    handles = [server.register(c) for c in cascades]
    streams = [
        (h, sd, poisson_arrivals(sorted(sd), args.rate, args.seed + k))
        for k, (h, sd) in enumerate(zip(handles, streams_docs))]
    results, wall = drive_server(server, streams)

    n = sum(len(r.pred) for r in results.values())
    print(f"streamed {n} docs ({args.tenants} tenants x "
          f"{args.docs}) in {wall:.2f}s ({n / max(wall, 1e-9):.1f} docs/s; "
          f"arrival rate {args.rate}/s per tenant)")
    for h in handles:
        r = results[h.query_id]
        st = r.stats
        exits = [r.exit_stage[d] for d in r.pred]
        print(f"  query {h.query_id}: p50 "
              f"{1e3 * st.latency_quantile(0.5):.0f} ms  p99 "
              f"{1e3 * st.latency_quantile(0.99):.0f} ms; "
              f"cache hit {st.cache_hit_rate():.1%}; "
              f"cost ${r.cost * 1e3:.4f}m; exit stages " + ", ".join(
                  f"{s}:{exits.count(s)}" for s in sorted(set(exits))))
    agg = server.stats()
    print(f"server: {agg.batches} launches; occupancy "
          f"{server.occupancy():.2f} docs/launch; evictions "
          f"{agg.evictions}; retired buckets {agg.retired_buckets}")
    print("arena bytes " + ", ".join(
        f"{m}={be.arena_nbytes():,}" for m, be in server.backends.items()))
    tl = server.telemetry_snapshot()["timeline"]
    print(f"timeline: sched {1e3 * tl['sched_s']:.1f} ms, host "
          f"{1e3 * tl['host_s']:.1f} ms, dispatch "
          f"{1e3 * tl['dispatch_s']:.1f} ms, device "
          f"{1e3 * tl['device_s']:.1f} ms, idle wait "
          f"{1e3 * tl['idle_wait_s']:.1f} ms; mean launch gap "
          f"{tl['mean_launch_gap_ms']:.2f} ms")
    if args.trace_out:
        from ..serving.telemetry import write_chrome_trace
        write_chrome_trace(server.telemetry, args.trace_out)
        print(f"wrote Perfetto trace to {args.trace_out} "
              f"(open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
