"""Serving entry point: batched prefill + decode throughput demo.

    python -m repro.launch.serve --arch qwen3-1.7b --batch 4 --prompt 128 --gen 16

Runs a reduced config on the host mesh; reports prefill/decode wall time.
On TPU this is the serve loop the cascade engine drives per stage.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..config import resolve
from ..configs import get_reduced
from ..models.model import LM
from ..models.runtime import Runtime
from ..models.whisper import WhisperModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch, dtype="float32", vocab_size=2048)
    rcfg = resolve(cfg, tp=1)
    rt = Runtime(attn_impl="xla", remat=False)
    model = LM(rcfg, rt) if cfg.family != "audio" else WhisperModel(rcfg, rt)
    params = model.init(jax.random.PRNGKey(0))

    B, S = args.batch, args.prompt
    s_alloc = S + args.gen
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 9,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frame_emb"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq_len, cfg.d_model))

    prefill = jax.jit(lambda p, b: model.prefill(p, b, s_alloc=s_alloc))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, states = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    pos = jnp.full((B,), S, jnp.int32)
    nxt = jnp.argmax(logits, -1)
    out_tokens = [nxt]
    t1 = time.time()
    for i in range(args.gen):
        logits, states = decode(params, nxt, states, pos + i)
        nxt = jnp.argmax(logits, -1)
        out_tokens.append(nxt)
    nxt.block_until_ready()
    t_decode = time.time() - t1

    print(f"arch={cfg.name} (reduced) B={B} prompt={S} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.0f} ms "
          f"({B * S / max(t_prefill, 1e-9):.0f} tok/s incl. compile)")
    print(f"decode:  {t_decode*1e3:.0f} ms "
          f"({B * args.gen / max(t_decode, 1e-9):.0f} tok/s incl. compile)")
    print("sample token ids:", [int(t[0]) for t in out_tokens[:8]])


if __name__ == "__main__":
    main()
