"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real single CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 per pod, 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (used by tests with small device counts)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """A mesh over whatever devices exist (CPU tests: usually 1x1)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh (pod+data when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
