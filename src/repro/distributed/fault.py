"""Fault tolerance: heartbeats, elastic remesh planning, straggler policy.

On a real deployment these hooks watch per-host liveness; offline they are
driven by tests/examples injecting failures.  The decisions they produce
are the production-relevant artifacts:

``HeartbeatMonitor``   tracks last-beat per participant, flags dead ones
                       (timeout) and stragglers (slowest vs median beat
                       interval), with hysteresis.

``plan_remesh``        given surviving chip count, pick the largest
                       supported mesh <= survivors and emit the restore
                       plan (checkpoint reshard + data-pipeline failover) —
                       elastic scaling uses the mesh-agnostic checkpoint
                       layout (checkpoint.py) and deterministic shard
                       reassignment (data/pipeline.py).

``StragglerPolicy``    serving-side mitigation: re-bucket documents queued
                       on slow shards onto fast ones once slowdown crosses
                       a threshold (see serving/scheduler.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class HeartbeatMonitor:
    timeout_s: float = 30.0
    straggler_factor: float = 2.0
    clock: callable = time.monotonic
    _last: Dict[str, float] = field(default_factory=dict)
    _intervals: Dict[str, List[float]] = field(default_factory=dict)

    def beat(self, who: str, step: Optional[int] = None) -> None:
        now = self.clock()
        if who in self._last:
            self._intervals.setdefault(who, []).append(now - self._last[who])
            self._intervals[who] = self._intervals[who][-16:]
        self._last[who] = now

    def dead(self) -> List[str]:
        now = self.clock()
        return [w for w, t in self._last.items()
                if now - t > self.timeout_s]

    def stragglers(self) -> List[str]:
        avgs = {w: sum(v) / len(v) for w, v in self._intervals.items()
                if len(v) >= 3}
        if len(avgs) < 2:
            return []
        med = sorted(avgs.values())[len(avgs) // 2]
        return [w for w, a in avgs.items()
                if a > self.straggler_factor * max(med, 1e-9)]


# meshes we know how to run, largest first: (shape, axis names)
SUPPORTED_MESHES: Tuple[Tuple[Tuple[int, ...], Tuple[str, ...]], ...] = (
    ((2, 16, 16), ("pod", "data", "model")),
    ((16, 16), ("data", "model")),
    ((8, 16), ("data", "model")),
    ((4, 16), ("data", "model")),
    ((2, 16), ("data", "model")),
    ((1, 16), ("data", "model")),
    ((1, 8), ("data", "model")),
    ((2, 2), ("data", "model")),      # dev-scale fallbacks
    ((1, 4), ("data", "model")),
    ((1, 2), ("data", "model")),
    ((1, 1), ("data", "model")),
)


@dataclass(frozen=True)
class RemeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    chips: int
    batch_scale: float            # new dp size / old dp size
    notes: str = ""

    def dp_size(self) -> int:
        return int(self.chips // self.shape[-1])


def plan_remesh(surviving_chips: int,
                old_dp: int = 16) -> Optional[RemeshPlan]:
    """Largest supported mesh that fits the survivors.

    The model axis is held at 16 (param layout stays valid); the data/pod
    axes shrink, and the caller rescales global batch or raises
    accumulation steps by ``batch_scale`` to keep the optimizer schedule
    meaningful.  Returns None when fewer than one model group survives.
    """
    for shape, axes in SUPPORTED_MESHES:
        chips = 1
        for s in shape:
            chips *= s
        if chips <= surviving_chips:
            dp = chips // shape[-1]
            return RemeshPlan(
                shape, axes, chips, batch_scale=dp / old_dp,
                notes=(f"restore latest checkpoint resharded to {shape}; "
                       f"data pipeline failover keeps shard determinism"))
    return None


@dataclass
class StragglerPolicy:
    """Decide when to migrate queued work off slow serving shards."""
    slowdown_threshold: float = 1.5

    def migrations(self, shard_rates: Dict[int, float]
                   ) -> List[Tuple[int, int]]:
        """shard -> docs/s.  Returns [(from_shard, to_shard), ...]."""
        if len(shard_rates) < 2:
            return []
        items = sorted(shard_rates.items(), key=lambda kv: kv[1])
        med = items[len(items) // 2][1]
        out = []
        fast = [s for s, r in items if r >= med][::-1]
        fi = 0
        for s, r in items:
            if r > 0 and med / r >= self.slowdown_threshold and fast:
                out.append((s, fast[fi % len(fast)]))
                fi += 1
        return out
