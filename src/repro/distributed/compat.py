"""JAX version compatibility shims for the distributed layer.

The codebase targets the jax>=0.5 public API (``jax.shard_map`` with
``check_vma``, ``jax.lax.axis_size``); deployment images sometimes pin
0.4.x where those live under ``jax.experimental.shard_map`` /
``check_rep`` and axis sizes are read via a literal ``psum``.  Everything
that maps over a mesh goes through these two helpers.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def axis_size(axis_name: str) -> int:
    """Static size of a mesh axis from inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    # a psum of the literal 1 is folded to a static int under tracing
    return jax.lax.psum(1, axis_name)
