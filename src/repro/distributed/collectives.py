"""Distributed collectives: SP-KV decode attention, overlap helpers,
gradient compression.

``sp_decode_attention``
    Long-context (batch=1) decode: the KV cache sequence dim is sharded over
    the ``data`` axis.  Each shard runs a local flash-decode over its slice
    and emits (numerator, denominator, max) in log-sum-exp form; partial
    softmaxes are combined with two psums — the flash-decoding pattern
    mapped onto a TPU mesh.

``ring_all_gather`` / ``ring_reduce_scatter``
    Chunked ``lax.ppermute`` rings.  XLA can overlap each permute step with
    the caller's per-chunk compute (``matmul_ag_overlap``), which is how we
    hide weight all-gathers behind matmuls in the ZeRO-1 optimizer path.

``int8_compress`` / ``int8_decompress`` + ``compressed_psum``
    Per-chunk int8 quantization with error feedback for the cross-pod
    gradient all-reduce (pod links are the slowest hop in the 2x16x16 mesh).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels import ops
from .compat import axis_size, shard_map


# ---------------------------------------------------------------------------
# Sequence-parallel (SP-KV) decode attention
# ---------------------------------------------------------------------------

def _local_decode_lse(q, k, v, kv_len, *, sm_scale, shard_offset):
    """Local flash-decode returning log-sum-exp parts.

    q: [B, H, Dh]; k/v: [B, S_local, KV, Dh]; kv_len: [B] *global* valid
    length; shard_offset: [B] global position of this shard's first slot.
    Returns (acc [B,H,Dh] f32 numerator, l [B,H] f32 denominator, m [B,H]).
    """
    B, S, KV, Dh = k.shape
    H = q.shape[1]
    g = H // KV
    qf = q.astype(jnp.float32) * sm_scale
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", qf, kf)              # [B, H, S]
    kpos = shard_offset[:, None] + jnp.arange(S)[None, :]  # [B, S] global pos
    valid = (kpos < kv_len[:, None])[:, None, :]           # [B, 1, S]
    s = jnp.where(valid, s, -jnp.inf)
    m = jnp.max(s, axis=-1)                                # [B, H]
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.where(valid, jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)                                # [B, H]
    acc = jnp.einsum("bhk,bkhd->bhd", p, vf)               # [B, H, Dh]
    return acc, l, m


def sp_decode_attention(
    q: jnp.ndarray,            # [B, H, Dh] replicated over data axis
    k: jnp.ndarray,            # [B, S, KV, Dh] seq sharded over "data"
    v: jnp.ndarray,
    kv_len: jnp.ndarray,       # [B] global valid length
    *,
    mesh: Mesh,
    sm_scale: float,
    axis: str = "data",
) -> jnp.ndarray:
    """Flash-decoding across the mesh: seq-sharded KV, lse-combined output."""
    S_global = k.shape[1]
    n = mesh.shape[axis]
    assert S_global % n == 0, (S_global, n)
    s_local = S_global // n

    def body(q, k, v, kv_len):
        idx = jax.lax.axis_index(axis)
        offset = jnp.full((q.shape[0],), idx * s_local, jnp.int32)
        acc, l, m = _local_decode_lse(
            q, k, v, kv_len, sm_scale=sm_scale, shard_offset=offset)
        # combine partial softmaxes: global max, rescale, two psums
        m_glob = jax.lax.pmax(m, axis)
        m_safe = jnp.where(jnp.isneginf(m_glob), 0.0, m_glob)
        scale = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        acc = jax.lax.psum(acc * scale[..., None], axis)
        l = jax.lax.psum(l * scale, axis)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    tp = "model" if "model" in mesh.axis_names else None
    pod = "pod" if "pod" in mesh.axis_names else None
    kv_heads_sharded = tp is not None and k.shape[2] % mesh.shape.get("model", 1) == 0 \
        and mesh.shape.get("model", 1) > 1 and k.shape[2] >= mesh.shape["model"]
    hspec = tp if kv_heads_sharded else None
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, hspec, None),             # q replicated over seq axis
            P(None, axis, hspec, None),       # k seq-sharded
            P(None, axis, hspec, None),       # v
            P(None),                          # kv_len
        ),
        out_specs=P(None, hspec, None),
    )(q, k, v, kv_len)


# ---------------------------------------------------------------------------
# Ring collectives (chunked, overlappable)
# ---------------------------------------------------------------------------

def ring_all_gather(x: jnp.ndarray, axis_name: str, *, axis: int = 0) -> jnp.ndarray:
    """All-gather via n-1 ppermute steps (inside shard_map).

    Returns the concatenation over the mesh axis along ``axis``.  Written as
    a ring so XLA can overlap each hop with caller compute on the previously
    received chunk.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    perm = [(i, (i + 1) % n) for i in range(n)]
    idx = jax.lax.axis_index(axis_name)
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis_name, perm)
        chunks.append(cur)
    # chunk j in `chunks` came from rank (idx - j) mod n; roll into rank order
    stacked = jnp.stack(chunks, axis=0)                     # [n, ...]
    order = (idx - jnp.arange(n)) % n                       # source rank of chunk j
    # scatter chunks to their source position
    out = jnp.zeros_like(stacked)
    out = out.at[order].set(stacked)
    parts = [jax.lax.index_in_dim(out, i, 0, keepdims=False) for i in range(n)]
    return jnp.concatenate(parts, axis=axis)


def ring_reduce_scatter(x: jnp.ndarray, axis_name: str, *, axis: int = 0) -> jnp.ndarray:
    """Reduce-scatter via n-1 ppermute+add steps (inside shard_map)."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    assert x.shape[axis] % n == 0
    chunk = x.shape[axis] // n
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def get_chunk(arr, j):
        # dynamic slice of chunk j along `axis`
        start = [0] * arr.ndim
        sizes = list(arr.shape)
        sizes[axis] = chunk
        start[axis] = j * chunk
        return jax.lax.dynamic_slice(arr, start, sizes)

    # start with my successor's chunk; accumulate around the ring
    acc = get_chunk(x, (idx + 1) % n)
    for step in range(1, n):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + get_chunk(x, (idx + 1 + step) % n)
    return acc


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------

def int8_compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(
    x: jnp.ndarray,
    axis_name: str,
    error: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int8-compressed all-reduce with error feedback (inside shard_map).

    Compensates ``x + error`` (the residual from the previous step), reduces
    the quantized tensor, and returns (mean-reduced value, new local error).
    Used for the *cross-pod* gradient hop where ICI bandwidth is scarcest;
    in-pod reduction stays full precision.
    """
    n = axis_size(axis_name)
    xc = x.astype(jnp.float32) + (error if error is not None else 0.0)
    q, scale = int8_compress(xc)
    new_error = xc - int8_decompress(q, scale)
    # all-reduce the dequantized value (int8 psum is unsupported; the wire
    # format models 4x fewer bytes — roofline accounting uses 1 byte/elem)
    red = jax.lax.psum(int8_decompress(q, scale), axis_name) / n
    return red.astype(x.dtype), new_error


# ---------------------------------------------------------------------------
# Overlapped TP matmul (all-gather x-shards while computing)
# ---------------------------------------------------------------------------

def matmul_ag_overlap(
    x: jnp.ndarray,             # [B, S/n, D] sequence-sharded activations
    w: jnp.ndarray,             # [D, F_local] weight shard
    axis_name: str,
) -> jnp.ndarray:
    """Compute full-sequence x @ w from seq-sharded x with a compute-overlapped
    ring all-gather: at each of the n steps, matmul the chunk we already have
    while the next chunk is in flight. Returns [B, S, F_local].
    """
    n = axis_size(axis_name)
    if n == 1:
        return x @ w
    perm = [(i, (i + 1) % n) for i in range(n)]
    idx = jax.lax.axis_index(axis_name)
    outs = []
    cur = x
    for step in range(n):
        outs.append(cur @ w)
        if step < n - 1:
            cur = jax.lax.ppermute(cur, axis_name, perm)
    stacked = jnp.stack(outs, axis=0)                      # [n, B, S/n, F]
    order = (idx - jnp.arange(n)) % n
    out = jnp.zeros_like(stacked)
    out = out.at[order].set(stacked)
    parts = [jax.lax.index_in_dim(out, i, 0, keepdims=False) for i in range(n)]
    return jnp.concatenate(parts, axis=1)
