"""Sharding helpers: logical axis specs -> mesh PartitionSpecs.

Modules in ``repro.models`` describe every parameter with a *logical* spec —
a tuple of logical axis names — via their ``spec_*`` functions.  This module
maps logical names to mesh axes:

    "tp"     -> "model"            (tensor parallel)
    "dp"     -> ("pod","data")     (batch / data parallel)
    "ep"     -> "data"             (expert parallel, MoE a2a strategy)
    "sp"     -> "data"             (sequence parallel for long-context KV)
    None     -> replicated

ZeRO-1 optimizer-state sharding is derived per-leaf: the first unsharded
dimension divisible by the dp size is additionally sharded over "data".
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


LOGICAL_TO_MESH = {
    "tp": "model",
    "ep": "data",
    "sp": "data",
    "dp_only": "data",
    None: None,
}


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def logical_to_pspec(logical: Sequence[Optional[str]], mesh: Mesh) -> P:
    """Map a logical axis tuple to a PartitionSpec on ``mesh``."""
    out = []
    for ax in logical:
        if ax == "dp":
            axes = dp_axes(mesh)
            out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        elif ax in LOGICAL_TO_MESH:
            m = LOGICAL_TO_MESH[ax]
            out.append(m if m is None or m in mesh.axis_names else None)
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*out)


def tree_pspecs(logical_tree: Any, mesh: Mesh) -> Any:
    """Map a pytree of logical tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda l: logical_to_pspec(l, mesh),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) > 0
        and all(isinstance(a, (str, type(None))) for a in x),
    )


def tree_shardings(logical_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        tree_pspecs(logical_tree, mesh),
        is_leaf=lambda x: isinstance(x, P),
    )


def zero_pspec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: additionally shard the first eligible dim over 'data'.

    A dim is eligible if it is unsharded in ``pspec`` and divisible by the
    data-axis size.  If none qualifies the spec is returned unchanged
    (moments stay TP-sharded only).
    """
    if "data" not in mesh.axis_names:
        return pspec
    dsize = mesh.shape["data"]
    spec = list(pspec) + [None] * (len(shape) - len(pspec))
    used = set()
    for s in spec:
        if isinstance(s, tuple):
            used.update(s)
        elif s is not None:
            used.add(s)
    if "data" in used:
        return pspec
    for i, (dim, s) in enumerate(zip(shape, spec)):
        if s is None and dim % dsize == 0 and dim >= dsize:
            spec[i] = "data"
            return P(*spec)
    return pspec


def zero_tree_pspecs(param_pspecs: Any, param_shapes: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda p, s: zero_pspec(p, tuple(s.shape), mesh),
        param_pspecs,
        param_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_pspec(mesh: Mesh, *trailing: Optional[str]) -> P:
    """PartitionSpec for [B, ...] arrays: batch over all dp axes."""
    axes = dp_axes(mesh)
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *trailing)


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that is a no-op off-mesh (CPU unit tests)."""
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x
