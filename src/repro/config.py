"""Configuration system for the task-cascades framework.

Every assigned architecture is described by a :class:`ModelConfig`; every
input-shape cell by a :class:`ShapeConfig`.  ``resolve()`` applies the
TP-divisibility padding policy (DESIGN.md §5) and returns a frozen
:class:`ResolvedConfig` that the model zoo consumes.

Configs are plain dataclasses (no framework deps) so that importing this
module never touches jax device state.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
ATTN_FULL = "attn_full"          # full causal self-attention
ATTN_LOCAL = "attn_local"        # sliding-window self-attention
MLSTM = "mlstm"                  # xLSTM matrix-memory block
SLSTM = "slstm"                  # xLSTM scalar-memory block
RGLRU = "rglru"                  # Griffin/RecurrentGemma RG-LRU block
ENC_ATTN = "enc_attn"            # bidirectional encoder self-attention

VALID_BLOCK_KINDS = {ATTN_FULL, ATTN_LOCAL, MLSTM, SLSTM, RGLRU, ENC_ATTN}

# Families
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
VLM = "vlm"
AUDIO = "audio"


def pad_to_multiple(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m``."""
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # "ep_a2a": experts sharded over the data axis via shard_map all-to-all;
    # "tp_dense": experts unsharded on the expert dim, d_ff sharded on model.
    strategy: str = "tp_dense"
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description (pre-padding)."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default d_model // num_heads
    # Repeating block pattern; cycled to fill num_layers.  E.g. gemma3:
    # 5×local + 1×global.  Dense default: (ATTN_FULL,).
    block_pattern: Tuple[str, ...] = (ATTN_FULL,)
    sliding_window: int = 4096           # for ATTN_LOCAL blocks
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False                # qwen3-style per-head RMS on q/k
    logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"                    # mlp activation (silu → SwiGLU)
    # encoder-decoder (whisper): number of encoder layers; decoder layers =
    # num_layers.  None for decoder-only archs.
    encoder_layers: Optional[int] = None
    encoder_seq_len: int = 0             # fixed encoder source length
    # modality frontend stub: if set, input_specs provide precomputed
    # embeddings of this dimension instead of token ids for the frontend part
    frontend_stub: Optional[str] = None  # None | "audio_frames" | "vision_patches"
    frontend_len: int = 0                # stub frontend sequence length
    max_seq_len: int = 131_072
    dtype: str = "bfloat16"
    embed_scale: bool = False            # gemma-style sqrt(d) embed multiplier
    # Pad KV heads up to the TP width so decode KV caches shard cleanly over
    # the model axis (DESIGN.md §5).  MQA archs (kv=1) set False and keep a
    # replicated KV with sequence-sharded flash-decode for long contexts.
    pad_kv_to_tp: bool = True
    # Supported shape cells (by name); long_500k only for sub-quadratic archs
    supported_shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")

    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand block_pattern cyclically over num_layers."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four LM-family shape cells (assigned set).
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ResolvedConfig:
    """ModelConfig after padding policy; consumed by the model zoo."""

    base: ModelConfig
    head_dim: int
    padded_heads: int            # Q heads after padding to TP multiple
    padded_kv_heads: int         # KV heads (>= min(kv, tp) grouping unit)
    padded_vocab: int
    tp: int                      # model-axis size the padding was computed for

    @property
    def name(self) -> str:
        return self.base.name

    @property
    def d_model(self) -> int:
        return self.base.d_model

    @property
    def num_layers(self) -> int:
        return self.base.num_layers

    @property
    def d_ff(self) -> int:
        return self.base.d_ff

    def param_count(self) -> int:
        """Approximate parameter count (dense-equivalent, post-padding)."""
        b = self.base
        d, l = b.d_model, b.num_layers
        h = self.padded_heads * self.head_dim
        hkv = self.padded_kv_heads * self.head_dim
        attn = d * h + 2 * d * hkv + h * d
        if b.moe is not None:
            ff = 3 * d * b.d_ff * b.moe.num_experts + d * b.moe.num_experts
        elif b.d_ff > 0:
            ff = 3 * d * b.d_ff
        else:
            ff = 0
        # ssm blocks approximated as attention-sized
        emb = self.padded_vocab * d * (1 if b.tie_embeddings else 2)
        enc = 0
        if b.encoder_layers:
            enc = b.encoder_layers * (attn + ff)
        return l * (attn + ff) + emb + enc

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts)."""
        b = self.base
        if b.moe is None:
            return self.param_count()
        d, l = b.d_model, b.num_layers
        h = self.padded_heads * self.head_dim
        hkv = self.padded_kv_heads * self.head_dim
        attn = d * h + 2 * d * hkv + h * d
        ff_active = 3 * d * b.d_ff * b.moe.top_k
        emb = self.padded_vocab * d * (1 if b.tie_embeddings else 2)
        return l * (attn + ff_active) + emb


def resolve(cfg: ModelConfig, tp: int = 16) -> ResolvedConfig:
    """Apply the padding policy (DESIGN.md §5) for a given TP width."""
    head_dim = cfg.head_dim or (cfg.d_model // cfg.num_heads)
    padded_heads = pad_to_multiple(cfg.num_heads, tp)
    # KV heads: pad to the TP width when requested (cache shardability —
    # DESIGN.md §5); else keep logical count, replicated across TP sub-groups.
    if cfg.num_kv_heads >= tp:
        padded_kv = pad_to_multiple(cfg.num_kv_heads, tp)
    elif cfg.pad_kv_to_tp:
        padded_kv = tp
    else:
        # must divide padded_heads for GQA grouping
        padded_kv = cfg.num_kv_heads
        if padded_heads % padded_kv != 0:
            # bump kv up to the smallest divisor of padded_heads >= kv
            k = padded_kv
            while padded_heads % k != 0:
                k += 1
            padded_kv = k
    padded_vocab = pad_to_multiple(cfg.vocab_size, tp)
    return ResolvedConfig(
        base=cfg,
        head_dim=head_dim,
        padded_heads=padded_heads,
        padded_kv_heads=padded_kv,
        padded_vocab=padded_vocab,
        tp=tp,
    )


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=min(cfg.num_layers, len(cfg.block_pattern) * 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2),
        d_ff=256 if cfg.d_ff > 0 else 0,
        vocab_size=512,
        head_dim=32,
        sliding_window=64,
        max_seq_len=4096,
        encoder_layers=2 if cfg.encoder_layers else None,
        encoder_seq_len=64 if cfg.encoder_layers else 0,
    )
    if cfg.moe is not None:
        small["moe"] = MoEConfig(
            num_experts=4, top_k=cfg.moe.top_k, strategy="tp_dense"
        )
    if cfg.mrope_sections is not None:
        # rescale M-RoPE sections to the reduced head_dim (keep t:h:w ratio)
        half = small["head_dim"] // 2
        t = half // 4
        hw = (half - t) // 2
        small["mrope_sections"] = (half - 2 * hw, hw, hw)
    if cfg.frontend_len:
        small["frontend_len"] = 8
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
