"""Sharded, mesh-agnostic checkpointing: async, atomic, keep-N.

Layout (one directory per step):

    <dir>/step_000123/
        MANIFEST.json        { leaf_path: {shape, dtype, spec, shards} }
        <leaf>__shard<i>.npy one file per (leaf, shard) — on a multi-host
                             deployment each host writes only the shards it
                             owns; this single-process build writes all of
                             them but keeps the per-shard layout so restore
                             can RESHARD to any mesh (elastic scaling:
                             restore 2x16x16 state onto 16x16 and back).
    <dir>/step_000123.done   commit marker (atomic rename protocol)

Async: `save` snapshots device arrays to host (blocking only for the
device->host copy) and hands serialization to a background thread; `wait`
joins.  Restore: read MANIFEST, assemble each leaf from shards, device_put
with the TARGET sharding (which may differ from the saved one).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _shard_slices(shape, n_shards: int, axis: int):
    """Split `axis` into n_shards contiguous slices."""
    if not shape or n_shards <= 1:
        yield tuple(slice(None) for _ in shape)
        return
    size = shape[axis]
    per = size // n_shards
    for i in range(n_shards):
        sl = [slice(None)] * len(shape)
        sl[axis] = slice(i * per, (i + 1) * per)
        yield tuple(sl)


def _pick_shard_axis(shape) -> int:
    """Largest dim is the shard axis (balanced file sizes)."""
    return int(np.argmax(shape)) if shape else 0


@dataclass
class Checkpointer:
    directory: str
    keep: int = 3
    shards_per_leaf: int = 4
    _pool: ThreadPoolExecutor = field(
        default_factory=lambda: ThreadPoolExecutor(max_workers=2))
    _pending: List[Future] = field(default_factory=list)

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> None:
        """Async checkpoint of a pytree of (device or host) arrays."""
        # snapshot to host NOW so training can mutate params immediately
        host = [(k, np.asarray(v)) for k, v in _flatten_with_paths(tree)]
        self._pending = [f for f in self._pending if not f.done()]
        self._pending.append(
            self._pool.submit(self._write, step, host))

    def _write(self, step: int, host: List[Tuple[str, np.ndarray]]) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for key, arr in host:
            fname_base = key.replace("/", "__")
            n_shards = self.shards_per_leaf if arr.ndim and \
                arr.shape[_pick_shard_axis(arr.shape)] % self.shards_per_leaf == 0 \
                else 1
            axis = _pick_shard_axis(arr.shape)
            for i, sl in enumerate(_shard_slices(arr.shape, n_shards, axis)):
                np.save(os.path.join(tmp, f"{fname_base}__shard{i}.npy"),
                        np.ascontiguousarray(arr[sl]))
            manifest[key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "shards": n_shards,
                "shard_axis": axis,
            }
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic commit
        with open(final + ".done", "w") as f:
            f.write("ok")
        self._gc()

    def wait(self) -> None:
        for f in self._pending:
            f.result()
        self._pending = []

    # --------------------------------------------------------------- restore
    def restore(self, step: int, tree_like: Any,
                shardings: Optional[Any] = None) -> Any:
        """Rebuild a pytree saved at ``step``.

        ``tree_like`` provides the structure; ``shardings`` (optional pytree
        of NamedSharding) targets a possibly DIFFERENT mesh than the one the
        checkpoint was written under — elastic restore.
        """
        self.wait()
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)

        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        leaves = []
        for (path, like), sh in zip(flat, shard_flat):
            key = "/".join(_path_str(p) for p in path)
            meta = manifest[key]
            parts = [np.load(os.path.join(
                d, f"{key.replace('/', '__')}__shard{i}.npy"))
                for i in range(meta["shards"])]
            arr = parts[0] if len(parts) == 1 else np.concatenate(
                parts, axis=meta["shard_axis"])
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # ------------------------------------------------------------------ meta
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)\.done", name)
            if m and os.path.isdir(os.path.join(
                    self.directory, f"step_{int(m.group(1)):08d}")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
            try:
                os.remove(self._step_dir(s) + ".done")
            except OSError:
                pass
