"""Deterministic fault injection for the cascade serving plane.

This module is the chaos half of the fault-tolerance contract: it wraps a
server's ``LMBackend``s in proxies that inject the failure classes the
engine must survive, from a single seeded RNG so every chaos run is
exactly reproducible.

Injected fault classes
----------------------
launch failure   the launch is poisoned at DISPATCH (the model step is
                 never enqueued, so no partial state exists) but the
                 ``InjectedLaunchFailure`` SURFACES at completion — where
                 a real device-side error would surface under async
                 dispatch; the engine re-enqueues each member document
                 solo with backoff.
non-finite conf  one document's confidence entry in the returned batch is
                 overwritten with NaN at completion, *after* a successful
                 step — the billing already happened, mirroring a real
                 model emitting garbage logits.  The engine quarantines
                 that document.
latency spike    completion sleeps ``spike_s`` before syncing (a slow
                 device launch: the host pays the stall when it needs the
                 results), exercising deadline/timeout paths without
                 touching results.
arena loss       at a planned launch index the injector reports the
                 (backend, bucket) holding the most live documents as
                 lost; the engine replays the eviction path (release slot,
                 zero cached length) so the next launch re-prefills.

Determinism: the injector draws a FIXED number of uniforms per dispatch
(one per probabilistic fault class, drawn whether or not the fault
fires) plus one per NaN event — drawn at completion — to pick the
victim row, so the fault schedule depends only on ``FaultPlan.seed`` and
the sequence of launches — not on which faults happened to fire earlier.
With one launch in flight the draw/pick interleaving is exactly the
pre-split order; with K>1, dispatch-order draws plus FIFO-completion
picks keep the schedule a pure function of the dispatch sequence.

Usage::

    injector = FaultInjector(FaultPlan(seed=7, launch_failure_p=0.2))
    injector.install(server)        # wraps server.backends in place
    ... submit / drain as usual ...
    injector.counts                 # {"launch_failures": ..., ...}

The wrappers forward every attribute to the wrapped backend, so the
engine's slot/eviction/billing paths run unmodified; with all
probabilities zero and no arena-loss event the wrapped server is
behaviourally identical to the bare one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import time

import numpy as np

from .telemetry import EV_FAULT


class InjectedFault(RuntimeError):
    """Base class for faults raised by the injection harness."""


class InjectedLaunchFailure(InjectedFault):
    """A launch that failed before its model step committed any state."""


@dataclass(frozen=True)
class FaultPlan:
    """Seeded description of which faults to inject and how often.

    Probabilities are per ``run_group`` call.  ``arena_loss_at`` names the
    1-based launch index *after* which the arena-loss event fires (None
    disables it); ``arena_loss_backend`` pins the victim backend by name
    (None picks the backend+bucket with the most live documents).
    """

    seed: int = 0
    launch_failure_p: float = 0.0
    nan_p: float = 0.0
    latency_spike_p: float = 0.0
    spike_s: float = 0.0
    arena_loss_at: Optional[int] = None
    arena_loss_backend: Optional[str] = None


class FaultInjector:
    """Draws the fault schedule and wraps backends with injecting proxies."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.calls = 0
        self.counts: Dict[str, int] = {
            "launch_failures": 0,
            "nan_confidences": 0,
            "latency_spikes": 0,
            "arena_losses": 0,
        }
        self._arena_loss_armed = plan.arena_loss_at is not None

    # -- per-call schedule -------------------------------------------------
    def draw(self) -> Tuple[bool, bool, bool]:
        """(fail_launch, corrupt_conf, spike) for the next run_group call.

        Always burns exactly three uniforms so the schedule is a pure
        function of the seed and the call index.
        """
        u_fail, u_nan, u_spike = self.rng.uniform(size=3)
        self.calls += 1
        return (u_fail < self.plan.launch_failure_p,
                u_nan < self.plan.nan_p,
                u_spike < self.plan.latency_spike_p)

    def pick_victim(self, n: int) -> int:
        """Row index whose confidence gets corrupted (extra draw)."""
        return int(self.rng.integers(n))

    # -- arena loss --------------------------------------------------------
    def poll_arena_loss(self, launch_idx: int, backends: Dict[str, Any]
                        ) -> List[Tuple[str, int]]:
        """(backend name, bucket) pairs lost after launch ``launch_idx``.

        Fires at most once, at ``plan.arena_loss_at``; the victim is the
        (backend, bucket) with the most live slots — losing an idle arena
        would test nothing.
        """
        if not self._arena_loss_armed or launch_idx < self.plan.arena_loss_at:
            return []
        self._arena_loss_armed = False
        best: Optional[Tuple[str, int]] = None
        best_live = 0
        for name, be in backends.items():
            inner = getattr(be, "_inner", be)
            if (self.plan.arena_loss_backend is not None
                    and name != self.plan.arena_loss_backend):
                continue
            live_by_bucket: Dict[int, int] = {}
            for bucket, _slot in inner._doc_slot.values():
                live_by_bucket[bucket] = live_by_bucket.get(bucket, 0) + 1
            for bucket, live in live_by_bucket.items():
                if live > best_live:
                    best, best_live = (name, bucket), live
        if best is None:
            return []
        self.counts["arena_losses"] += 1
        return [best]

    # -- installation ------------------------------------------------------
    def wrap(self, backend: Any) -> "FaultyBackend":
        return FaultyBackend(backend, self)

    def install(self, server: Any) -> "FaultInjector":
        """Wrap every backend of ``server`` in place and register self."""
        server.backends = {name: self.wrap(be)
                           for name, be in server.backends.items()}
        server.faults = self
        return self


class _InjectedTicket:
    """Fault wrapper around a backend's ``GroupTicket``: carries the
    completion-time effects (spike sleep, injected failure, NaN
    corruption) decided at dispatch.  Poisoned tickets (injected launch
    failure) have NO inner ticket — the failure was decided before the
    model step was enqueued, so no state was committed — and present
    inert defaults for the timeline fields the server reads on the
    failed-record path."""

    __slots__ = ("inner", "fail_exc", "corrupt", "spike_s", "ids")

    _POISONED_DEFAULTS = {"timing": None, "ts_enqueue": 0.0,
                          "ts_dispatched": 0.0, "ts_sync": 0.0,
                          "ts_ready": 0.0, "copy_bytes": 0,
                          "hbm_bytes": None}

    def __init__(self, inner: Any, fail_exc: Optional[Exception],
                 corrupt: bool, spike_s: float, ids: List[int]):
        self.inner = inner
        self.fail_exc = fail_exc
        self.corrupt = corrupt
        self.spike_s = spike_s
        self.ids = ids

    def __getattr__(self, name: str) -> Any:
        inner = object.__getattribute__(self, "inner")
        if inner is not None:
            return getattr(inner, name)
        try:
            return _InjectedTicket._POISONED_DEFAULTS[name]
        except KeyError:
            raise AttributeError(name) from None


class FaultyBackend:
    """Transparent ``LMBackend`` proxy that injects planned faults.

    Everything except the launch path (``dispatch_group`` /
    ``complete_group`` / ``run_group``) forwards to the wrapped backend,
    so slot allocation, eviction, retirement and byte accounting behave
    exactly as without injection.  The fault schedule is drawn at
    dispatch; the fault EFFECTS (sleep, raise, NaN) land at completion —
    where async dispatch surfaces real device errors.
    """

    def __init__(self, inner: Any, injector: FaultInjector):
        object.__setattr__(self, "_inner", inner)
        object.__setattr__(self, "_injector", injector)

    def __getattr__(self, name: str) -> Any:
        return getattr(object.__getattribute__(self, "_inner"), name)

    def __setattr__(self, name: str, value: Any) -> None:
        setattr(object.__getattribute__(self, "_inner"), name, value)

    def dispatch_group(self, *args, **kwargs) -> _InjectedTicket:
        """Draw this launch's fault schedule, then enqueue the real step
        (unless the launch is poisoned — then nothing is enqueued and no
        state commits, exactly the pre-split raise-before-step
        contract).  Counts and EV_FAULT trace events stamp at draw time
        so the injection is visible next to the dispatch that chose it."""
        inj: FaultInjector = object.__getattribute__(self, "_injector")
        inner = object.__getattribute__(self, "_inner")
        # The inner backend shares the server's telemetry handle; injected
        # faults land in the owning documents' span traces (EV_FAULT) so a
        # Perfetto view shows the injection next to the retry/quarantine
        # it provokes.  RNG draw order is untouched: telemetry reads the
        # schedule, it never draws.
        tm = getattr(inner, "telemetry", None)
        ids = args[0] if args else kwargs.get("ids", [])
        fail, corrupt, spike = inj.draw()
        spike_s = inj.plan.spike_s if (spike
                                       and inj.plan.spike_s > 0.0) else 0.0
        if spike_s:
            inj.counts["latency_spikes"] += 1
            if tm is not None and tm.enabled:
                tm.count("serve_injected_faults_total", 1,
                         kind="latency_spike", backend=inner.name)
                if tm.tracing:
                    ts = time.perf_counter()
                    for d in ids:
                        tm.event(d, EV_FAULT, ts,
                                 {"kind": "latency_spike",
                                  "backend": inner.name,
                                  "spike_s": inj.plan.spike_s})
        if fail:
            inj.counts["launch_failures"] += 1
            if tm is not None and tm.enabled:
                tm.count("serve_injected_faults_total", 1,
                         kind="launch_failure", backend=inner.name)
                if tm.tracing:
                    ts = time.perf_counter()
                    for d in ids:
                        tm.event(d, EV_FAULT, ts,
                                 {"kind": "launch_failure",
                                  "backend": inner.name})
            exc = InjectedLaunchFailure(
                f"injected launch failure (call {inj.calls}, "
                f"model={inner.name})")
            return _InjectedTicket(None, exc, False, spike_s, list(ids))
        ticket = inner.dispatch_group(*args, **kwargs)
        return _InjectedTicket(ticket, None, corrupt, spike_s, list(ids))

    def complete_group(self, ticket: _InjectedTicket):
        """Apply the ticket's planned effects where async dispatch
        surfaces them: sleep out a latency spike, raise a poisoned
        launch's failure, and corrupt the victim confidence after a
        successful sync."""
        inj: FaultInjector = object.__getattribute__(self, "_injector")
        inner = object.__getattribute__(self, "_inner")
        tm = getattr(inner, "telemetry", None)
        if ticket.spike_s:
            time.sleep(ticket.spike_s)
        if ticket.fail_exc is not None:
            raise ticket.fail_exc
        pred, conf, new_d, cached_d = inner.complete_group(ticket.inner)
        if ticket.corrupt:
            inj.counts["nan_confidences"] += 1
            conf = np.array(conf, dtype=np.float64, copy=True)
            victim = inj.pick_victim(conf.shape[0])
            conf[victim] = np.nan
            if tm is not None and tm.enabled:
                tm.count("serve_injected_faults_total", 1,
                         kind="nan_conf", backend=inner.name)
                if tm.tracing and victim < len(ticket.ids):
                    tm.event(ticket.ids[victim], EV_FAULT,
                             time.perf_counter(),
                             {"kind": "nan_conf", "backend": inner.name})
        return pred, conf, new_d, cached_d

    def run_group(self, *args, **kwargs):
        """Synchronous composition (one ticket in flight): exactly the
        pre-split fault semantics and RNG draw order."""
        return self.complete_group(self.dispatch_group(*args, **kwargs))
