"""Cascade serving: a long-lived multi-tenant ``CascadeServer`` running a
continuous-batching request loop over real JAX models (slot-arena data
plane).

This is the data-plane twin of ``core.cost_model``: the paper's API prompt
caching becomes PHYSICAL KV-prefix reuse.  Documents ride *before*
operations in the token stream, so

  * extending a document from fraction f_j to f_i > f_j runs the model's
    ``extend`` path over only the new suffix (cached doc-prefix KV reused);
  * switching operations on the same model at the same fraction re-runs
    ONLY the operation tokens against the cached document KV;
  * the engine never merges operation tokens into the cached document
    state, exactly mirroring the doc-before-op prompt layout: on the
    paged data plane op suffixes decode over the arena in place behind a
    tiny KV-window undo log, on the gather plane against a row copy that
    is dropped — either way the cached document prefix survives bitwise
    untouched.

Multi-tenant serving API
------------------------
One server owns the LM backends, their KV arenas, and the global
``scheduler.RequestQueue``; many queries (cascades) are registered and
served CONCURRENTLY over that shared substrate:

    server = CascadeServer(backends, operations, n_classes)
    handle = server.register(cascade, accuracy_target=0.9)   # QueryHandle
    fut    = handle.submit(doc_id, text)                     # DocFuture
    server.step()                            dispatch ONE launch (any query)
    handle.poll()                            this query's fresh resolutions
    handle.result() / server.stats(qid)      per-query results, stats, $
    server.drain()                           step until idle (all queries)

Every submitted document becomes a ``scheduler.DocRequest`` carrying its
owning ``query_id``; the stage cursor resolves ``(model, op, fraction)``
through the handle's stage table.  Because the launch signature
``(backend, bucket, cached_len, op, f_len)`` carries neither stage index
nor query id, ``RequestQueue.next_launch`` packs ready documents ACROSS
queries: a stage-0 prefill for query A and a stage-2 decode for query B
merge into one launch whenever their static shapes agree, and mixed-query
launches share compiled steps, op-token memos, and KV slots in one arena
pool.  Results, ``ServeStats``, and $-accounting stay partitioned per
query.  Which ready group dispatches next is a pluggable ``policy``
(default ``scheduler.oldest_head_first``; admission is fair across
queries because ``(arrival, seq)`` is server-global FIFO).

``CascadeEngine`` survives as the single-query compatibility wrapper:
``start(cascade)`` registers one query on a private session and
``submit/step/poll/drain/run`` delegate to it — ``run()`` is bit-identical
(preds, confs, per-document $) to the pre-server engine on static corpora.

Arena layout, slot lifecycle & memory control
---------------------------------------------
Per (backend, length bucket) the server keeps one persistent
``arena.BucketArena``: a batched state pytree ``[n_slots + 1, ...,
s_alloc, ...]`` (s_alloc = bucket + operation reserve; the extra row is
scratch for batch padding).  A document is assigned a slot on first touch
and keeps it until it exits its cascade — unless a backend budget binds.
Budgets are dual: ``slot_budget`` caps live slots, ``byte_budget`` caps
device bytes across the backend's arenas (projected via
``arena_nbytes()`` + the growth the pending launch would force), and
eviction triggers on whichever binds first.  Victims are chosen
fewest-cached-tokens-lost first (newest arrival breaks ties): the evicted
document re-enters the queue at its current stage with ``cached_len = 0``
and re-prefills as new tokens.  Under byte pressure a bucket emptied by
eviction is retired IMMEDIATELY (its arena freed); otherwise buckets
whose live-slot count stays zero for ``retire_after`` launches are
retired in the background, so a drifting length mix does not pin memory.
Survivor compaction is an index gather (``LM.take_states``) and a scatter
back (``LM.put_states``) inside one jitted step — no per-document pytree
stacking/slicing on the host.

Stage steps compile once per static signature ``(bucket, cached_len,
new_len, op_len, batch)`` — note: no stage index and no query id, so
interleaved stages AND interleaved queries share compiled steps.
Prefill-into-arena is the ``cached_len == 0`` case of extend, fraction
extension writes the suffix at a static offset with per-row true lengths
masking bucket PAD out of the chunk (``kernels/flash_attention.py``
scalar-prefetch ``kv_len``), and the operation suffix runs as masked
decode steps whose per-document ``kv_len`` rides through
``kernels/decode_attention.py``.

Paged data plane (default on Pallas runtimes, for models whose
serve-state is all full-attention KV caches): the stage step never
copies arena rows.  Per-sequence slot ids ride in scalar-prefetch SMEM
beside ``kv_len`` and the paged kernels
(``ops.arena_decode_attention`` / ``ops.attention_paged``) DMA
``k_arena[slot]`` blocks directly, so extend scatters only the chunk's
KV and decode reads the arena in place — per-launch copy traffic drops
from O(batch * s_alloc) (the gather/scatter of whole rows) to the
O(batch * op_len) op-suffix undo log (see ``LMBackend.paged_step``'s
comments; ``gather_bytes_per_launch`` vs ``paged_copy_bytes_per_launch``
quantify it).  Results are BITWISE identical to the gather plane —
preds, confs, per-document $, and the arena contents itself — which
``tests/test_serving.py`` asserts; the gather step survives as the
reference/CPU plane (``paged=False``, XLA/naive impls).

Token accounting (new vs cached, true unpadded counts), per-stage $ cost,
per-document latencies, evictions, and retired buckets are recorded in a
per-query ``ServeStats`` with the same rates as the analytical cost
model, so engine costs are directly comparable to ``run_cascade`` in
tests; ``server.stats()`` aggregates across queries (launches counted
once, however many queries shared them).

Failure model (fault-tolerant serving plane)
--------------------------------------------
Every submitted document reaches exactly one terminal state —
``RESOLVED``, ``FAILED``, or ``TIMED_OUT`` — surfaced on its
``DocFuture`` (``.status`` / ``.error``); ``drain()`` never hangs on a
fault.  The machinery, front to back:

  launch failure   ``run_group`` raising (backends commit arena state
                   only after a successful step, so a failed launch
                   leaves no partial state) re-enqueues each member
                   document SOLO with capped-exponential backoff
                   (``RetryPolicy``) — launch-level isolation: one
                   poisoned document in a packed cross-query launch
                   cannot fail its cohort, because retries run in
                   singleton groups.  Documents exceeding
                   ``retry.max_retries`` resolve ``FAILED``.
  deadline         ``submit(..., deadline_s=...)`` bounds a document's
                   wall-clock; expired documents are popped from the
                   queue each step and resolve ``TIMED_OUT`` (deadline
                   beats backoff).
  quarantine       a non-finite confidence (NaN/Inf logits upstream) is
                   caught post-launch — the launch is already billed —
                   and the document retries solo at the same stage; a
                   second non-finite result escalates it straight to the
                   final (oracle) stage as graceful degradation, and a
                   non-finite FINAL stage resolves it ``FAILED``.
  circuit breaker  ``breaker_threshold`` consecutive launch failures on
                   one backend open it for ``breaker_cooldown`` launch
                   attempts; queued stages that would run on the sick
                   backend are rerouted to the NEXT cascade stage (and
                   billed as that stage) until the breaker half-opens.
                   The final stage is never skipped.
  arena loss       a lost (backend, bucket) replays the existing
                   eviction path — slots released, ``cached_len`` zeroed
                   — so survivors re-prefill exactly like evicted
                   documents (``recovered_docs`` counts them).
  watchdog         ``stall_limit`` consecutive no-progress steps (zero
                   launches, zero resolutions, nothing legitimately in
                   backoff) raise ``ServerStalledError`` listing the
                   stuck requests instead of spinning forever.
  journal          a write-ahead ``RequestJournal`` (submit records
                   written BEFORE the queue admit, resolutions after)
                   enables ``CascadeServer.recover(journal)`` warm
                   restart: resolved documents are restored verbatim
                   (same preds/$, no recompute), unresolved ones are
                   re-submitted with identical ids and accounting.

``ServeStats`` carries the fault counters (retries, quarantines,
timeouts, failures, breaker trips, recovered docs) and the per-launch
billing ledger (``server.ledger()``) replays per-query $ exactly.  With
no faults injected and no deadlines set, every addition above is inert:
the fault-free path is bitwise identical to the pre-fault engine.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import (Any, Dict, List, Mapping, Optional, Sequence, Set,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tasks import Cascade
from ..data.tokenizer import PAD, HashWordTokenizer, class_token
from .arena import BucketArena
from .scheduler import (FAILED, RESOLVED, TIMED_OUT, DocRequest, LaunchSpec,
                        RequestQueue, RetryPolicy, SchedulingPolicy,
                        ServeStats, SlotAllocator, StageConfig, fraction_len)
from .telemetry import (EV_COW_COPY, EV_ESCALATE, EV_EVICT, EV_LAUNCH,
                        EV_PREFIX_HIT, EV_QUARANTINE, EV_RETRY, EV_SUBMIT,
                        LaunchRecord, Telemetry)

_bw_utilization = None     # lazy launch/roofline import (avoids a cycle)


def _bw_util(bytes_moved: float, seconds: float) -> float:
    global _bw_utilization
    if _bw_utilization is None:
        from ..launch.roofline import bandwidth_utilization
        _bw_utilization = bandwidth_utilization
    return _bw_utilization(bytes_moved, seconds)


class ServerStalledError(RuntimeError):
    """``drain()``/``step()`` detected a live-locked server: ``stall_limit``
    consecutive steps made no progress (no launch, no resolution) while
    nothing was legitimately waiting out a retry backoff.  ``stuck`` lists
    ``(query_id, ext_id, stage, retries, not_before)`` per wedged request.
    """

    def __init__(self, message: str,
                 stuck: List[Tuple[int, int, int, int, float]]):
        super().__init__(message)
        self.stuck = stuck


@dataclass
class BackendHealth:
    """Consecutive-failure circuit breaker state for one backend.

    ``threshold`` straight launch failures open the breaker for
    ``cooldown`` launch attempts (server-global attempt counter); while
    open, the server reroutes the backend's queued stages to the next
    cascade stage.  After the cooldown the breaker half-opens: the next
    launch probes the backend, and a further failure re-trips it.
    """

    threshold: int = 3
    cooldown: int = 8
    consecutive_failures: int = 0
    opened_at: Optional[int] = None     # attempt index the breaker opened
    trips: int = 0

    def record_failure(self, attempt_idx: int) -> bool:
        """Note one launch failure; True when this failure TRIPS the
        breaker (fresh trip or re-trip after an expired cooldown)."""
        self.consecutive_failures += 1
        if (self.consecutive_failures >= self.threshold
                and not self.is_open(attempt_idx)):
            self.opened_at = attempt_idx
            self.trips += 1
            return True
        return False

    def record_success(self) -> None:
        self.consecutive_failures = 0
        self.opened_at = None

    def is_open(self, attempt_idx: int) -> bool:
        return (self.opened_at is not None
                and attempt_idx < self.opened_at + self.cooldown)


class RequestJournal:
    """Write-ahead request journal enabling warm-restart recovery.

    ``record_submit`` runs BEFORE the request enters the queue and
    ``record_resolution`` after a terminal state is reached, so at any
    crash point the journal names every admitted document and exactly
    which ones are unresolved.  ``CascadeServer.recover(journal)`` on a
    fresh server (same cascades registered in the same order) restores
    resolved documents verbatim — original pred/conf/$, no recompute —
    and re-submits unresolved ones with identical external ids,
    arrivals, and deadline semantics.
    """

    def __init__(self) -> None:
        self.registrations: List[int] = []          # qids in register order
        self.submits: List[Dict[str, Any]] = []
        self.resolutions: Dict[Tuple[int, int], Dict[str, Any]] = {}

    def record_register(self, query_id: int) -> None:
        self.registrations.append(query_id)

    def record_submit(self, query_id: int, ext_id: int, text: str,
                      arrival: Optional[float], stage: int,
                      deadline_s: Optional[float]) -> None:
        self.submits.append(dict(
            query_id=query_id, ext_id=ext_id, text=text, arrival=arrival,
            stage=stage, deadline_s=deadline_s))

    def record_resolution(self, req: DocRequest) -> None:
        self.resolutions[(req.query_id, req.ext_id)] = dict(
            status=req.status, pred=req.pred, conf=req.conf,
            exit_stage=req.exit_stage, cost=float(req.cost),
            error=req.error)

    def unresolved(self) -> List[Dict[str, Any]]:
        return [s for s in self.submits
                if (s["query_id"], s["ext_id"]) not in self.resolutions]


def _pad_width(n: int) -> int:
    """Static launch width: next power of two (few compiled batch shapes)."""
    return 1 << max(n - 1, 0).bit_length()


@dataclass
class GroupTicket:
    """One in-flight stage launch: the non-blocking ``dispatch_group``
    half returns this; ``complete_group`` consumes it.

    ``logits``/``states`` are device FUTURES (JAX async dispatch) — the
    jitted step has been enqueued but nothing has been waited on.  The
    sanitizer bracket (``san_ticket``) stays OPEN across the ticket's
    lifetime, so any structural arena operation (clear/release/retire)
    that touches the ticket's rows while it is in flight raises
    ``ArenaRaceError`` — exactly the race the brackets were built to
    audit.  Host-side billing metadata (``new_d``/``cached_d``/
    ``op_len``) and structural traffic (``copy_bytes``/``hbm_bytes``)
    are captured at dispatch so concurrent tickets never race on backend
    scratch attributes."""

    ids: List[int]
    bucket: int
    n_classes: int
    logits: Any                      # device future [Bp, vocab]
    states: Any                      # device future (arena pytree)
    new_d: np.ndarray                # per-doc new true tokens
    cached_d: np.ndarray             # per-doc cached true tokens
    op_len: int                      # billed op suffix (P on prefix plane)
    san: Any                         # ArenaSanitizer or None
    san_ticket: Any                  # open begin_launch bracket (or None)
    timing: Dict[str, float]         # host/dispatch at dispatch; +device
    ts_enqueue: float                # jit call began (dispatch segment)
    ts_dispatched: float             # dispatch_group returned control
    copy_bytes: int
    hbm_bytes: Optional[float]
    ts_sync: float = 0.0             # block_until_ready entered
    ts_ready: float = 0.0            # device results host-visible


@dataclass
class LMBackend:
    """A model + params behind the server, with a slot-based KV arena."""

    name: str
    model: Any                       # models.model.LM (or compatible)
    params: Any
    tokenizer: HashWordTokenizer
    rate_per_token: float = 1.0      # $ parity with the analytical model
    cached_discount: float = 0.5
    # NOTE: arenas size per-slot allocation as bucket + op_reserve (rounded
    # to a decode block on pallas runtimes); ``s_alloc`` is kept for seed
    # API compatibility and no longer bounds arena memory.
    s_alloc: int = 4096
    op_reserve: int = 64             # suffix headroom past the bucket length
    init_slots: int = 8              # initial arena capacity per bucket
    slot_budget: Optional[int] = None  # max live slots across buckets
    byte_budget: Optional[int] = None  # max device bytes across arenas
    retire_after: int = 64           # idle launches before bucket retirement
    # Paged data plane: None = auto (on for Pallas runtimes when the model
    # is paged-capable — every serve-state leaf a full-attention KV cache).
    # True forces it (XLA/naive impls fall back to a per-call gather inside
    # the kernels wrappers — reference semantics, not the fast path); False
    # forces the PR-1 gather/scatter stage step.
    paged: Optional[bool] = None
    # Arena STORAGE dtype for KV-cache leaves ("bfloat16" compresses an
    # f32 model's arenas to half the bytes; int8 is staged behind the same
    # switch).  Quantization happens on the extend/decode scatter; the
    # attention kernels upcast to f32 at read (DMA-time dequant), so the
    # $-ledger — billed from token counts, never physical bytes — is
    # exactly unchanged.  None stores the compute dtype.
    kv_dtype: Optional[str] = None
    # Opt-in PREFIX SHARING (op-first prompt layout): operation tokens sit
    # at positions [0, P) and are prefilled ONCE per (backend, op, bucket)
    # into a pinned refcounted arena row; every attached document's
    # leading block-table columns point at that row, with a copy-on-write
    # partial-block copy into the document's private row where the op
    # remainder and doc tokens share a block.  Requires the paged plane
    # (block tables).  The default (False) keeps the doc-before-op layout
    # bitwise unchanged.
    prefix_sharing: bool = False
    prefix_hits: int = 0             # attaches to a shared prefix row
    cow_copies: int = 0              # partial-block copy-on-write copies
    _arenas: Dict[int, BucketArena] = field(default_factory=dict)
    _alloc: SlotAllocator = field(default_factory=SlotAllocator)
    _doc_slot: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    _idle: Dict[int, int] = field(default_factory=dict)
    _slot_nbytes: Dict[int, int] = field(default_factory=dict)
    _prefix_ids: Dict[Tuple[int, str], int] = field(default_factory=dict)
    _next_prefix_id: int = -1        # pseudo doc ids for prefix rows (< 0,
    #                                  disjoint from server request ids >= 0)
    _step: Optional[Any] = None      # jitted stage step (lazy)
    _prefix_step: Optional[Any] = None   # jitted prefix-layout step (lazy)
    pressure_retired: int = 0        # buckets freed mid-eviction (byte budget)
    # Derived view kept for compatibility: host assembly + async dispatch
    # wall-clock, exactly the pre-telemetry lumped scalar.  The per-launch
    # decomposition (host/dispatch/device) lives in ``last_timing`` and is
    # folded into the server's launch timeline (serving/telemetry.py).
    host_overhead_s: float = 0.0
    telemetry: Optional[Any] = field(default=None, repr=False)  # Telemetry
    last_timing: Optional[Dict[str, float]] = field(default=None, repr=False)
    last_copy_bytes: int = field(default=0, repr=False)
    last_hbm_bytes: Optional[float] = field(default=None, repr=False)
    _params_nbytes: Optional[int] = field(default=None, repr=False)
    # Runtime arena sanitizer (analysis.sanitizer.ArenaSanitizer): per-row
    # ownership epochs + launch read/write-set brackets that turn silent
    # slot-aliasing races into a diagnostic ``ArenaRaceError``.  None =
    # follow the ARENA_SANITIZE env var; True/False force it.  The checks
    # are host-side metadata only — device math, the $-ledger, RNG draws
    # and hub telemetry counters are bitwise unaffected (violations, which
    # abort the run anyway, are the only hub-visible events).
    sanitize: Optional[bool] = None
    # callback rid -> {"query":..., "doc":...} installed by CascadeServer
    # so sanitizer diagnostics can name the owning query/document
    doc_info: Optional[Any] = field(default=None, repr=False)
    _sanitizer: Optional[Any] = field(default=None, repr=False)

    def sanitizer(self):
        """The active ``ArenaSanitizer`` (lazily built), or None when off."""
        enabled = self.sanitize
        if enabled is None:
            from ..analysis.sanitizer import env_enabled
            enabled = env_enabled()
        if not enabled:
            return None
        if self._sanitizer is None:
            from ..analysis.sanitizer import ArenaSanitizer
            self._sanitizer = ArenaSanitizer(backend=self.name,
                                             doc_info=lambda rid: (
                                                 self.doc_info(rid)
                                                 if self.doc_info else None),
                                             telemetry=self.telemetry)
        self._sanitizer.telemetry = self.telemetry   # server may install late
        return self._sanitizer

    def reset(self) -> None:
        self._arenas.clear()
        self._alloc.reset()
        self._doc_slot.clear()
        self._idle.clear()
        self._prefix_ids.clear()
        self.prefix_hits = 0
        self.cow_copies = 0
        self.pressure_retired = 0
        self.host_overhead_s = 0.0
        self.last_timing = None
        self.last_copy_bytes = 0
        self.last_hbm_bytes = None
        if self._sanitizer is not None:
            self._sanitizer.reset()
        # the jitted step closes over model only; its compile cache survives
        # (telemetry handle survives too — the server owns its lifecycle)

    def params_nbytes(self) -> int:
        """Device bytes of the parameter set (memoized): the fixed term
        of the decode-launch HBM-traffic estimate."""
        if self._params_nbytes is None:
            self._params_nbytes = int(sum(
                leaf.size * leaf.dtype.itemsize
                for leaf in jax.tree_util.tree_leaves(self.params)))
        return self._params_nbytes

    def _note_launch_traffic(self, bucket: int, batch: int, op_len: int,
                             n_new: int, kv_true: np.ndarray) -> None:
        """Per-launch structural traffic for the telemetry timeline:
        copy/undo-log bytes (same model the paged benchmark gates) and,
        for decode-only launches, the estimated HBM bytes the step
        streams (params once per suffix token + the batch's live KV)."""
        if self.uses_paged_kv():
            self.last_copy_bytes = self.paged_copy_bytes_per_launch(
                bucket, batch, op_len)
        else:
            self.last_copy_bytes = self.gather_bytes_per_launch(bucket,
                                                                batch)
        if n_new == 0:
            s_alloc = self._s_alloc_for(bucket)
            kv_bytes = (float(kv_true[:batch].sum())
                        * self.slot_nbytes(bucket) / s_alloc)
            self.last_hbm_bytes = op_len * (self.params_nbytes() + kv_bytes)
        else:
            self.last_hbm_bytes = None

    # ------------------------------------------------------------ slot admin
    def cached_len(self, doc_id: int) -> int:
        """Padded cached-prefix length of ``doc_id`` (0 when uncached)."""
        bs = self._doc_slot.get(doc_id)
        if bs is None:
            return 0
        bucket, slot = bs
        return int(self._arenas[bucket].cached_len[slot])

    def true_cached_len(self, doc_id: int) -> int:
        """TRUE (unpadded) cached tokens of ``doc_id`` — what an eviction
        would actually lose (and re-bill as new tokens)."""
        bs = self._doc_slot.get(doc_id)
        if bs is None:
            return 0
        bucket, slot = bs
        return int(self._arenas[bucket].true_len[slot])

    def has_slot(self, doc_id: int) -> bool:
        return doc_id in self._doc_slot

    def live_slots(self) -> int:
        return len(self._doc_slot)

    def live_docs(self) -> List[int]:
        return list(self._doc_slot)

    def cached_op(self, doc_id: int) -> Optional[str]:
        """Operation id the document's cached prefix was built under
        (prefix-sharing arenas only; None when uncached/untracked)."""
        bs = self._doc_slot.get(doc_id)
        if bs is None:
            return None
        bucket, slot = bs
        ar = self._arenas.get(bucket)
        return None if ar is None else ar.slot_op.get(slot)

    def release(self, doc_id: int) -> None:
        """Free the document's slot (it exited the cascade or was evicted)."""
        bs = self._doc_slot.pop(doc_id, None)
        if bs is not None:
            bucket, slot = bs
            ar = self._arenas.get(bucket)
            if ar is not None:
                ar.detach_prefix(slot)     # unpin the shared op-prefix row
                if ar.sanitizer is not None:
                    ar.sanitizer.note_release(bucket, slot)
            self._alloc.release(bucket, doc_id)

    # ------------------------------------------------------- memory control
    def arena_nbytes(self) -> int:
        """Total device bytes pinned by this backend's arenas."""
        return sum(ar.nbytes() for ar in self._arenas.values())

    def _kv_jnp_dtype(self):
        return None if self.kv_dtype is None else jnp.dtype(self.kv_dtype)

    def slot_nbytes(self, bucket: int) -> int:
        """Device bytes one arena row of ``bucket`` pins.

        Computed from state SHAPES (``jax.eval_shape`` semantics — nothing
        is materialized) AT THE STORED DTYPE — a bf16-compressed arena
        row bills half an f32 row — so the byte budget can project the
        cost of a bucket whose arena does not exist yet and the billing
        matches ``arena.nbytes()`` exactly.
        """
        n = self._slot_nbytes.get(bucket)
        if n is None:
            if self.kv_dtype is None:
                shapes = self.model.state_shapes(1, self._s_alloc_for(bucket))
            else:
                shapes = self.model.state_shapes(
                    1, self._s_alloc_for(bucket),
                    kv_dtype=self._kv_jnp_dtype())
            n = sum(int(math.prod(l.shape)) * np.dtype(l.dtype).itemsize
                    for l in jax.tree.leaves(shapes))
            self._slot_nbytes[bucket] = n
        return n

    def _initial_capacity(self, bucket: int) -> int:
        """Capacity a NEW arena for ``bucket`` opens with: ``init_slots``,
        shrunk to what the byte budget can host beside existing arenas
        (>= 1 — a single slot always proceeds, even over budget)."""
        cap = self.init_slots
        if self.byte_budget is not None:
            s = self.slot_nbytes(bucket)
            avail = (self.byte_budget - self.arena_nbytes()) // s - 1
            cap = min(cap, avail)
        return max(cap, 1)

    def projected_nbytes(self, bucket: int, need_new: int) -> int:
        """Arena bytes after ``bucket`` grows to host ``need_new`` more
        slots (free-list reuse, budget-capped initial capacity, and
        capacity doubling modelled exactly)."""
        total = self.arena_nbytes()
        free = self._alloc.high_water(bucket) - self._alloc.live(bucket)
        grow_to = self._alloc.high_water(bucket) + max(need_new - free, 0)
        ar = self._arenas.get(bucket)
        if ar is None:
            if need_new <= 0:
                return total
            rows_now, new_cap = 0, self._initial_capacity(bucket)
        else:
            rows_now, new_cap = ar.capacity + 1, ar.capacity
        while new_cap < grow_to:
            new_cap *= 2
        return total + ((new_cap + 1) - rows_now) * self.slot_nbytes(bucket)

    def over_budget(self, bucket: int, need_new: int) -> bool:
        """Would hosting ``need_new`` fresh slots in ``bucket`` bust either
        budget?  Slots and bytes are checked independently — eviction
        triggers on whichever binds first."""
        if (self.slot_budget is not None
                and self.live_slots() + need_new > self.slot_budget):
            return True
        if (self.byte_budget is not None
                and self.projected_nbytes(bucket, need_new) > self.byte_budget):
            return True
        return False

    def admissible_new(self, bucket: int, need: int) -> int:
        """Largest prefix of ``need`` fresh allocations both budgets can
        host (>= 1: a single document always proceeds, so launches cannot
        livelock under an impossibly small budget)."""
        k = need
        while k > 1 and self.over_budget(bucket, k):
            k -= 1
        return k

    def evict_for_room(self, bucket: int, need_new: int,
                       victims: Sequence[int]) -> List[int]:
        """Preempt slots until ``need_new`` allocations for ``bucket`` fit
        both budgets.

        ``victims`` is the caller's priority order, lowest first (the
        server passes fewest-cached-tokens-lost first, newest arrival
        breaking ties, and excludes the launch being packed).  Returns the
        evicted doc ids; the caller re-queues them with ``cached_len = 0``.
        Under byte pressure a bucket emptied by eviction is retired
        immediately (``pressure_retired`` counts them for stats) — slot
        recycling alone frees no bytes, dropping the arena does.  Stops
        early when the victim list runs out — the launch is then trimmed
        by the server rather than over-committing the arena.
        """
        evicted: List[int] = []
        if self.slot_budget is None and self.byte_budget is None:
            return evicted
        # unreferenced prefix rows go first: dropping the memo costs one
        # re-prefill later but frees a slot without losing any document's
        # cache (pinned rows — refs > 0 — are never touched here)
        if self.over_budget(bucket, need_new):
            self._reclaim_prefix_rows(bucket)
        for d in victims:
            if not self.over_budget(bucket, need_new):
                break
            bs = self._doc_slot.get(d)
            if bs is None:
                continue
            vb = bs[0]
            slot_over = (self.slot_budget is not None
                         and self.live_slots() + need_new > self.slot_budget)
            if not slot_over:
                # byte pressure alone: a same-bucket victim only helps by
                # avoiding GROWTH (freed slots are recycled; releasing
                # them frees no bytes).  An arena already irreducibly
                # over budget must not thrash its residents' caches.
                grows = (self.projected_nbytes(bucket, need_new)
                         > self.arena_nbytes())
                if vb == bucket and not grows:
                    continue
            self.release(d)
            evicted.append(d)
            if (self.byte_budget is not None and vb != bucket
                    and vb in self._arenas and self._live_real(vb) == 0):
                self.retire(vb)
                self.pressure_retired += 1
        return evicted

    def _live_real(self, bucket: int) -> int:
        """Live DOCUMENT slots in ``bucket`` (prefix pseudo-slots, which
        hold shared op rows rather than documents, excluded)."""
        ar = self._arenas.get(bucket)
        n_prefix = len(ar.prefix_row) if ar is not None else 0
        return self._alloc.live(bucket) - n_prefix

    def _reclaim_prefix_rows(self, bucket: int) -> None:
        """Free every UNREFERENCED prefix row of ``bucket`` (slot returns
        to the free list; the op re-prefills on next use)."""
        ar = self._arenas.get(bucket)
        if ar is None:
            return
        for op_key in ar.unreferenced_prefix_ops():
            row = ar.drop_prefix(op_key)   # arena hook unpins for sanitizer
            if ar.sanitizer is not None:
                ar.sanitizer.note_release(bucket, row)
            pid = self._prefix_ids.pop((bucket, op_key), None)
            if pid is not None:
                self._alloc.release(bucket, pid)

    def note_launch(self) -> int:
        """Bucket retirement hook, called once per server step (on every
        backend, so one that stops receiving launches still ticks).

        A bucket whose live-slot count has been zero for ``retire_after``
        consecutive ticks has drifted out of the workload's length mix:
        its device arena is freed (``retire``).  Returns how many buckets
        were retired.
        """
        retired = 0
        for bucket in list(self._arenas):
            if self._live_real(bucket) == 0:
                self._idle[bucket] = self._idle.get(bucket, 0) + 1
                if self._idle[bucket] >= self.retire_after:
                    self.retire(bucket)
                    retired += 1
            else:
                self._idle[bucket] = 0
        return retired

    def retire(self, bucket: int) -> None:
        """Free an idle bucket's arena (no live DOCUMENT slots; prefix
        rows — necessarily unreferenced once the documents are gone — are
        dropped with it, memo included)."""
        assert self._live_real(bucket) == 0, \
            f"bucket {bucket} retired with live slots"
        self._reclaim_prefix_rows(bucket)
        ar = self._arenas.pop(bucket, None)
        if ar is not None and ar.sanitizer is not None:
            ar.sanitizer.note_retire(bucket)
        self._alloc.retire_bucket(bucket)
        self._idle.pop(bucket, None)

    def _s_alloc_for(self, bucket: int) -> int:
        s_alloc = bucket + self.op_reserve
        impl = getattr(self.model.rt, "attn_impl", "")
        if impl.startswith("pallas") or self.prefix_sharing:
            # keep the decode kernel's cache axis a block multiple so
            # ops.decode_attention never pads K/V copies per step.  Prefix
            # sharing rounds on EVERY impl: block tables are full-width
            # [B, s_alloc // block] and the gather reference must agree
            # with the Pallas plane on the table geometry.
            blk = getattr(self.model.rt, "block_kv", 512)
            if s_alloc > blk:           # <= blk is always a single block
                s_alloc = -(-s_alloc // blk) * blk
        return s_alloc

    def _block_size(self, bucket: int) -> int:
        """Block-table granularity for ``bucket``: the decode kernel's kv
        block, clamped to the row length (matches the effective block the
        Pallas dispatch conditions in ``kernels.ops`` require)."""
        s_alloc = self._s_alloc_for(bucket)
        return min(getattr(self.model.rt, "block_kv", 512), s_alloc)

    def _arena(self, bucket: int) -> BucketArena:
        ar = self._arenas.get(bucket)
        if ar is None:
            ar = BucketArena(self.model, bucket, self._s_alloc_for(bucket),
                             capacity=self._initial_capacity(bucket),
                             kv_dtype=self._kv_jnp_dtype(),
                             sanitizer=self.sanitizer())
            self._arenas[bucket] = ar
        return ar

    def _slot_for(self, bucket: int, doc_id: int, arena: BucketArena) -> int:
        prev = self._doc_slot.get(doc_id)
        assert prev is None or prev[0] == bucket, \
            f"doc {doc_id} already staged in bucket {prev[0]}, got {bucket}"
        slot = self._alloc.peek(bucket, doc_id)
        if slot < 0:
            slot = self._alloc.slot_of(bucket, doc_id)
            arena.ensure_capacity(self._alloc.high_water(bucket))
            arena.clear_slot(slot)
            if arena.sanitizer is not None:
                arena.sanitizer.note_alloc(bucket, slot, doc_id)
            self._doc_slot[doc_id] = (bucket, slot)
        return slot

    # --------------------------------------------------------------- compute
    def uses_paged_kv(self) -> bool:
        """Resolve the ``paged`` switch (None = auto): the paged stage step
        needs a paged-capable model and pays off when the kernels resolve
        slots in-kernel, i.e. on Pallas runtimes."""
        if self.prefix_sharing:
            # prefix sharing lives on block tables — paged plane only (the
            # gather REFERENCE is the XLA fallback inside the paged kernels
            # wrappers, not the row-copy stage step)
            if self.paged is None:
                self.paged = True
            assert self.paged, "prefix_sharing requires the paged data plane"
        if self.paged is None:
            impl = getattr(getattr(self.model, "rt", None), "attn_impl", "")
            self.paged = bool(
                impl.startswith("pallas")
                and getattr(self.model, "supports_paged_kv", False))
        if self.paged:
            assert getattr(self.model, "supports_paged_kv", False), \
                "paged=True requires a model whose serve-state is all " \
                "full-attention KV caches (LM.supports_paged_kv)"
        return self.paged

    def _build_step(self):
        model = self.model

        def gather_step(params, arena_states, slots, new_tok, op_tok,
                        kv_true, ext_true, *, c_len: int, op_len: int):
            st = model.take_states(arena_states, slots)
            if new_tok.shape[1] > 0:
                # prefill (c_len == 0) / fraction-extend into the arena;
                # ext_true = per-row REAL extent of cache + chunk, so
                # bucket-PAD keys are invisible inside the chunk too
                _, st = model.extend(params, {"tokens": new_tok}, st,
                                     q_offset=c_len, kv_len=ext_true)
                arena_states = model.put_states(arena_states, slots, st)
            # operation suffix: masked decode steps over the gathered COPY
            # (kv_true = per-doc TRUE prefix length -> pad KV is invisible;
            # the doc snapshot in the arena survives untouched)
            logits = None
            pos = kv_true.astype(jnp.int32)
            B = slots.shape[0]
            for t in range(op_len):
                tok = jnp.broadcast_to(op_tok[t], (B,))
                logits, st = model.decode_step(params, tok, st, pos + t)
            return logits, arena_states

        def paged_step(params, arena_states, slots, new_tok, op_tok,
                       kv_true, ext_true, *, c_len: int, op_len: int):
            # PAGED data plane: the arena is never row-copied.  The extend
            # scatters only the chunk's KV into the addressed rows and the
            # kernels DMA arena blocks through slot ids in scalar-prefetch
            # SMEM, so per-launch HBM traffic is the attended blocks — not
            # a [B, s_alloc] gather + scatter of whole rows.
            if new_tok.shape[1] > 0:
                _, arena_states = model.extend(
                    params, {"tokens": new_tok}, arena_states,
                    q_offset=c_len, kv_len=ext_true, slots=slots)
            # operation suffix: masked decode steps run IN PLACE over the
            # arena.  The op tokens' KV lands at [kv_true, kv_true+op_len)
            # of each row — positions that may hold live document KV (the
            # true fraction can undershoot the padded cache) — so the
            # window is snapshotted first and restored after: an O(B *
            # op_len) undo log instead of an O(B * s_alloc) row copy, and
            # the arena leaves the step bitwise identical to the gather
            # path's.
            logits = None
            pos = kv_true.astype(jnp.int32)
            B = slots.shape[0]
            saved = model.take_kv_window(arena_states, slots, pos, op_len)
            for t in range(op_len):
                tok = jnp.broadcast_to(op_tok[t], (B,))
                logits, arena_states = model.decode_step(
                    params, tok, arena_states, pos + t, slots=slots)
            arena_states = model.put_kv_window(arena_states, slots, pos,
                                               op_len, saved)
            return logits, arena_states

        step = paged_step if self.uses_paged_kv() else gather_step
        kwargs: Dict[str, Any] = {"static_argnames": ("c_len", "op_len")}
        if jax.default_backend() != "cpu":      # CPU donation only warns
            kwargs["donate_argnums"] = (1,)
        return jax.jit(step, **kwargs)

    def _build_prefix_step(self):
        assert self.uses_paged_kv()     # resolves paged=None, checks model
        model = self.model

        def prefix_step(params, arena_states, slots, block_tables, new_tok,
                        last_tok, kv_true, ext_true, *, c_len: int,
                        p_len: int):
            # OP-FIRST layout: the shared operation prefix occupies cache
            # positions [0, p_len) — prefilled once into a pinned arena
            # row that the leading block-table columns point at — and the
            # document lives at [p_len, p_len + f_len).  Writes (extend
            # scatter, readout token) land in the document's own row
            # (``slots``); reads resolve through ``block_tables``.
            if new_tok.shape[1] > 0:
                _, arena_states = model.extend(
                    params, {"tokens": new_tok}, arena_states,
                    q_offset=p_len + c_len, kv_len=p_len + ext_true,
                    slots=slots, block_tables=block_tables)
            # readout: re-feed the LAST TRUE document token at its own
            # position and take its logits as the class readout — rows are
            # ragged, so the extend's final-position logits belong to
            # bucket PAD for short documents.  The re-fed token overwrites
            # one KV position with decode-path values; a width-1 KV-window
            # undo log keeps the cached row bitwise pristine.
            pos = p_len + kv_true.astype(jnp.int32) - 1
            saved = model.take_kv_window(arena_states, slots, pos, 1)
            logits, arena_states = model.decode_step(
                params, last_tok, arena_states, pos, slots=slots,
                block_tables=block_tables)
            arena_states = model.put_kv_window(arena_states, slots, pos, 1,
                                               saved)
            return logits, arena_states

        kwargs: Dict[str, Any] = {"static_argnames": ("c_len", "p_len")}
        if jax.default_backend() != "cpu":      # CPU donation only warns
            kwargs["donate_argnums"] = (1,)
        return jax.jit(prefix_step, **kwargs)

    # ------------------------------------------------------- prefix sharing
    def prefix_slot_needed(self, bucket: int, op_id: Optional[str]) -> bool:
        """Would the next launch of ``op_id`` in ``bucket`` allocate a
        fresh prefix row?  (The server's budget pass counts it as one
        more new slot.)"""
        if not self.prefix_sharing or op_id is None:
            return False
        ar = self._arenas.get(bucket)
        return ar is None or op_id not in ar.prefix_row

    def _ensure_prefix_row(self, arena: BucketArena, bucket: int,
                           op_key: str, op_tokens: np.ndarray) -> int:
        """Memoized op-prefix prefill: the first launch of ``op_key`` in
        this bucket prefills the operation tokens ONCE into a dedicated
        arena row (positions [0, P)); later launches just point their
        block tables at it.  The row is allocated through the shared
        ``SlotAllocator`` under a NEGATIVE pseudo doc id, so it can never
        collide with a document slot but stays invisible to
        ``live_docs()``/eviction (pinned while referenced)."""
        row = arena.prefix_row.get(op_key)
        if row is not None:
            return row
        pid = self._prefix_ids.get((bucket, op_key))
        if pid is None:
            pid = self._next_prefix_id
            self._next_prefix_id -= 1
            self._prefix_ids[(bucket, op_key)] = pid
        row = self._alloc.slot_of(bucket, pid)
        arena.ensure_capacity(self._alloc.high_water(bucket))
        arena.clear_slot(row)
        san = arena.sanitizer
        if san is not None:
            san.note_alloc(bucket, row, pid)
        arena.prefix_row[op_key] = row
        arena.prefix_refs[row] = 0
        P = len(op_tokens)
        arena.prefix_len[row] = P
        # prefill the EFFECTIVE prefix [0, P_eff): op tokens plus PAD up
        # to the blocking boundary (see _prefix_eff_len) — the pad gap's
        # KV is deterministic and shared, so every document and every
        # plane (pallas / gather reference / bf16) sees identical values
        p_eff = self._prefix_eff_len(P)
        tok = np.full(p_eff, PAD, np.int32)
        tok[:P] = op_tokens
        ticket = None
        if san is not None:
            ticket = san.begin_launch(
                bucket, (self.name, "prefix_prefill", op_key, bucket),
                reads={row}, writes={row}, scratch=arena.scratch_slot)
        try:
            _, arena.states = self.model.extend(
                self.params, {"tokens": jnp.asarray(tok)[None]},
                arena.states, q_offset=0,
                kv_len=jnp.asarray([p_eff], jnp.int32),
                slots=jnp.asarray([row], jnp.int32))
        finally:
            if san is not None:
                san.end_launch(ticket)
        if san is not None:
            san.note_pin(bucket, row, op_key)
        return row

    def _prefix_eff_len(self, P: int) -> int:
        """Layout length of an op prefix: the document must start at an
        offset the attention blocking can address (chunk KV windows are
        ``P_eff + cached + new`` wide, and the flash paths need widths
        that are within one block or block multiples), so the prefix is
        padded up to the smallest compliant length.  Big-block runtimes
        (block >= op length) keep ``P_eff == P`` — there the op shares
        via the copy-on-write remainder; small-block runtimes round up to
        a block multiple — there it shares via whole block-table columns.
        """
        blk_q = getattr(self.model.rt, "block_q", 512)
        blk_kv = getattr(self.model.rt, "block_kv", 512)
        p_eff = P
        while ((p_eff > blk_q and p_eff % blk_q)
               or (p_eff > blk_kv and p_eff % blk_kv)):
            p_eff += 1
        assert p_eff <= self.op_reserve, \
            f"op prefix pads to {p_eff} > op_reserve ({self.op_reserve})"
        return p_eff

    def _dispatch_group_prefix(self, ids, doc_tokens, bucket, f_len,
                               fraction, eff_c, op_tokens, n_classes,
                               op_key):
        """Prefix-sharing twin of the standard ``dispatch_group`` body:
        op-first layout, block-table indirection, memoized op prefill,
        one readout decode instead of a per-launch op-suffix decode loop
        (and hence zero undo-log bytes for the op suffix — only the
        width-1 readout window is saved/restored, inside the step).
        Returns a ``GroupTicket`` with its sanitizer bracket open; the
        attach-time COW copy and any first-touch op prefill close their
        own brackets here at dispatch (they touch only the shared row
        plus this launch's fresh private rows — disjoint from every
        other open ticket's write set).

        Billing is IDENTICAL to the standard plane — ``new_d = doc
        segment + op_len`` per document — because $ follows the token
        accounting contract, not physical work; the op prefill amortizing
        across documents is exactly the engine-side analogue of the
        paper's prompt-cache discount already modelled by
        ``cached_discount``.
        """
        assert len(op_tokens) > 0, "operations must encode to >= 1 token"
        P = len(op_tokens)
        assert P <= self.op_reserve, \
            f"operation longer than op_reserve ({P})"
        p_eff = self._prefix_eff_len(P)           # layout offset of the doc
        t0 = time.perf_counter()
        arena = self._arena(bucket)
        row = self._ensure_prefix_row(arena, bucket, op_key, op_tokens)
        assert arena.prefix_len[row] == P, \
            f"op {op_key!r} re-encoded to a different length"
        slots = [self._slot_for(bucket, d, arena) for d in ids]
        B = len(ids)
        Bp = _pad_width(B)
        n_new = f_len - eff_c                     # 0 => decode-only launch
        s_alloc = arena.s_alloc
        tb = self._block_size(bucket)
        nb = s_alloc // tb
        shared_full = p_eff // tb                 # whole blocks shared
        rem_start = shared_full * tb
        rem = p_eff - rem_start                   # partial-block remainder

        # attach documents to the shared row; the partial block (where the
        # op remainder and the document's first tokens share a cache
        # block) diverges immediately, so it is copied into the private
        # row at attach time — the copy-on-write moment
        fresh: List[int] = []
        for i, d in enumerate(ids):
            slot = slots[i]
            if eff_c > 0:
                assert arena.slot_op.get(slot) == op_key, \
                    f"doc {d} cached under op {arena.slot_op.get(slot)!r} " \
                    f"launched as {op_key!r} (server must invalidate)"
            if arena.slot_prefix.get(slot) is None:
                arena.attach_prefix(slot, op_key)
                fresh.append(slot)
        self.prefix_hits += len(fresh)
        tm = self.telemetry
        if tm is not None and tm.tracing and fresh:
            fresh_set = set(fresh)
            fresh_docs = [d for i, d in enumerate(ids)
                          if slots[i] in fresh_set]
            ts = time.perf_counter()
            for d in fresh_docs:
                tm.event(d, EV_PREFIX_HIT, ts, {"backend": self.name})
        san = arena.sanitizer
        if fresh and rem > 0:
            n = len(fresh)
            src = jnp.full((n,), row, jnp.int32)
            dst = jnp.asarray(fresh, jnp.int32)
            start = jnp.full((n,), rem_start, jnp.int32)
            cow_ticket = None
            if san is not None:
                with san.cow(bucket):
                    cow_ticket = san.begin_launch(
                        bucket, (self.name, "cow_copy", op_key, bucket),
                        reads={row}, writes=set(fresh),
                        scratch=arena.scratch_slot)
            try:
                win = self.model.take_kv_window(arena.states, src, start,
                                                rem)
                arena.states = self.model.put_kv_window(arena.states, dst,
                                                        start, rem, win)
            finally:
                if san is not None:
                    san.end_launch(cow_ticket)
            self.cow_copies += n
            if tm is not None and tm.tracing:
                ts = time.perf_counter()
                for d in fresh_docs:
                    tm.event(d, EV_COW_COPY, ts, {"backend": self.name})

        slots_arr = np.full(Bp, arena.scratch_slot, np.int32)
        slots_arr[:B] = slots
        # full-width table [Bp, s_alloc // tb]: column j is the arena row
        # holding positions [j*tb, (j+1)*tb) — leading shared columns hit
        # the pinned prefix row, the rest the document's private row
        bt = np.repeat(slots_arr[:, None], nb, axis=1)
        if shared_full > 0:
            bt[:B, :shared_full] = row
        new_tok = np.full((Bp, n_new), PAD, np.int32)
        last_tok = np.full(Bp, PAD, np.int32)
        kv_true = np.ones(Bp, np.int32)
        ext_true = np.ones(Bp, np.int32)
        new_d = np.zeros(B, np.int64)
        cached_d = np.zeros(B, np.int64)
        for i, d in enumerate(ids):
            toks = doc_tokens[d]
            slot = slots[i]
            if n_new > 0:
                seg = toks[min(eff_c, len(toks)): min(f_len, len(toks))]
                new_tok[i, : len(seg)] = seg
                new_d[i] = len(seg)
                cached_d[i] = min(eff_c, len(toks))
                ext_true[i] = min(eff_c, len(toks)) + len(seg)
            else:
                cached_d[i] = min(int(arena.true_len[slot]),
                                  self._true_len(toks, fraction))
            kt = self._true_len(toks, fraction)
            kv_true[i] = kt
            last_tok[i] = toks[kt - 1]
        t1 = time.perf_counter()
        self.host_overhead_s += t1 - t0

        if self._prefix_step is None:
            self._prefix_step = self._build_prefix_step()
        t2 = time.perf_counter()
        ticket = None
        if san is not None:
            # block-table columns resolve to slots + the pinned prefix row:
            # writes land in the private rows, the row is the shared read
            ticket = san.begin_launch(
                bucket, (self.name, "prefix_step", op_key, bucket, eff_c,
                         f_len, B),
                reads=set(slots) | {row}, writes=set(slots),
                scratch=arena.scratch_slot)
        try:
            logits, new_states = self._prefix_step(
                self.params, arena.states, jnp.asarray(slots_arr),
                jnp.asarray(bt), jnp.asarray(new_tok),
                jnp.asarray(last_tok),
                jnp.asarray(kv_true), jnp.asarray(ext_true),
                c_len=eff_c, p_len=p_eff)
        except BaseException:
            if san is not None:
                san.end_launch(ticket)
            raise
        # RSA003-verified rebind: with donation on, the step consumed the
        # old arena buffers; the arena now holds the result FUTURE, so a
        # later launch on this arena chains through it (device-ordered)
        arena.states = new_states
        t3 = time.perf_counter()
        self.host_overhead_s += t3 - t2    # async dispatch
        # undo log here is the width-1 readout window, not the op suffix
        self._note_launch_traffic(bucket, B, 1, n_new, kv_true)
        if n_new > 0:
            for i, d in enumerate(ids):
                slot = slots[i]
                arena.cached_len[slot] = f_len
                arena.true_len[slot] = min(f_len, len(doc_tokens[d]))
        return GroupTicket(
            ids=list(ids), bucket=bucket, n_classes=n_classes,
            logits=logits, states=new_states, new_d=new_d,
            cached_d=cached_d, op_len=P, san=san, san_ticket=ticket,
            timing={"host": t1 - t0, "dispatch": t3 - t2},
            ts_enqueue=t2, ts_dispatched=t3,
            copy_bytes=self.last_copy_bytes,
            hbm_bytes=self.last_hbm_bytes)

    # ----------------------------------------------------- paged accounting
    def gather_bytes_per_launch(self, bucket: int, batch: int) -> int:
        """Device bytes the GATHER stage step copies per launch just to
        address the arena: ``take_states`` materializes a [batch, s_alloc]
        row copy of every state leaf (and extend scatters it back).
        Decode-only launches pay this too.  The paged step eliminates it."""
        return batch * self.slot_nbytes(bucket)

    def paged_copy_bytes_per_launch(self, bucket: int, batch: int,
                                    op_len: int) -> int:
        """Bytes the PAGED stage step copies per launch: the op-suffix
        undo log (save + restore of the ``op_len`` dirtied cache rows).
        Zero bytes scale with the cache/bucket size — the arena itself is
        read in place by the kernels."""
        s_alloc = self._s_alloc_for(bucket)
        row = self.slot_nbytes(bucket)
        return 2 * batch * op_len * (row // s_alloc)

    def class_confidences(self, logits: jnp.ndarray, n_classes: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Softmax over the class answer tokens -> (pred, conf)."""
        toks = [class_token(c) for c in range(n_classes)]
        cls_logits = np.asarray(logits, np.float64)[:, toks]
        z = cls_logits - cls_logits.max(axis=1, keepdims=True)
        probs = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
        return probs.argmax(axis=1), probs.max(axis=1)

    def run_stage(
        self,
        doc_ids: Sequence[int],
        doc_tokens: Mapping[int, np.ndarray],
        bucket: int,                             # padded full-doc length
        fraction: float,
        op_tokens: np.ndarray,
        n_classes: int,
    ) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """Run (op, fraction) over one bucket batch (stage-synchronous API).

        Documents may carry heterogeneous cached prefixes: the batch is
        split into per-``cached_len`` launches (each reusing its cache)
        rather than re-prefilling everyone.  Returns (pred [B], conf [B],
        new_tokens, cached_tokens) with TRUE (unpadded) token counts for $
        accounting.  The request loop calls ``run_group`` directly (the
        scheduler has already grouped by cached length).
        """
        B = len(doc_ids)
        f_len = fraction_len(bucket, fraction)
        pred = np.zeros(B, np.int64)
        conf = np.zeros(B, np.float64)
        pos_of = {d: i for i, d in enumerate(doc_ids)}
        new_true_total = 0
        cached_true_total = 0

        groups: Dict[int, List[int]] = {}
        for d in doc_ids:
            eff_c = min(self.cached_len(d), f_len)
            groups.setdefault(eff_c, []).append(d)

        for eff_c in sorted(groups):
            ids = groups[eff_c]
            p, c, new_d, cached_d = self.run_group(
                ids, doc_tokens, bucket, f_len, fraction, eff_c,
                op_tokens, n_classes)
            for j, d in enumerate(ids):
                pred[pos_of[d]] = p[j]
                conf[pos_of[d]] = c[j]
            new_true_total += int(new_d.sum())
            cached_true_total += int(cached_d.sum())
        return pred, conf, new_true_total, cached_true_total

    def run_group(self, ids, doc_tokens, bucket, f_len, fraction, eff_c,
                  op_tokens, n_classes, op_id: Optional[str] = None):
        """One static-signature launch: all ``ids`` share ``eff_c``.

        Returns (pred [B], conf [B], new_tokens [B], cached_tokens [B])
        with PER-DOCUMENT true token counts, so the request loop can
        attribute cost to each document's own stage and query even when a
        launch mixes stages or registered queries.

        Synchronous composition of the overlapped halves —
        ``complete_group(dispatch_group(...))`` with exactly one ticket
        in flight, bitwise the pre-split behavior.  The server's
        ahead-of-time dispatch loop calls the halves directly to keep up
        to K tickets open.
        """
        return self.complete_group(self.dispatch_group(
            ids, doc_tokens, bucket, f_len, fraction, eff_c, op_tokens,
            n_classes, op_id=op_id))

    def dispatch_group(self, ids, doc_tokens, bucket, f_len, fraction,
                       eff_c, op_tokens, n_classes,
                       op_id: Optional[str] = None) -> GroupTicket:
        """Non-blocking half of ``run_group``: pick slots, assemble the
        launch arrays, enqueue the jitted stage step (JAX async dispatch
        — control returns while the device works), and hand back a
        ``GroupTicket`` whose sanitizer bracket stays OPEN until
        ``complete_group``.  Every piece of host bookkeeping that does
        not depend on device results — billing token counts,
        cached-length advances, structural traffic — happens here, so
        completion only waits and reads out.

        ``op_id`` names the operation for the prefix-sharing memo; callers
        that don't thread one get a content-derived key (same tokens ==
        same prefix row either way).
        """
        if self.prefix_sharing:
            op_key = op_id if op_id is not None else \
                "op:" + ",".join(str(int(t)) for t in op_tokens)
            return self._dispatch_group_prefix(ids, doc_tokens, bucket,
                                               f_len, fraction, eff_c,
                                               op_tokens, n_classes,
                                               op_key)
        assert len(op_tokens) > 0, "operations must encode to >= 1 token"
        assert len(op_tokens) <= self.op_reserve, \
            f"operation longer than op_reserve ({len(op_tokens)})"
        t0 = time.perf_counter()
        arena = self._arena(bucket)
        slots = [self._slot_for(bucket, d, arena) for d in ids]
        B = len(ids)
        Bp = _pad_width(B)
        n_new = f_len - eff_c                     # 0 => decode-only launch
        op_len = len(op_tokens)

        slots_arr = np.full(Bp, arena.scratch_slot, np.int32)
        slots_arr[:B] = slots
        new_tok = np.full((Bp, n_new), PAD, np.int32)
        kv_true = np.ones(Bp, np.int32)
        ext_true = np.ones(Bp, np.int32)
        new_d = np.zeros(B, np.int64)
        cached_d = np.zeros(B, np.int64)
        for i, d in enumerate(ids):
            toks = doc_tokens[d]
            slot = slots[i]
            if n_new > 0:
                seg = toks[min(eff_c, len(toks)): min(f_len, len(toks))]
                new_tok[i, : len(seg)] = seg
                new_d[i] = len(seg)
                cached_d[i] = min(eff_c, len(toks))
                ext_true[i] = min(eff_c, len(toks)) + len(seg)
            else:
                cached_d[i] = min(int(arena.true_len[slot]),
                                  self._true_len(toks, fraction))
            kv_true[i] = self._true_len(toks, fraction)
        t1 = time.perf_counter()
        self.host_overhead_s += t1 - t0

        if self._step is None:
            self._step = self._build_step()
        t2 = time.perf_counter()
        san = arena.sanitizer
        ticket = None
        if san is not None:
            ticket = san.begin_launch(
                bucket, (self.name, "step", bucket, eff_c, f_len, B),
                reads=set(slots), writes=set(slots),
                scratch=arena.scratch_slot)
        try:
            logits, new_states = self._step(
                self.params, arena.states, jnp.asarray(slots_arr),
                jnp.asarray(new_tok), jnp.asarray(op_tokens, jnp.int32),
                jnp.asarray(kv_true), jnp.asarray(ext_true),
                c_len=eff_c, op_len=op_len)
        except BaseException:
            if san is not None:
                san.end_launch(ticket)
            raise
        # RSA003-verified rebind: with donation on, the step consumed the
        # old arena buffers; the arena now holds the result FUTURE, so a
        # later launch on this arena chains through it (device-ordered)
        arena.states = new_states
        t3 = time.perf_counter()
        self.host_overhead_s += t3 - t2    # async dispatch
        self._note_launch_traffic(bucket, B, op_len, n_new, kv_true)
        if n_new > 0:
            for i, d in enumerate(ids):
                slot = slots[i]
                arena.cached_len[slot] = f_len
                arena.true_len[slot] = min(f_len, len(doc_tokens[d]))
        return GroupTicket(
            ids=list(ids), bucket=bucket, n_classes=n_classes,
            logits=logits, states=new_states, new_d=new_d,
            cached_d=cached_d, op_len=op_len, san=san, san_ticket=ticket,
            timing={"host": t1 - t0, "dispatch": t3 - t2},
            ts_enqueue=t2, ts_dispatched=t3,
            copy_bytes=self.last_copy_bytes,
            hbm_bytes=self.last_hbm_bytes)

    def complete_group(self, ticket: GroupTicket):
        """Blocking half of ``run_group``: wait out the ticket's device
        work, close its sanitizer bracket, and read out the routing
        confidences.

        Blocks on the LOGITS only: with buffer donation on, a later
        launch chained onto the same arena consumes the ticket's
        ``states`` buffers, so waiting on them would touch donated
        storage — while the logits are never donated and their readiness
        implies the whole step (arena writes included) retired.  The
        bracket closes in ``finally`` so a device-side error surfacing
        at sync still releases the ticket's rows."""
        t0 = time.perf_counter()
        ticket.ts_sync = t0
        try:
            # device segment: wait out the step here (host-side sync only
            # — the np.asarray readout below then costs nothing extra) so
            # the timeline can split dispatch/in-flight from device wait
            jax.block_until_ready(ticket.logits)
        finally:
            if ticket.san is not None:
                ticket.san.end_launch(ticket.san_ticket)
        t1 = time.perf_counter()
        ticket.ts_ready = t1
        ticket.timing["device"] = t1 - t0
        self.last_timing = dict(ticket.timing)
        B = len(ticket.ids)
        pred, conf = self.class_confidences(
            np.asarray(ticket.logits)[:B], ticket.n_classes)
        return pred, conf, ticket.new_d + ticket.op_len, ticket.cached_d

    @staticmethod
    def _true_len(toks: np.ndarray, fraction: float) -> int:
        return max(int(math.ceil(len(toks) * fraction)), 1)


@dataclass
class EngineResult:
    pred: Dict[int, int]          # RESOLVED documents only
    conf: Dict[int, float]
    exit_stage: Dict[int, int]
    cost: float
    stats: ServeStats
    stage_cost: List[float] = field(default_factory=list)
    doc_cost: Dict[int, float] = field(default_factory=dict)   # all terminal
    status: Dict[int, str] = field(default_factory=dict)       # all terminal


# stage-table entry: (model, op_id, fraction, threshold_vector-or-None)
_StageEntry = Tuple[str, str, float, Optional[np.ndarray]]


@dataclass
class DocFuture:
    """Resolution handle for one submitted document.

    ``handle.submit`` returns one; it stays live until the server resolves
    the document (``done``), after which ``pred``/``conf``/``exit_stage``/
    ``cost`` are populated.  ``result()`` steps the server until this
    document resolves (other queries' work is served along the way — the
    future never bypasses the scheduler).

    ``done`` covers every TERMINAL state — ``status`` distinguishes
    ``RESOLVED`` from ``FAILED``/``TIMED_OUT`` (``error`` carries the
    diagnostic); ``pred``/``conf``/``exit_stage`` stay None for
    non-resolved terminals and ``result()`` raises for them.
    """

    query_id: int
    doc_id: int                       # the CALLER's id (ext_id)
    _req: DocRequest = field(repr=False)
    _server: "CascadeServer" = field(repr=False)

    @property
    def done(self) -> bool:
        return self._req.done

    @property
    def status(self) -> str:
        """Lifecycle state: pending / resolved / failed / timed_out."""
        return self._req.status

    @property
    def error(self) -> Optional[str]:
        """Diagnostic for FAILED/TIMED_OUT terminals (None otherwise)."""
        return self._req.error

    @property
    def pred(self) -> Optional[int]:
        return self._req.pred

    @property
    def conf(self) -> Optional[float]:
        return self._req.conf

    @property
    def exit_stage(self) -> Optional[int]:
        return self._req.exit_stage

    @property
    def cost(self) -> float:
        return self._req.cost

    @property
    def evictions(self) -> int:
        return self._req.evictions

    def result(self) -> Tuple[int, float, int]:
        """Block (stepping the server) until terminal: (pred, conf, stage).

        Raises ``RuntimeError`` when the document terminates FAILED or
        TIMED_OUT — a terminal state is always reached, never a hang.
        """
        while not self._req.done:
            assert self._server.pending(), \
                "server idle before this document resolved"
            if not self._server.step():
                self._server._idle_wait()
        if self._req.status != RESOLVED:
            raise RuntimeError(
                f"document {self.doc_id} (query {self.query_id}) "
                f"{self._req.status}: {self._req.error}")
        return self._req.pred, self._req.conf, self._req.exit_stage


@dataclass
class QueryHandle:
    """One registered query's view of a ``CascadeServer``.

    Returned by ``server.register(cascade, ...)``.  ``submit`` admits
    documents into the SHARED request queue (they may merge into launches
    with other queries' documents); ``poll``/``result``/``stats``/``cost``
    are partitioned to this query.  ``accuracy_target`` is the caller's
    declared accuracy floor (the alpha the cascade was assembled for) —
    recorded for admission/monitoring; the thresholds baked into the
    cascade are what enforce it.
    """

    query_id: int
    stages: List[_StageEntry] = field(repr=False)
    _server: "CascadeServer" = field(repr=False)
    accuracy_target: Optional[float] = None

    def stage_config(self, stage: int) -> StageConfig:
        model, op_id, fraction, _ = self.stages[stage]
        return model, op_id, fraction

    def submit(self, doc_id: int, text: str,
               arrival: Optional[float] = None, stage: int = 0,
               arrival_ts: Optional[float] = None,
               deadline_s: Optional[float] = None) -> DocFuture:
        """Admit a document into this query (streaming arrival).

        ``arrival`` is the scheduling priority — any comparable float
        (logical sequence numbers are fine); lower runs first, ACROSS
        queries.  ``arrival_ts`` is an absolute ``time.perf_counter()``
        timestamp anchoring the latency measurement — streaming drivers
        pass the SCHEDULED arrival so pre-submit queueing counts; it
        defaults to submit time.  ``arrival`` defaults to ``arrival_ts``
        so priority follows real arrival order when only timestamps are
        given.  ``stage`` lets pre-screened documents enter the cascade
        mid-way (clamped to the oracle).  Document ids are scoped to the
        query: two queries may both submit a document ``7``.

        ``deadline_s`` bounds the document's wall-clock from submit: past
        it the document resolves ``TIMED_OUT`` instead of launching
        again (retry backoff does not extend the deadline).  Raises
        ``ValueError`` for empty/whitespace-only text or a ``doc_id``
        already submitted to this query.
        """
        return self._server._submit(self, doc_id, text, arrival=arrival,
                                    stage=stage, arrival_ts=arrival_ts,
                                    deadline_s=deadline_s)

    def pending(self) -> int:
        """This query's documents admitted but not yet resolved."""
        return self._server.pending(self.query_id)

    def poll(self) -> Dict[int, Tuple[int, float, int]]:
        """This query's results resolved since the last poll:
        doc -> (pred, conf, exit_stage)."""
        return self._server._poll_query(self.query_id)

    def result(self) -> EngineResult:
        """Everything this query has resolved so far (per-query stats/$)."""
        return self._server.result(self.query_id)

    def drain(self) -> EngineResult:
        """Step the server until THIS query is idle (other queries' work
        is served along the way), then return its result."""
        while self.pending():
            if not self._server.step():
                self._server._idle_wait()
        return self.result()

    @property
    def stats(self) -> ServeStats:
        return self._server.stats(self.query_id)

    @property
    def cost(self) -> float:
        return self._server.cost(self.query_id)


@dataclass
class _Flight:
    """One dispatched-but-uncompleted launch in the server's ahead-of-time
    dispatch window.  ``group`` is the backend's ``GroupTicket`` (None
    only on the failed-dispatch record path); ``attempt`` pins the
    attempt index at dispatch time so timeline records stay dense even
    though ``_attempts`` advances past the flight before it completes."""

    launch: LaunchSpec
    be: Any
    group: Any
    attempt: int
    t_begin: float
    t_sched: float


@dataclass
class CascadeServer:
    """Long-lived multi-tenant executor of task cascades over shared
    backends.

    ``register`` / ``handle.submit`` / ``step`` / ``poll`` / ``drain`` is
    the serving API; the server owns the backends, their KV arenas, and
    one global request queue, and serves every registered query
    concurrently.  See the module docstring for the scheduling contract.
    """

    backends: Dict[str, Any]                # "proxy"/"oracle" -> backend
    operations: Dict[str, str]              # op id -> operation text
    n_classes: int
    batch_size: int = 8
    policy: Optional[SchedulingPolicy] = None   # None = oldest_head_first
    # ---- fault-tolerance knobs (see the module docstring's failure model)
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    breaker_threshold: int = 3       # consecutive failures to open a breaker
    breaker_cooldown: int = 8        # launch attempts a breaker stays open
    stall_limit: int = 256           # no-progress steps before stall error
    journal: Optional[RequestJournal] = None    # write-ahead request journal
    faults: Optional[Any] = None     # FaultInjector (set by install())
    # Observability hub (serving/telemetry.py): metric registry + launch
    # timeline on by default ("counters"); per-doc span traces opt in via
    # level="trace".  Host-side only — the data plane stays bitwise
    # identical at every level.
    telemetry: Telemetry = field(default_factory=Telemetry, repr=False)
    idle_wait_cap: float = 0.25      # max seconds one _idle_wait sleeps
    # Overlapped ahead-of-time dispatch: keep up to ``inflight`` launches
    # enqueued on the device before blocking for the oldest one's routing
    # confidences.  1 (default) is bitwise the pre-overlap behavior; K>1
    # hides scheduler/host bookkeeping behind device compute.  Safe by
    # construction: in-flight documents are out of the ready queue (so
    # concurrent launches own disjoint arena rows), the scheduler vetoes
    # groups that would touch rows open tickets own, and every structural
    # path (eviction, arena loss, reset) drains conflicting tickets first
    # — with the sanitizer's open brackets auditing exactly that.
    inflight: int = 1
    _op_tok_cache: Dict[Tuple[str, str], np.ndarray] = field(
        default_factory=dict, repr=False)
    # ---- serving state (shared queue; per-query partitions keyed by qid)
    _handles: Dict[int, QueryHandle] = field(default_factory=dict, repr=False)
    _queue: RequestQueue = field(default_factory=RequestQueue, repr=False)
    _requests: Dict[int, DocRequest] = field(default_factory=dict, repr=False)
    _ids: Dict[Tuple[int, int], int] = field(default_factory=dict, repr=False)
    _tok: Dict[str, Dict[int, np.ndarray]] = field(
        default_factory=dict, repr=False)
    _query_stats: Dict[int, ServeStats] = field(
        default_factory=dict, repr=False)
    _departed: ServeStats = field(default_factory=ServeStats, repr=False)
    _query_cost: Dict[int, float] = field(default_factory=dict, repr=False)
    _fresh: Dict[int, List[int]] = field(default_factory=dict, repr=False)
    _pending: Dict[int, int] = field(default_factory=dict, repr=False)
    _launches: int = field(default=0, repr=False)
    _retired: int = field(default=0, repr=False)
    _flights: List[_Flight] = field(default_factory=list, repr=False)
    _max_inflight_seen: int = field(default=0, repr=False)
    _seq: int = field(default=0, repr=False)
    _next_qid: int = field(default=0, repr=False)
    # ---- fault-tolerance state
    _health: Dict[str, BackendHealth] = field(default_factory=dict,
                                              repr=False)
    _ledger: List[Tuple[int, int, int, float]] = field(
        default_factory=list, repr=False)   # (launch, qid, rid, cost)
    _attempts: int = field(default=0, repr=False)   # launches tried (+failed)
    _stalled_steps: int = field(default=0, repr=False)
    _breaker_trips: int = field(default=0, repr=False)
    _failed_launches: int = field(default=0, repr=False)
    # ---- shared-substrate memory counters (mirrored into query stats)
    _arena_bytes_peak: int = field(default=0, repr=False)
    _prefix_hits: int = field(default=0, repr=False)
    _cow_copies: int = field(default=0, repr=False)

    def __post_init__(self) -> None:
        if not self._tok:
            self._tok = {m: {} for m in self.backends}
        for be in self.backends.values():   # share the hub with backends
            be.telemetry = self.telemetry
            # sanitizer diagnostics name the owning query/document
            be.doc_info = self._doc_info

    def _doc_info(self, rid: int) -> Optional[Dict[str, Any]]:
        """Owner lookup for arena-sanitizer diagnostics: server request id
        -> the owning query and caller document ids (None if unknown —
        e.g. prefix pseudo-ids, which are negative and never submitted)."""
        req = self._requests.get(rid)
        if req is None:
            return None
        return {"query": req.query_id, "doc": req.ext_id}

    def _op_tokens(self, backend, op_id: str) -> np.ndarray:
        key = (backend.name, op_id)
        toks = self._op_tok_cache.get(key)
        if toks is None:
            toks = np.asarray(
                backend.tokenizer.encode(self.operations[op_id]), np.int32)
            self._op_tok_cache[key] = toks
        return toks

    # ------------------------------------------------------------- lifecycle
    def reset(self) -> None:
        """Drop every query and in-flight request; reset backends/arenas.

        Compiled stage steps and op-token memos survive (they close over
        models and operation text only).
        """
        assert not self._flights, \
            "reset with launches in flight; drain them first"
        for be in self.backends.values():
            be.reset()
        self._queue.clear()
        self._handles.clear()
        self._requests.clear()
        self._ids.clear()
        self._tok = {m: {} for m in self.backends}
        self._query_stats.clear()
        self._departed = ServeStats()
        self._query_cost.clear()
        self._fresh.clear()
        self._pending.clear()
        self._launches = 0
        self._retired = 0
        self._max_inflight_seen = 0
        self._seq = 0
        self._next_qid = 0
        self._health.clear()
        self._ledger.clear()
        self._attempts = 0
        self._stalled_steps = 0
        self._breaker_trips = 0
        self._failed_launches = 0
        self._arena_bytes_peak = 0
        self._prefix_hits = 0
        self._cow_copies = 0
        self.telemetry.clear()          # traces reference dropped requests
        if self.journal is not None:    # dropped queries: journal restarts
            self.journal = RequestJournal()

    def register(self, cascade: Cascade,
                 accuracy_target: Optional[float] = None,
                 oracle_model: str = "oracle",
                 oracle_op: str = "o_orig") -> QueryHandle:
        """Register a query (cascade) for serving; returns its handle.

        Backends and arenas are NOT reset — registration is cheap and
        concurrent queries share the serving substrate.  The oracle
        fall-through (``oracle_model``, ``oracle_op``, f=1, no
        thresholds) is appended so every submitted document resolves.
        """
        qid = self._next_qid
        self._next_qid += 1
        handle = QueryHandle(
            query_id=qid,
            stages=cascade.stage_entries(self.n_classes, oracle_model,
                                         oracle_op),
            _server=self, accuracy_target=accuracy_target)
        self._handles[qid] = handle
        self._query_stats[qid] = ServeStats()
        self._query_cost[qid] = 0.0
        self._fresh[qid] = []
        self._pending[qid] = 0
        if self.journal is not None:
            self.journal.record_register(qid)
        return handle

    def unregister(self, handle: QueryHandle) -> None:
        """Withdraw a query and free its bookkeeping (results included —
        read ``handle.result()`` first).  Asserts the query is idle:
        drain it before unregistering.  The query's contribution to the
        server-wide aggregate (``stats()``/``occupancy()``) is retained —
        launch history does not shrink when a tenant departs."""
        qid = handle.query_id
        assert self._pending.get(qid, 0) == 0, \
            "unregister with documents pending; drain the query first"
        gone = self._query_stats.get(qid)
        if gone is not None:
            self._merge_stats(self._departed, gone)
        self._handles.pop(qid, None)
        self._query_stats.pop(qid, None)
        self._query_cost.pop(qid, None)
        self._fresh.pop(qid, None)
        self._pending.pop(qid, None)
        for (q, d), rid in list(self._ids.items()):
            if q == qid:
                del self._ids[(q, d)]
                self._requests.pop(rid, None)
                for tok in self._tok.values():
                    tok.pop(rid, None)

    def _submit(self, handle: QueryHandle, doc_id: int, text: str,
                arrival: Optional[float] = None, stage: int = 0,
                arrival_ts: Optional[float] = None,
                deadline_s: Optional[float] = None) -> DocFuture:
        qid = handle.query_id
        assert self._handles.get(qid) is handle, \
            "handle is not registered with this server"
        if not isinstance(text, str) or not text.strip():
            raise ValueError(
                f"doc {doc_id!r} (query {qid}): document text is empty or "
                "whitespace-only")
        key = (qid, doc_id)
        if key in self._ids:
            raise ValueError(
                f"doc {doc_id!r} already submitted to query {qid} "
                "(doc ids must be unique within a query)")
        if arrival_ts is None:
            arrival_ts = time.perf_counter()
        if arrival is None:
            arrival = arrival_ts
        if self.journal is not None:    # write-ahead: journal BEFORE admit
            self.journal.record_submit(qid, doc_id, text, arrival, stage,
                                       deadline_s)
        rid = self._seq                   # server-global request id == seq
        self._seq += 1
        req = DocRequest(
            doc_id=rid, query_id=qid, ext_id=doc_id,
            stage=min(max(int(stage), 0), len(handle.stages) - 1),
            arrival=arrival, seq=rid, arrival_ts=arrival_ts)
        if deadline_s is not None:
            req.deadline = arrival_ts + deadline_s
        enc: Dict[int, np.ndarray] = {}     # backends often share a tokenizer
        for m, be in self.backends.items():
            ids = enc.get(id(be.tokenizer))
            if ids is None:
                ids = np.asarray(be.tokenizer.encode(text), np.int32)
                enc[id(be.tokenizer)] = ids
            self._tok[m][rid] = ids
            req.tok_len[m] = len(ids)
        self._requests[rid] = req
        self._ids[key] = rid
        self._pending[qid] += 1
        self._queue.push(req)
        tm = self.telemetry
        if tm.enabled:
            tm.count("serve_docs_submitted_total", 1, query=qid)
            if tm.tracing:
                tm.register_doc(rid, qid, doc_id)
                tm.event(rid, EV_SUBMIT, time.perf_counter(),
                         {"stage": req.stage})
        return DocFuture(query_id=qid, doc_id=doc_id, _req=req, _server=self)

    def pending(self, query_id: Optional[int] = None) -> int:
        """Documents admitted but not yet resolved (one query, or all).

        Counts documents riding in-flight launches too — drain loops
        must keep stepping until every open ticket has completed, not
        just until the ready queue empties."""
        if query_id is None:
            return (len(self._queue)
                    + sum(len(f.launch.doc_ids) for f in self._flights))
        return self._pending.get(query_id, 0)

    # ------------------------------------------------------------ scheduling
    def _stage_of(self, req: DocRequest) -> StageConfig:
        """Resolve a request's current stage through its owning query."""
        return self._handles[req.query_id].stage_config(req.stage)

    def _victim_order(self, be, protected: Set[int]) -> List[int]:
        """Eviction priority, lowest first: fewest-cached-tokens-lost,
        newest arrival breaking ties (two stable sorts, reversed-arrival
        first).  Documents riding open tickets are never victims — the
        dispatch loop drains conflicting flights before evicting, and
        this filter is the belt-and-braces guarantee the sanitizer's
        open brackets would otherwise turn into an ``ArenaRaceError``."""
        inflight = {d for f in self._flights for d in f.launch.doc_ids}
        victims = sorted(
            (d for d in be.live_docs()
             if d not in protected and d not in inflight),
            key=lambda d: self._requests[d].key(), reverse=True)
        victims.sort(key=be.true_cached_len)
        return victims

    def _make_room(self, be, launch: LaunchSpec) -> LaunchSpec:
        """Enforce the backend's slot/byte budgets for one launch.

        First preempts live slots outside the launch (fewest cached
        tokens lost first); if the budgets still cannot host every new
        allocation, the newest tail of the launch is deferred back to the
        queue (at least one document always proceeds).
        """
        if (getattr(be, "slot_budget", None) is None
                and getattr(be, "byte_budget", None) is None):
            return launch
        # the shared op-prefix row (first launch of this op in this
        # bucket) is one more fresh slot the budgets must host
        extra = 1 if (hasattr(be, "prefix_slot_needed")
                      and be.prefix_slot_needed(launch.bucket, launch.op_id)
                      ) else 0
        need = sum(1 for d in launch.doc_ids if not be.has_slot(d)) + extra
        if not be.over_budget(launch.bucket, need):
            return launch
        victims = self._victim_order(be, set(launch.doc_ids))
        # snapshot BEFORE eviction releases the slots: the true cached
        # tokens each victim loses is exactly what its next launch must
        # re-prefill (the capacity metric the benchmark gates on)
        lost = {d: be.true_cached_len(d) for d in victims}
        tm = self.telemetry
        for d in be.evict_for_room(launch.bucket, need, victims):
            req = self._requests[d]
            req.cached[be.name] = 0
            req.evictions += 1
            st = self._query_stats[req.query_id]
            st.evictions += 1
            st.re_prefill_tokens += lost[d]
            if tm.enabled:
                tm.count("serve_evictions_total", 1, backend=launch.model)
                if tm.tracing:
                    tm.event(d, EV_EVICT, time.perf_counter(),
                             {"backend": launch.model,
                              "lost_tokens": lost[d], "reason": "budget"})
        retired = getattr(be, "pressure_retired", 0)
        if retired:
            be.pressure_retired = 0
            self._note_retired(retired)
        room = be.admissible_new(launch.bucket, need)
        if need <= room:
            return launch
        # trim: keep the oldest prefix whose new allocations fit (>= 1 doc)
        keep_ids: List[int] = []
        keep_stages: List[int] = []
        used = extra        # the prefix row allocates regardless of trim
        for d, s in zip(launch.doc_ids, launch.stages):
            cost = 0 if be.has_slot(d) else 1
            if keep_ids and used + cost > room:
                self._queue.push(self._requests[d])  # defer to a later launch
                continue
            keep_ids.append(d)
            keep_stages.append(s)
            used += cost
        return LaunchSpec(
            model=launch.model, op_id=launch.op_id, fraction=launch.fraction,
            bucket=launch.bucket, cached_len=launch.cached_len,
            f_len=launch.f_len, doc_ids=tuple(keep_ids),
            stages=tuple(keep_stages))

    def _note_retired(self, n: int) -> None:
        # arenas are shared: retirement is a server-wide memory event,
        # mirrored into every query's stats (aggregate counts it once)
        self._retired += n
        for st in self._query_stats.values():
            st.retired_buckets += n

    def step(self) -> List[Tuple[int, int]]:
        """Fill the dispatch window, then complete the oldest launch.

        Ahead-of-time dispatch: up to ``inflight`` launches are enqueued
        non-blocking (``dispatch_group`` returns a ticket while the
        device works), then exactly one — the oldest — is completed,
        because the scheduler needs ITS confidences to route its
        documents' next stages.  At ``inflight=1`` this is bitwise the
        classic dispatch-then-block step.  Launches may mix documents
        from several registered queries (same static signature).
        Returns the ``(query_id, doc_id)`` pairs that reached a TERMINAL
        state this step (may be empty).  No-op when idle.  A failed
        launch never raises out of ``step``: its documents are
        re-enqueued solo with backoff (or finished FAILED/TIMED_OUT past
        their retry/deadline budgets) — see the module docstring's
        failure model.

        Telemetry: each launch's wall time decomposes into
        scheduler-pick / host / dispatch / device segments (host is the
        residual, so the four sum to the record's wall clock exactly);
        overlapped launches additionally stamp their in-flight window
        (``inflight_s``) — see ``serving/telemetry.py``.
        """
        t_begin = now = time.perf_counter()
        terminal: List[Tuple[int, int]] = []
        for req in self._queue.pop_expired(now):    # deadline beats backoff
            self._finish(req, TIMED_OUT, now, error="deadline exceeded")
            terminal.append((req.query_id, req.ext_id))
        self._reroute_sick()
        k = max(int(self.inflight), 1)
        dispatched = False
        while len(self._flights) < k:
            # the first pick reuses the step-entry stamp (inflight=1 parity:
            # sched_s measures queue grouping, not work done meanwhile)
            t_pick = time.perf_counter() if dispatched else t_begin
            launch = self._queue.next_launch(
                self._stage_of, self.batch_size, policy=self.policy,
                now=t_pick,
                blocked=self._inflight_blocked if self._flights else None)
            t_sched = time.perf_counter()
            if launch is None:
                break
            be = self.backends[launch.model]
            if self._flights and self._room_needed(be, launch):
                # eviction releases rows open tickets may still read or
                # write: drain every in-flight launch before making room
                self._complete_flights(terminal)
            launch = self._make_room(be, launch)
            self._attempts += 1
            fl = _Flight(launch=launch, be=be, group=None,
                         attempt=self._attempts - 1, t_begin=t_pick,
                         t_sched=t_sched)
            try:
                fl.group = be.dispatch_group(
                    list(launch.doc_ids), self._tok[launch.model],
                    launch.bucket, launch.f_len, launch.fraction,
                    launch.cached_len, self._op_tokens(be, launch.op_id),
                    self.n_classes, op_id=launch.op_id)
            except Exception as exc:    # noqa: BLE001 — isolate the launch
                # fresh stamp: retry/terminal events must postdate any
                # fault events the injector recorded DURING the failed
                # launch (and the retry backoff anchors at the failure)
                self._on_launch_failure(launch, exc, time.perf_counter(),
                                        terminal)
                self._record_flight(fl, ok=False, error=str(exc))
                self._note_progress(True)
                return terminal
            self._flights.append(fl)
            dispatched = True
            self._max_inflight_seen = max(self._max_inflight_seen,
                                          len(self._flights))
        if self._flights:
            self._complete_one(terminal)
            self._note_progress(True)
        else:
            self._note_progress(bool(terminal) or dispatched)
        return terminal

    def _inflight_blocked(self, key) -> bool:
        """Scheduler veto for overlapped dispatch: True if co-scheduling
        this signature group next to the OPEN tickets could touch rows a
        ticket owns.  Documents in flight are already out of the ready
        set, so distinct launches hold disjoint private rows by
        construction; the shared surface is the prefix-sharing plane's
        pinned op row — a FIRST-TOUCH prefill writes that row, so a
        group needing one is held back until the bucket's open tickets
        (which read the row's bucket arena) complete.  Attaching to an
        existing row is a shared read and co-schedules freely."""
        model, op_id, blen = key[0], key[1], key[3]
        be = self.backends[model]
        if not getattr(be, "prefix_sharing", False):
            return False
        if not any(f.launch.model == model and f.launch.bucket == blen
                   for f in self._flights):
            return False
        return bool(be.prefix_slot_needed(blen, op_id))

    def _room_needed(self, be, launch: LaunchSpec) -> bool:
        """Whether ``_make_room`` would have to evict for this launch
        (same budget arithmetic, zero side effects) — the dispatch loop
        drains open tickets first when it would."""
        if (getattr(be, "slot_budget", None) is None
                and getattr(be, "byte_budget", None) is None):
            return False
        extra = 1 if (hasattr(be, "prefix_slot_needed")
                      and be.prefix_slot_needed(launch.bucket, launch.op_id)
                      ) else 0
        need = sum(1 for d in launch.doc_ids if not be.has_slot(d)) + extra
        return bool(be.over_budget(launch.bucket, need))

    def _complete_flights(self, terminal: List[Tuple[int, int]]) -> None:
        """Drain every in-flight launch (FIFO) ahead of a structural
        operation that could touch open tickets' rows (eviction, arena
        loss)."""
        while self._flights:
            self._complete_one(terminal)

    def _complete_one(self, terminal: List[Tuple[int, int]]) -> None:
        """Complete the OLDEST in-flight launch and route its documents.

        FIFO completion keeps billing-ledger order a pure function of
        dispatch order.  Dispatch order itself may legally differ from
        ``inflight=1`` — the window fills with already-ready cohorts
        before a completion re-queues escalated documents — but every
        document still runs exactly its stage ladder, so per-document
        preds/confs/$ (and the arena state they leave behind) are
        bitwise schedule-independent."""
        tm = self.telemetry
        fl = self._flights.pop(0)
        launch, be = fl.launch, fl.be
        ids = list(launch.doc_ids)
        try:
            p, c, new_d, cached_d = be.complete_group(fl.group)
        except Exception as exc:        # noqa: BLE001 — isolate the launch
            # faults surface at completion now: the injector's failure
            # raises here (and real device errors surface at sync), so
            # retry/terminal stamps postdate the fault events
            self._on_launch_failure(launch, exc, time.perf_counter(),
                                    terminal)
            self._record_flight(fl, ok=False, error=str(exc))
            return
        health = self._health.get(launch.model)
        if health is not None:
            health.record_success()
        now = time.perf_counter()
        if tm.tracing:
            sig = (launch.model, launch.op_id, launch.bucket,
                   launch.cached_len, launch.f_len)
            for i, rid in enumerate(ids):
                tm.event(rid, EV_LAUNCH, now,
                         {"sig": sig, "batch": len(ids),
                          "stage": self._requests[rid].stage,
                          "launch": self._launches})
        touched: Dict[int, None] = {}           # queries in this launch
        for i, rid in enumerate(ids):
            req = self._requests[rid]
            qid = req.query_id
            touched[qid] = None
            stats = self._query_stats[qid]
            thr = self._handles[qid].stages[req.stage][3]
            cost_d = (new_d[i] * be.rate_per_token
                      + cached_d[i] * be.rate_per_token * be.cached_discount)
            stats.record(req.stage, 1, int(new_d[i]), int(cached_d[i]),
                         cost_d)
            self._query_cost[qid] += cost_d
            req.cost += cost_d
            self._ledger.append((self._launches, qid, rid, float(cost_d)))
            req.cached[be.name] = be.cached_len(rid)
            if not np.isfinite(c[i]):
                self._quarantine(req, stats, now, terminal)
                continue
            if thr is None or c[i] >= thr[p[i]]:
                self._finish(req, RESOLVED, now, pred=int(p[i]),
                             conf=float(c[i]), exit_stage=req.stage)
                terminal.append((qid, req.ext_id))
            else:
                req.stage += 1
                req.solo = False        # rejoin cohort launches
                if tm.tracing:
                    tm.event(rid, EV_ESCALATE, now,
                             {"to": req.stage, "reason": "threshold"})
                self._sync_cached_for_stage(req)
                self._queue.push(req)
        self._launches += 1
        if tm.enabled:
            tm.count("serve_tokens_total", int(new_d.sum()),
                     backend=launch.model, kind="new")
            tm.count("serve_tokens_total", int(cached_d.sum()),
                     backend=launch.model, kind="cached")
        self._sync_shared_counters()
        for qid in touched:       # a query's ``batches`` = launches it rode
            self._query_stats[qid].batches += 1
        # retirement ticks on EVERY backend: one that stops receiving
        # launches must still free arenas its drifted length mix pinned
        # (safe under open tickets: their live docs keep buckets unretired)
        retired = sum(b.note_launch() for b in self.backends.values()
                      if hasattr(b, "note_launch"))
        if retired:
            self._note_retired(retired)
        self._record_flight(fl, ok=True)
        if self.faults is not None:     # planned arena-loss events, if any
            losses = self.faults.poll_arena_loss(self._launches,
                                                 self.backends)
            if losses and self._flights:
                # releasing a lost arena's rows would hit open tickets:
                # drain them first (poll fires at most once — the nested
                # completions cannot re-enter this branch)
                self._complete_flights(terminal)
            for bname, bucket in losses:
                self._apply_arena_loss(bname, bucket)

    def _record_flight(self, fl: _Flight, ok: bool,
                       error: Optional[str] = None) -> None:
        """Close out one launch's timeline record.  Dispatch and device
        segments come from the ticket's direct measurement around the
        jitted step and its sync; scheduler-pick is the pre-launch
        boundary stamp; the host segment is the residual, so the four
        sum to the record's wall clock exactly.  Overlapped records also
        carry the dispatch-return -> sync-begin window (``inflight_s``)
        and their enqueue/ready stamps for the gap histogram."""
        tm = self.telemetry
        if not tm.enabled:
            return
        t_end = time.perf_counter()
        g = fl.group
        timing = (g.timing if g is not None else None) or {}
        dispatch = timing.get("dispatch", 0.0)
        device = timing.get("device", 0.0)
        launch = fl.launch
        batch = len(launch.doc_ids)
        wall = t_end - fl.t_begin
        sched = fl.t_sched - fl.t_begin
        host = max(wall - sched - dispatch - device, 0.0)
        rec = LaunchRecord(
            index=fl.attempt, ts_start=fl.t_begin, model=launch.model,
            op_id=launch.op_id, bucket=launch.bucket,
            cached_len=launch.cached_len, f_len=launch.f_len, batch=batch,
            width=_pad_width(batch), sched_s=sched, host_s=host,
            dispatch_s=dispatch, device_s=device, wall_s=wall,
            copy_bytes=g.copy_bytes if (ok and g is not None) else 0,
            ok=ok, error=error,
            ts_enqueue=g.ts_enqueue if g is not None else 0.0,
            ts_ready=g.ts_ready if g is not None else 0.0,
            inflight_s=(max(g.ts_sync - g.ts_dispatched, 0.0)
                        if g is not None and g.ts_sync > 0.0 else 0.0))
        if ok and rec.decode_only:
            hbm = g.hbm_bytes if g is not None else None
            if hbm and device > 0.0:
                rec.hbm_bytes = hbm
                rec.bw_util = _bw_util(hbm, device)
        tm.record_launch(rec)
        tm.set_gauge("serve_queue_depth", len(self._queue))

    def _sync_cached_for_stage(self, req: DocRequest) -> None:
        """Prefix-sharing invalidation on op switch.

        In the op-first layout a document's cached KV was computed
        ATTENDING TO the operation prefix in front of it, so advancing to
        a stage that runs a DIFFERENT op on the same prefix-sharing
        backend makes the whole cache invalid: release the slot and
        restart from ``cached_len = 0`` (the re-prefill bills as new
        tokens, exactly like an eviction).  Doc-before-op backends keep
        their cache — that layout never bakes the op into document KV.
        """
        stages = self._handles[req.query_id].stages
        if req.stage >= len(stages):
            return
        model, op_id = stages[req.stage][0], stages[req.stage][1]
        be = self.backends[model]
        if not getattr(be, "prefix_sharing", False):
            return
        cached_op = be.cached_op(req.doc_id)
        if cached_op is not None and cached_op != op_id:
            be.release(req.doc_id)
            req.cached[model] = 0

    def _sync_shared_counters(self) -> None:
        """Refresh shared-substrate memory counters after a launch and
        mirror them into every query's stats (like breaker trips: the
        substrate is shared, so per-query stats report the server-wide
        values and the aggregate counts them once)."""
        self._prefix_hits = sum(getattr(b, "prefix_hits", 0)
                                for b in self.backends.values())
        self._cow_copies = sum(getattr(b, "cow_copies", 0)
                               for b in self.backends.values())
        tm = self.telemetry
        nbytes = 0
        for name, b in self.backends.items():
            if not hasattr(b, "arena_nbytes"):
                continue
            bn = b.arena_nbytes()
            nbytes += bn
            if tm.enabled:
                tm.set_gauge("serve_arena_bytes", bn, backend=name)
                tm.set_gauge("serve_arena_growths",
                             sum(ar.growths
                                 for ar in getattr(b, "_arenas", {}
                                                   ).values()),
                             backend=name)
        self._arena_bytes_peak = max(self._arena_bytes_peak, nbytes)
        if tm.enabled:
            tm.set_gauge("serve_arena_bytes_peak", self._arena_bytes_peak)
        # sanitizer check totals ride the PRIVATE per-sanitizer registries
        # (never the hub — its gated series must be sanitize-inert); the
        # stats mirror is how runs assert coverage (checks > 0)
        san_checks = sum(b._sanitizer.checks
                         for b in self.backends.values()
                         if getattr(b, "_sanitizer", None) is not None)
        for st in self._query_stats.values():
            st.prefix_hits = self._prefix_hits
            st.cow_copies = self._cow_copies
            st.arena_bytes_peak = self._arena_bytes_peak
            st.sanitizer_checks = san_checks

    # ------------------------------------------------------- fault handling
    def _finish(self, req: DocRequest, status: str, now: float,
                pred: Optional[int] = None, conf: Optional[float] = None,
                exit_stage: Optional[int] = None,
                error: Optional[str] = None) -> None:
        """Move one request to a terminal state (the ONLY exit path):
        bookkeeping, slot release, latency/fault counters, journal."""
        qid = req.query_id
        stats = self._query_stats[qid]
        req.done = True
        req.status = status
        req.error = error
        if status == RESOLVED:
            req.pred = pred
            req.conf = conf
            req.exit_stage = exit_stage
            stats.latencies.append(max(now - req.arrival_ts, 0.0))
        elif status == TIMED_OUT:
            stats.timeouts += 1
        elif status == FAILED:
            stats.failures += 1
        for b in self.backends.values():
            if hasattr(b, "release"):
                b.release(req.doc_id)
        for tok in self._tok.values():
            tok.pop(req.doc_id, None)
        self._fresh[qid].append(req.doc_id)
        self._pending[qid] -= 1
        if self.journal is not None:
            self.journal.record_resolution(req)
        tm = self.telemetry
        if tm.enabled:
            tm.count("serve_docs_terminal_total", 1, query=qid,
                     status=status)
            if status == RESOLVED:
                tm.observe("serve_doc_latency_seconds",
                           max(now - req.arrival_ts, 0.0), query=qid)
            if tm.tracing:       # terminal kinds == scheduler status strings
                attrs = ({"stage": exit_stage} if status == RESOLVED
                         else {"error": error})
                tm.event(req.doc_id, status, now, attrs)

    def _on_launch_failure(self, launch: LaunchSpec, exc: Exception,
                           now: float,
                           terminal: List[Tuple[int, int]]) -> None:
        """Launch-level isolation: the failed cohort's documents retry
        INDIVIDUALLY (solo singleton groups) with capped-exponential
        backoff; retry/deadline budgets exhausted -> FAILED/TIMED_OUT.
        Backends commit arena state only after a successful step, so
        there is no partial state to unwind.  Feeds the breaker."""
        self._failed_launches += 1
        tm = self.telemetry
        if tm.enabled:
            tm.count("serve_launch_failures_total", 1, backend=launch.model)
        health = self._health.get(launch.model)
        if health is None:
            health = BackendHealth(threshold=self.breaker_threshold,
                                   cooldown=self.breaker_cooldown)
            self._health[launch.model] = health
        if health.record_failure(self._attempts):
            self._breaker_trips += 1
            # breakers guard a SHARED backend: mirror the trip into every
            # query's stats (the aggregate counts it once)
            for st in self._query_stats.values():
                st.breaker_trips += 1
        for rid in launch.doc_ids:
            req = self._requests[rid]
            stats = self._query_stats[req.query_id]
            req.retries += 1
            stats.retries += 1
            if tm.enabled:
                tm.count("serve_retries_total", 1, query=req.query_id)
            if req.deadline is not None and req.deadline <= now:
                self._finish(req, TIMED_OUT, now, error="deadline exceeded")
                terminal.append((req.query_id, req.ext_id))
            elif req.retries > self.retry.max_retries:
                self._finish(
                    req, FAILED, now,
                    error=f"launch failed {req.retries}x (last: {exc})")
                terminal.append((req.query_id, req.ext_id))
            else:
                req.solo = True
                backoff = self.retry.backoff(req.retries)
                req.not_before = now + backoff
                self._queue.push(req)
                if tm.tracing:
                    tm.event(rid, EV_RETRY, now,
                             {"retries": req.retries, "backoff_s": backoff})

    def _quarantine(self, req: DocRequest, stats: ServeStats, now: float,
                    terminal: List[Tuple[int, int]]) -> None:
        """Non-finite confidence: the launch itself succeeded (and was
        billed), but this document's output is garbage.  First offense
        retries solo at the same stage; a repeat escalates straight to
        the final stage (graceful degradation — the oracle re-reads the
        document from scratch); non-finite at the FINAL stage fails."""
        stats.quarantines += 1
        req.quarantines += 1
        tm = self.telemetry
        if tm.enabled:
            tm.count("serve_quarantines_total", 1, query=req.query_id)
            if tm.tracing:
                tm.event(req.doc_id, EV_QUARANTINE, now,
                         {"count": req.quarantines})
        final = len(self._handles[req.query_id].stages) - 1
        if req.quarantines < 2:
            req.solo = True             # isolate the retry
            self._queue.push(req)
        elif req.stage < final:
            req.stage = final
            req.solo = True
            if tm.tracing:
                tm.event(req.doc_id, EV_ESCALATE, now,
                         {"to": final, "reason": "quarantine"})
            self._sync_cached_for_stage(req)
            self._queue.push(req)
        else:
            self._finish(req, FAILED, now,
                         error="non-finite confidence at final stage")
            terminal.append((req.query_id, req.ext_id))

    def _reroute_sick(self) -> None:
        """Advance queued stages past backends whose breaker is open: the
        document runs its NEXT cascade stage instead (billed as that
        stage).  The final stage is never skipped — documents whose only
        remaining stage is sick wait out the cooldown (or their retry/
        deadline budget)."""
        if not self._health:
            return
        for req in self._queue.ready():
            handle = self._handles[req.query_id]
            final = len(handle.stages) - 1
            advanced = False
            while req.stage < final:
                h = self._health.get(handle.stages[req.stage][0])
                if h is None or not h.is_open(self._attempts):
                    break
                req.stage += 1
                advanced = True
            if advanced:
                self._sync_cached_for_stage(req)
                if self.telemetry.tracing:
                    self.telemetry.event(
                        req.doc_id, EV_ESCALATE, time.perf_counter(),
                        {"to": req.stage, "reason": "breaker"})

    def _apply_arena_loss(self, bname: str, bucket: int) -> None:
        """Replay the eviction path for every live document of a lost
        (backend, bucket): slot released, cached prefix zeroed — the
        next launch re-prefills over a recycled slot, exactly like a
        budget eviction.  In-flight results already billed are kept."""
        be = self.backends[bname]
        tm = self.telemetry
        if tm.enabled:
            tm.count("serve_arena_losses_total", 1, backend=bname)
        for d in list(be.live_docs()):
            if be._doc_slot[d][0] != bucket:
                continue
            lost = be.true_cached_len(d)     # before release zeroes it
            be.release(d)
            req = self._requests.get(d)
            if req is not None and not req.done:
                req.cached[bname] = 0
                st = self._query_stats[req.query_id]
                st.recovered_docs += 1
                st.re_prefill_tokens += lost
                if tm.tracing:
                    tm.event(d, EV_EVICT, time.perf_counter(),
                             {"backend": bname, "lost_tokens": lost,
                              "reason": "arena_loss"})

    def _note_progress(self, progressed: bool) -> None:
        """Liveness watchdog: ``stall_limit`` consecutive no-progress
        steps with nothing legitimately waiting out a finite backoff
        raise ``ServerStalledError`` instead of spinning forever."""
        if progressed:
            self._stalled_steps = 0
            return
        wait = self._queue.next_eligible_in()
        if wait is None or (wait > 0 and math.isfinite(wait)):
            self._stalled_steps = 0     # idle, or a legitimate backoff wait
            return
        self._stalled_steps += 1
        if self._stalled_steps >= self.stall_limit:
            stuck = [(r.query_id, r.ext_id, r.stage, r.retries,
                      r.not_before) for r in self._queue.ready()]
            raise ServerStalledError(
                f"no progress in {self._stalled_steps} consecutive steps; "
                f"stuck requests (qid, doc, stage, retries, not_before): "
                f"{stuck}", stuck)

    def _idle_wait(self) -> None:
        """Sleep out the shortest pending retry backoff so drain loops do
        not busy-spin while every request is backing off.

        Sleeps the ACTUAL eligible interval (capped at ``idle_wait_cap``)
        instead of a fixed 50 ms slice — a 0.5 s backoff used to cost ten
        wakeups; now it costs at most ``ceil(0.5 / cap)``.  The measured
        sleep time accumulates into the launch timeline
        (``telemetry.idle_wait_s``) so drain-side idle waits are visible
        next to sched/host/dispatch/device in ``telemetry_snapshot()``."""
        wait = self._queue.next_eligible_in()
        if wait is not None and wait > 0 and math.isfinite(wait):
            t0 = time.perf_counter()
            time.sleep(min(wait, self.idle_wait_cap))
            self.telemetry.add_idle_wait(time.perf_counter() - t0)

    def ledger(self) -> List[Tuple[int, int, int, float]]:
        """Per-document billing ledger: ``(launch, query_id, request_id,
        cost)`` in billing order — replaying the entries per query with
        ``+=`` reproduces ``cost(qid)`` EXACTLY (same float additions in
        the same order).  Restored journal entries use launch == -1."""
        return list(self._ledger)

    # --------------------------------------------------------------- results
    def _poll_query(self, query_id: int) -> Dict[int, Tuple[int, float, int]]:
        out = {}
        for rid in self._fresh.get(query_id, []):
            req = self._requests[rid]
            out[req.ext_id] = (req.pred, req.conf, req.exit_stage)
        self._fresh[query_id] = []
        return out

    def poll(self) -> Dict[Tuple[int, int], Tuple[int, float, int]]:
        """Server-wide results resolved since the last poll:
        (query_id, doc_id) -> (pred, conf, exit_stage)."""
        out = {}
        for qid in list(self._fresh):
            for d, v in self._poll_query(qid).items():
                out[(qid, d)] = v
        return out

    def cost(self, query_id: int) -> float:
        """Accumulated $ of one query."""
        return self._query_cost[query_id]

    def stats(self, query_id: Optional[int] = None) -> ServeStats:
        """Per-query stats, or the server-wide aggregate (query_id=None).

        Aggregation counts each launch ONCE however many queries shared
        it (``batches`` = server launches), sums stage vectors by index,
        and concatenates latencies.  A query's own ``batches`` counts the
        launches that carried at least one of its documents, so per-query
        batches can sum to more than the aggregate — that overlap is the
        multi-tenant packing win.
        """
        if query_id is not None:
            return self._query_stats[query_id]
        agg = ServeStats()
        for st in [self._departed, *self._query_stats.values()]:
            self._merge_stats(agg, st)
        agg.batches = self._launches
        agg.retired_buckets = self._retired
        agg.breaker_trips = self._breaker_trips   # shared, counted once
        agg.prefix_hits = self._prefix_hits       # shared substrate, ditto
        agg.cow_copies = self._cow_copies
        agg.arena_bytes_peak = self._arena_bytes_peak
        return agg

    @staticmethod
    def _merge_stats(dst: ServeStats, src: ServeStats) -> None:
        """Fold one query's stats into ``dst``.

        Delegates to ``ServeStats.merge_from``, which walks
        ``dataclasses.fields`` and applies each field's declared merge
        strategy — a new counter added to ``ServeStats`` is merged by
        default ("sum") instead of silently dropping here.  Launch and
        breaker counters are declared "shared" (launches and backends
        are shared across queries) and skipped; ``stats()`` overwrites
        them from server-global state."""
        dst.merge_from(src)

    def occupancy(self) -> float:
        """Mean documents per launch across every query the server has
        served — departed queries included (the packing metric: higher
        than any single query could reach alone means cross-query
        launches are being merged)."""
        docs = sum(sum(st.stage_docs)
                   for st in [self._departed, *self._query_stats.values()])
        return docs / self._launches if self._launches else 0.0

    def telemetry_snapshot(self) -> Dict[str, Any]:
        """Structured observability snapshot: the telemetry subsystem's
        counters + launch timeline (``Telemetry.snapshot``) plus a
        ``server`` section of scheduler-level state and — at
        ``level="trace"`` — a ``spans`` section from
        ``Telemetry.validate_spans`` (terminal events required only once
        the queue is idle; in-flight documents legitimately have open
        spans).  Embedded by ``benchmarks/serve_engine.py --smoke`` so CI
        gates span completeness and structural event counts."""
        snap = self.telemetry.snapshot()
        snap["server"] = {
            "launches": self._launches,
            "attempts": self._attempts,
            "failed_launches": self._failed_launches,
            "queue_depth": len(self._queue),
            "occupancy": self.occupancy(),
            # peak dispatch-window depth actually reached (the CI overlap
            # gate requires >= 2 on the --inflight legs)
            "max_inflight": self._max_inflight_seen,
        }
        if self.telemetry.tracing:
            snap["spans"] = self.telemetry.validate_spans(
                require_terminal=not self.pending())
        return snap

    def result(self, query_id: int) -> EngineResult:
        """One query's terminal documents (keyed by the caller's doc ids),
        with per-query cost/stats and deterministic per-document $.

        ``pred``/``conf``/``exit_stage`` cover RESOLVED documents;
        ``status``/``doc_cost`` cover every terminal state (FAILED and
        TIMED_OUT documents have billed partial work too)."""
        done = [r for r in self._requests.values()
                if r.done and r.query_id == query_id]
        ok = [r for r in done if r.status == RESOLVED]
        stats = self._query_stats[query_id]
        return EngineResult(
            pred={r.ext_id: r.pred for r in ok},
            conf={r.ext_id: r.conf for r in ok},
            exit_stage={r.ext_id: r.exit_stage for r in ok},
            cost=self._query_cost[query_id], stats=stats,
            stage_cost=list(stats.stage_cost),
            doc_cost={r.ext_id: r.cost for r in done},
            status={r.ext_id: r.status for r in done})

    def drain(self) -> Dict[int, EngineResult]:
        """Step until the shared queue is idle; per-query results.

        Terminal-state guarantee: every admitted document leaves the
        queue as RESOLVED, FAILED, or TIMED_OUT (the watchdog raises
        ``ServerStalledError`` rather than spinning), so ``drain``
        always returns."""
        while self.pending():
            if not self.step():
                self._idle_wait()
        return {qid: self.result(qid) for qid in self._handles}

    # -------------------------------------------------------- warm restart
    def recover(self, journal: RequestJournal
                ) -> Dict[Tuple[int, int], DocFuture]:
        """Warm-restart from a prior server's write-ahead journal.

        Call on a FRESH server after re-registering the same cascades in
        the same order (journal registration order maps onto this
        server's registration order).  Documents the journal shows
        resolved are restored verbatim — original pred/conf/status/$,
        no recompute, ``cost(qid)`` re-accumulated in journal order so
        accounting matches exactly.  Unresolved documents are
        re-submitted with identical external ids, arrivals, and deadline
        budgets (``recovered_docs`` counts them); step/drain as usual to
        finish them.  Returns ``(query_id, ext_id) -> DocFuture`` for
        every journaled document.
        """
        if len(journal.registrations) != len(self._handles):
            raise ValueError(
                f"journal has {len(journal.registrations)} registered "
                f"queries, this server has {len(self._handles)}; register "
                "the same cascades (in order) before recover()")
        qid_map = dict(zip(journal.registrations, sorted(self._handles)))
        futures: Dict[Tuple[int, int], DocFuture] = {}
        for sub in journal.submits:
            handle = self._handles[qid_map[sub["query_id"]]]
            res = journal.resolutions.get((sub["query_id"], sub["ext_id"]))
            if res is None:
                fut = handle.submit(
                    sub["ext_id"], sub["text"], arrival=sub["arrival"],
                    stage=sub["stage"], deadline_s=sub["deadline_s"])
                self._query_stats[handle.query_id].recovered_docs += 1
            else:
                fut = self._restore(handle, sub, res)
            futures[(handle.query_id, sub["ext_id"])] = fut
        return futures

    def _restore(self, handle: QueryHandle, sub: Dict[str, Any],
                 res: Dict[str, Any]) -> DocFuture:
        """Re-materialize one already-terminal journaled document:
        request record, result fields, $-accounting (ledger entry with
        launch == -1), and this server's own journal — no model work."""
        qid = handle.query_id
        rid = self._seq
        self._seq += 1
        req = DocRequest(
            doc_id=rid, query_id=qid, ext_id=sub["ext_id"], stage=0,
            arrival=sub["arrival"], seq=rid, arrival_ts=time.perf_counter())
        req.done = True
        req.status = res["status"]
        req.pred = res["pred"]
        req.conf = res["conf"]
        req.exit_stage = res["exit_stage"]
        req.cost = res["cost"]
        req.error = res["error"]
        self._requests[rid] = req
        self._ids[(qid, sub["ext_id"])] = rid
        self._query_cost[qid] += res["cost"]
        self._ledger.append((-1, qid, rid, res["cost"]))
        self._fresh[qid].append(rid)
        tm = self.telemetry
        tm.count("serve_docs_restored_total", 1, query=qid)
        if tm.tracing:
            # Restored documents get a degenerate span (submit + terminal
            # at the same stamp): span validation sees a complete span
            # without pretending to know the original timings.
            ts = req.arrival_ts
            tm.register_doc(rid, qid, sub["ext_id"])
            tm.event(rid, EV_SUBMIT, ts,
                     {"stage": sub["stage"], "restored": True})
            attrs = ({"stage": req.exit_stage} if req.status == RESOLVED
                     else {"error": req.error})
            attrs["restored"] = True
            tm.event(rid, req.status, ts, attrs)
        if self.journal is not None:
            self.journal.record_submit(
                qid, sub["ext_id"], sub["text"], sub["arrival"],
                sub["stage"], sub["deadline_s"])
            self.journal.record_resolution(req)
        return DocFuture(query_id=qid, doc_id=sub["ext_id"], _req=req,
                         _server=self)


@dataclass
class CascadeEngine(CascadeServer):
    """Single-query compatibility wrapper over ``CascadeServer``.

    ``start(cascade)`` resets the server session and registers exactly one
    query; ``submit/step/poll/drain/result`` operate on it with the
    pre-server signatures, and ``run()`` (submit everything + drain) is
    bit-identical — preds, confs, per-document $ — to the single-tenant
    engine on static corpora: one registered query produces exactly the
    same launch sequence through the shared queue.
    """

    _handle: Optional[QueryHandle] = field(default=None, repr=False)

    # single-query views used by tests/tools (the server partitions these)
    @property
    def _reqs(self) -> Dict[int, DocRequest]:
        qid = self._handle.query_id
        return {r.ext_id: r for r in self._requests.values()
                if r.query_id == qid}

    @property
    def _stats(self) -> ServeStats:
        return self._query_stats[self._handle.query_id]

    # ------------------------------------------------------------- lifecycle
    def start(self, cascade: Cascade, oracle_model: str = "oracle") -> None:
        """Begin a single-query serving session: reset backends, clear the
        queue, register the cascade."""
        self.reset()
        self._handle = self.register(cascade, oracle_model=oracle_model)

    def submit(self, doc_id: int, text: str,
               arrival: Optional[float] = None, stage: int = 0,
               arrival_ts: Optional[float] = None,
               deadline_s: Optional[float] = None) -> DocFuture:
        """Admit a document into the session (see ``QueryHandle.submit``)."""
        assert self._handle is not None, "call start(cascade) before submit()"
        return self._handle.submit(doc_id, text, arrival=arrival,
                                   stage=stage, arrival_ts=arrival_ts,
                                   deadline_s=deadline_s)

    def step(self) -> List[int]:
        """Dispatch one launch; returns the doc ids resolved by it."""
        assert self._handle is not None, "call start(cascade) before step()"
        return [d for _, d in super().step()]

    def poll(self) -> Dict[int, Tuple[int, float, int]]:
        """Results resolved since the last poll: doc -> (pred, conf, stage)."""
        return self._handle.poll()

    def result(self, query_id: Optional[int] = None) -> EngineResult:
        if query_id is None:
            query_id = self._handle.query_id
        return super().result(query_id)

    def drain(self) -> EngineResult:
        """Step until the queue is idle; result covers the whole session."""
        while self.pending():
            if not CascadeServer.step(self):
                self._idle_wait()
        return self.result()

    # -------------------------------------------------------- batch wrapper
    def run(self, cascade: Cascade, docs: Mapping[int, str],
            oracle_model: str = "oracle",
            enter_stage: Optional[Mapping[int, int]] = None) -> EngineResult:
        """docs: doc_id -> (already reordered) document text.

        Thin batch wrapper over the request loop: submit every document,
        drain the queue.  ``enter_stage`` (doc_id -> stage index) admits
        documents mid-cascade; stage indices are clamped to the oracle
        stage, so every admitted document resolves.
        """
        requested = dict(enter_stage or {})
        for d in requested:
            if d not in docs:
                raise KeyError(f"enter_stage doc {d!r} not in docs")
        self.start(cascade, oracle_model)
        for d, text in docs.items():
            self.submit(d, text, stage=requested.get(d, 0))
        return self.drain()
