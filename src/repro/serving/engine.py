"""Cascade execution engine over real JAX models (slot-arena data plane).

This is the data-plane twin of ``core.cost_model``: the paper's API prompt
caching becomes PHYSICAL KV-prefix reuse.  Documents ride *before*
operations in the token stream, so

  * extending a document from fraction f_j to f_i > f_j runs the model's
    ``extend`` path over only the new suffix (cached doc-prefix KV reused);
  * switching operations on the same model at the same fraction re-runs
    ONLY the operation tokens against the cached document KV;
  * the engine never merges operation tokens into the cached document
    state (op suffixes decode against a gathered *copy* of the slot states
    and are dropped), exactly mirroring the doc-before-op prompt layout.

Arena layout & slot lifecycle
-----------------------------
Per (backend, length bucket) the engine keeps one persistent
``arena.BucketArena``: a batched state pytree ``[n_slots + 1, ...,
s_alloc, ...]`` (s_alloc = bucket + operation reserve; the extra row is
scratch for batch padding).  A document is assigned a slot on first touch
and keeps it until it exits the cascade, at which point the slot returns
to the free list (``scheduler.SlotAllocator``).  Survivor compaction
between stages is an index gather (``LM.take_states``) and a scatter back
(``LM.put_states``) inside one jitted step — no per-document pytree
stacking/slicing on the host.

Stage steps compile once per static signature ``(bucket, cached_len,
new_len, op_len, batch)``: prefill-into-arena is the ``cached_len == 0``
case of extend, fraction extension writes the suffix at a static offset,
and the operation suffix runs as masked decode steps whose per-document
``kv_len`` (true, unpadded prefix length) rides through
``kernels/decode_attention.py``'s scalar-prefetch mask.  Because the op
read is length-masked, mixed TRUE lengths within a bucket share one
launch, and mixed CACHED lengths (documents that entered at different
stages) split into per-offset launches instead of forcing the seed
engine's whole-batch re-prefill.

Token accounting (new vs cached, true unpadded counts) and per-stage $
cost are recorded in ``ServeStats`` with the same rates as the analytical
cost model, so engine costs are directly comparable to ``run_cascade`` in
tests.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tasks import Cascade
from ..data.tokenizer import PAD, HashWordTokenizer, class_token
from .arena import BucketArena
from .scheduler import (ServeStats, SlotAllocator, fraction_len,
                        pack_stage_batches)


def _pad_width(n: int) -> int:
    """Static launch width: next power of two (few compiled batch shapes)."""
    return 1 << max(n - 1, 0).bit_length()


@dataclass
class LMBackend:
    """A model + params behind the engine, with a slot-based KV arena."""

    name: str
    model: Any                       # models.model.LM (or compatible)
    params: Any
    tokenizer: HashWordTokenizer
    rate_per_token: float = 1.0      # $ parity with the analytical model
    cached_discount: float = 0.5
    # NOTE: arenas size per-slot allocation as bucket + op_reserve (rounded
    # to a decode block on pallas runtimes); ``s_alloc`` is kept for seed
    # API compatibility and no longer bounds arena memory.
    s_alloc: int = 4096
    op_reserve: int = 64             # suffix headroom past the bucket length
    init_slots: int = 8              # initial arena capacity per bucket
    _arenas: Dict[int, BucketArena] = field(default_factory=dict)
    _alloc: SlotAllocator = field(default_factory=SlotAllocator)
    _doc_slot: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    _step: Optional[Any] = None      # jitted stage step (lazy)
    host_overhead_s: float = 0.0     # pack/assembly/dispatch wall-clock

    def reset(self) -> None:
        self._arenas.clear()
        self._alloc.reset()
        self._doc_slot.clear()
        self.host_overhead_s = 0.0
        # the jitted step closes over model only; its compile cache survives

    # ------------------------------------------------------------ slot admin
    def cached_len(self, doc_id: int) -> int:
        """Padded cached-prefix length of ``doc_id`` (0 when uncached)."""
        bs = self._doc_slot.get(doc_id)
        if bs is None:
            return 0
        bucket, slot = bs
        return int(self._arenas[bucket].cached_len[slot])

    def release(self, doc_id: int) -> None:
        """Free the document's slot (it exited the cascade)."""
        bs = self._doc_slot.pop(doc_id, None)
        if bs is not None:
            self._alloc.release(bs[0], doc_id)

    def _arena(self, bucket: int) -> BucketArena:
        ar = self._arenas.get(bucket)
        if ar is None:
            s_alloc = bucket + self.op_reserve
            impl = getattr(self.model.rt, "attn_impl", "")
            if impl.startswith("pallas"):
                # keep the decode kernel's cache axis a block multiple so
                # ops.decode_attention never pads K/V copies per step
                blk = getattr(self.model.rt, "block_kv", 512)
                if s_alloc > blk:       # <= blk is always a single block
                    s_alloc = -(-s_alloc // blk) * blk
            ar = BucketArena(self.model, bucket, s_alloc,
                             capacity=self.init_slots)
            self._arenas[bucket] = ar
        return ar

    def _slot_for(self, bucket: int, doc_id: int, arena: BucketArena) -> int:
        prev = self._doc_slot.get(doc_id)
        assert prev is None or prev[0] == bucket, \
            f"doc {doc_id} already staged in bucket {prev[0]}, got {bucket}"
        slot = self._alloc.peek(bucket, doc_id)
        if slot < 0:
            slot = self._alloc.slot_of(bucket, doc_id)
            arena.ensure_capacity(self._alloc.high_water(bucket))
            arena.clear_slot(slot)
            self._doc_slot[doc_id] = (bucket, slot)
        return slot

    # --------------------------------------------------------------- compute
    def _build_step(self):
        model = self.model

        def step(params, arena_states, slots, new_tok, op_tok, kv_true,
                 *, c_len: int, op_len: int):
            st = model.take_states(arena_states, slots)
            if new_tok.shape[1] > 0:
                # prefill (c_len == 0) / fraction-extend into the arena
                _, st = model.extend(params, {"tokens": new_tok}, st,
                                     q_offset=c_len)
                arena_states = model.put_states(arena_states, slots, st)
            # operation suffix: masked decode steps over the gathered COPY
            # (kv_true = per-doc TRUE prefix length -> pad KV is invisible;
            # the doc snapshot in the arena survives untouched)
            logits = None
            pos = kv_true.astype(jnp.int32)
            B = slots.shape[0]
            for t in range(op_len):
                tok = jnp.broadcast_to(op_tok[t], (B,))
                logits, st = model.decode_step(params, tok, st, pos + t)
            return logits, arena_states

        kwargs: Dict[str, Any] = {"static_argnames": ("c_len", "op_len")}
        if jax.default_backend() != "cpu":      # CPU donation only warns
            kwargs["donate_argnums"] = (1,)
        return jax.jit(step, **kwargs)

    def class_confidences(self, logits: jnp.ndarray, n_classes: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Softmax over the class answer tokens -> (pred, conf)."""
        toks = [class_token(c) for c in range(n_classes)]
        cls_logits = np.asarray(logits, np.float64)[:, toks]
        z = cls_logits - cls_logits.max(axis=1, keepdims=True)
        probs = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
        return probs.argmax(axis=1), probs.max(axis=1)

    def run_stage(
        self,
        doc_ids: Sequence[int],
        doc_tokens: Mapping[int, np.ndarray],
        bucket: int,                             # padded full-doc length
        fraction: float,
        op_tokens: np.ndarray,
        n_classes: int,
    ) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """Run (op, fraction) over one bucket batch.

        Documents may carry heterogeneous cached prefixes: the batch is
        split into per-``cached_len`` launches (each reusing its cache)
        rather than re-prefilling everyone.  Returns (pred [B], conf [B],
        new_tokens, cached_tokens) with TRUE (unpadded) token counts for $
        accounting.
        """
        assert len(op_tokens) > 0, "operations must encode to >= 1 token"
        assert len(op_tokens) <= self.op_reserve, \
            f"operation longer than op_reserve ({len(op_tokens)})"
        B = len(doc_ids)
        f_len = fraction_len(bucket, fraction)
        pred = np.zeros(B, np.int64)
        conf = np.zeros(B, np.float64)
        pos_of = {d: i for i, d in enumerate(doc_ids)}
        new_true_total = 0
        cached_true_total = 0

        groups: Dict[int, List[int]] = {}
        for d in doc_ids:
            eff_c = min(self.cached_len(d), f_len)
            groups.setdefault(eff_c, []).append(d)

        for eff_c in sorted(groups):
            ids = groups[eff_c]
            p, c, new_t, cached_t = self._run_group(
                ids, doc_tokens, bucket, f_len, fraction, eff_c,
                op_tokens, n_classes)
            for j, d in enumerate(ids):
                pred[pos_of[d]] = p[j]
                conf[pos_of[d]] = c[j]
            new_true_total += new_t
            cached_true_total += cached_t
        return pred, conf, new_true_total, cached_true_total

    def _run_group(self, ids, doc_tokens, bucket, f_len, fraction, eff_c,
                   op_tokens, n_classes):
        """One static-signature launch: all ``ids`` share ``eff_c``."""
        t0 = time.perf_counter()
        arena = self._arena(bucket)
        slots = [self._slot_for(bucket, d, arena) for d in ids]
        B = len(ids)
        Bp = _pad_width(B)
        n_new = f_len - eff_c                     # 0 => decode-only launch
        op_len = len(op_tokens)

        slots_arr = np.full(Bp, arena.scratch_slot, np.int32)
        slots_arr[:B] = slots
        new_tok = np.full((Bp, n_new), PAD, np.int32)
        kv_true = np.ones(Bp, np.int32)
        new_true = 0
        cached_true = 0
        for i, d in enumerate(ids):
            toks = doc_tokens[d]
            slot = slots[i]
            if n_new > 0:
                seg = toks[min(eff_c, len(toks)): min(f_len, len(toks))]
                new_tok[i, : len(seg)] = seg
                new_true += len(seg)
                cached_true += min(eff_c, len(toks))
            else:
                cached_true += min(int(arena.true_len[slot]),
                                   self._true_len(toks, fraction))
            kv_true[i] = self._true_len(toks, fraction)
        self.host_overhead_s += time.perf_counter() - t0

        if self._step is None:
            self._step = self._build_step()
        t0 = time.perf_counter()
        logits, new_states = self._step(
            self.params, arena.states, jnp.asarray(slots_arr),
            jnp.asarray(new_tok), jnp.asarray(op_tokens, jnp.int32),
            jnp.asarray(kv_true), c_len=eff_c, op_len=op_len)
        arena.states = new_states
        self.host_overhead_s += time.perf_counter() - t0   # async dispatch

        if n_new > 0:
            for i, d in enumerate(ids):
                slot = slots[i]
                arena.cached_len[slot] = f_len
                arena.true_len[slot] = min(f_len, len(doc_tokens[d]))
        pred, conf = self.class_confidences(
            np.asarray(logits)[:B], n_classes)
        return pred, conf, new_true + B * op_len, cached_true

    @staticmethod
    def _true_len(toks: np.ndarray, fraction: float) -> int:
        return max(int(math.ceil(len(toks) * fraction)), 1)


@dataclass
class EngineResult:
    pred: Dict[int, int]
    conf: Dict[int, float]
    exit_stage: Dict[int, int]
    cost: float
    stats: ServeStats
    stage_cost: List[float] = field(default_factory=list)


@dataclass
class CascadeEngine:
    """Executes a task cascade over documents with real backends."""

    backends: Dict[str, Any]                # "proxy"/"oracle" -> backend
    operations: Dict[str, str]              # op id -> operation text
    n_classes: int
    batch_size: int = 8
    _op_tok_cache: Dict[Tuple[str, str], np.ndarray] = field(
        default_factory=dict, repr=False)

    def _op_tokens(self, backend, op_id: str) -> np.ndarray:
        key = (backend.name, op_id)
        toks = self._op_tok_cache.get(key)
        if toks is None:
            toks = np.asarray(
                backend.tokenizer.encode(self.operations[op_id]), np.int32)
            self._op_tok_cache[key] = toks
        return toks

    def run(self, cascade: Cascade, docs: Mapping[int, str],
            oracle_model: str = "oracle",
            enter_stage: Optional[Mapping[int, int]] = None) -> EngineResult:
        """docs: doc_id -> (already reordered) document text.

        ``enter_stage`` (doc_id -> stage index) admits documents mid-run —
        the streaming-arrival pattern.  Late entrants share buckets with
        docs that already carry cached prefixes; the per-``cached_len``
        launch split keeps the veterans' caches hot.  Stage indices are
        clamped to the oracle stage, so every admitted document resolves.
        """
        stats = ServeStats()
        tok: Dict[str, Dict[int, np.ndarray]] = {m: {} for m in self.backends}
        full_len: Dict[int, int] = {}
        for m, be in self.backends.items():
            be.reset()
            for d, text in docs.items():
                ids = np.asarray(be.tokenizer.encode(text), np.int32)
                tok[m][d] = ids
                full_len[d] = len(ids)
        last_stage = len(cascade.tasks)          # oracle fallthrough index
        requested = dict(enter_stage or {})
        enter_stage = {}
        for d, s in requested.items():
            if d not in docs:
                raise KeyError(f"enter_stage doc {d!r} not in docs")
            enter_stage[d] = min(max(int(s), 0), last_stage)

        unresolved = [d for d in docs if enter_stage.get(d, 0) <= 0]
        pred: Dict[int, int] = {}
        conf: Dict[int, float] = {}
        exit_stage: Dict[int, int] = {}
        cost = 0.0

        stages = list(cascade.tasks) + [None]        # None = oracle task
        for si, task in enumerate(stages):
            if si > 0:
                unresolved.extend(
                    d for d, s in enter_stage.items() if s == si)
            if not unresolved:
                continue
            if task is None:
                model, op_id, fraction, thr = oracle_model, "o_orig", 1.0, None
            else:
                model = task.config.model
                op_id = task.config.operation
                fraction = task.config.fraction
                thr = task.threshold_vector(self.n_classes)
            be = self.backends[model]
            cached = {d: be.cached_len(d) if hasattr(be, "cached_len") else 0
                      for d in unresolved}
            batches = pack_stage_batches(
                unresolved, full_len, cached, fraction, self.batch_size)
            survivors = []
            for sb in batches:
                ids = list(sb.doc_ids)
                p, c, new_t, cached_t = be.run_stage(
                    ids, tok[model], sb.bucket, fraction,
                    self._op_tokens(be, op_id), self.n_classes)
                batch_cost = (
                    new_t * be.rate_per_token
                    + cached_t * be.rate_per_token * be.cached_discount)
                stats.record(si, len(ids), new_t, cached_t, batch_cost)
                stats.batches += 1
                cost += batch_cost
                for i, d in enumerate(ids):
                    take = thr is None or c[i] >= thr[p[i]]
                    if take:
                        pred[d] = int(p[i])
                        conf[d] = float(c[i])
                        exit_stage[d] = si
                        for b in self.backends.values():
                            if hasattr(b, "release"):
                                b.release(d)
                    else:
                        survivors.append(d)
            unresolved = survivors
        return EngineResult(pred, conf, exit_stage, cost, stats,
                            stage_cost=list(stats.stage_cost))
