"""Cascade execution engine over real JAX models.

This is the data-plane twin of ``core.cost_model``: the paper's API prompt
caching becomes PHYSICAL KV-prefix reuse.  Documents ride *before*
operations in the token stream, so

  * extending a document from fraction f_j to f_i > f_j runs the model's
    ``extend`` path over only the new suffix (cached doc-prefix KV reused);
  * switching operations on the same model at the same fraction re-runs
    ONLY the operation tokens against the cached document KV;
  * the engine never merges operation tokens into the cached document
    state (states are immutable pytrees — the op-extension's states are
    simply dropped), exactly mirroring the doc-before-op prompt layout.

Shape discipline: documents are bucketed ONCE by full-document token count
(power-of-two buckets); within a bucket every doc pads to the bucket
length, so each (stage, bucket) launch has a static (cached_len, new_len)
signature — a handful of compiled shapes regardless of corpus size.  PAD
tokens participate in attention (standard right-pad serving compromise;
the class logits read off the final OPERATION token, which always attends
to the true document prefix).

Token accounting (new vs cached, true unpadded counts) is recorded per
stage and converted to $ with the same rates as the analytical cost model,
so engine costs are directly comparable to ``run_cascade`` in tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tasks import Cascade, TaskConfig
from ..data.tokenizer import PAD, HashWordTokenizer, class_token
from .scheduler import ServeStats, bucket_len, make_buckets


def _path_key(p) -> str:
    return str(getattr(p, "key", getattr(p, "idx", p)))


def _leaf_batch_axis(path) -> int:
    """Batch axis of a state leaf: scan-stacked 'stages' leaves carry the
    repetition dim first (R, B, ...); everything else is (B, ...)."""
    return 1 if _path_key(path[0]) == "stages" else 0


def _stack_states(states_list):
    flat0, treedef = jax.tree_util.tree_flatten_with_path(states_list[0])
    flats = [jax.tree.leaves(s) for s in states_list]
    out = []
    for li, (path, _) in enumerate(flat0):
        ax = _leaf_batch_axis(path)
        out.append(jnp.stack([f[li] for f in flats], axis=ax))
    return jax.tree_util.tree_unflatten(treedef, out)


def _slice_states(states, i: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(states)
    out = [jnp.take(leaf, i, axis=_leaf_batch_axis(path))
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class LMBackend:
    """A model + params behind the engine, with per-doc KV state cache."""

    name: str
    model: Any                       # models.model.LM (or compatible)
    params: Any
    tokenizer: HashWordTokenizer
    rate_per_token: float = 1.0      # $ parity with the analytical model
    cached_discount: float = 0.5
    s_alloc: int = 4096
    # doc_id -> (padded_cached_len, true_cached_tokens, per-doc states)
    _cache: Dict[int, Tuple[int, int, Any]] = field(default_factory=dict)

    def reset(self) -> None:
        self._cache.clear()

    def class_confidences(self, logits: jnp.ndarray, n_classes: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Softmax over the class answer tokens -> (pred, conf)."""
        toks = [class_token(c) for c in range(n_classes)]
        cls_logits = np.asarray(logits, np.float64)[:, toks]
        z = cls_logits - cls_logits.max(axis=1, keepdims=True)
        probs = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
        return probs.argmax(axis=1), probs.max(axis=1)

    def run_stage(
        self,
        doc_ids: Sequence[int],
        doc_tokens: Mapping[int, np.ndarray],
        bucket: int,                             # padded full-doc length
        fraction: float,
        op_tokens: np.ndarray,
        n_classes: int,
    ) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """Run (op, fraction) over one bucket batch.

        All docs in the batch share ``bucket``; the fraction slice is
        ``ceil(fraction * bucket)`` tokens (right-padded with PAD), so the
        whole batch extends from the same static offset.
        Returns (pred [B], conf [B], new_tokens, cached_tokens) with TRUE
        (unpadded) token counts for $ accounting.
        """
        B = len(doc_ids)
        f_len = max(int(math.ceil(bucket * fraction)), 1)
        entries = [self._cache.get(d) for d in doc_ids]
        have_cache = all(e is not None for e in entries) and \
            len({e[0] for e in entries if e is not None}) == 1
        c_len = entries[0][0] if have_cache and entries[0] else 0
        if c_len > f_len:
            # cached prefix already covers this fraction: reuse as-is
            states = _stack_states([e[2] for e in entries])
            q_off = c_len
            new_true = 0
            cached_true = sum(min(e[1], self._true_len(doc_tokens[d],
                                                       fraction))
                              for e, d in zip(entries, doc_ids))
            n_new = 0
        else:
            n_new = f_len - c_len
            new_tok = np.full((B, max(n_new, 1)), PAD, np.int32)
            new_true = 0
            cached_true = 0
            for i, d in enumerate(doc_ids):
                toks = doc_tokens[d]
                seg = toks[min(c_len, len(toks)): min(f_len, len(toks))]
                new_tok[i, : len(seg)] = seg
                new_true += len(seg)
                cached_true += min(c_len, len(toks)) if have_cache else 0
            if have_cache and c_len > 0:
                states = _stack_states([e[2] for e in entries])
                _, states = self.model.extend(
                    self.params, {"tokens": jnp.asarray(new_tok)},
                    states, q_offset=c_len)
            else:
                _, states = self.model.prefill(
                    self.params, {"tokens": jnp.asarray(new_tok)},
                    s_alloc=self.s_alloc)
            q_off = f_len
            for i, d in enumerate(doc_ids):
                toks = doc_tokens[d]
                true_cached = min(f_len, len(toks))
                self._cache[d] = (f_len, true_cached,
                                  _slice_states(states, i))

        # operation extension (doc-state snapshot survives untouched)
        opb = np.broadcast_to(op_tokens[None],
                              (B, len(op_tokens))).astype(np.int32)
        logits, _ = self.model.extend(
            self.params, {"tokens": jnp.asarray(opb)}, states, q_offset=q_off)
        pred, conf = self.class_confidences(logits, n_classes)
        return pred, conf, new_true + B * len(op_tokens), cached_true

    @staticmethod
    def _true_len(toks: np.ndarray, fraction: float) -> int:
        return max(int(math.ceil(len(toks) * fraction)), 1)


@dataclass
class EngineResult:
    pred: Dict[int, int]
    conf: Dict[int, float]
    exit_stage: Dict[int, int]
    cost: float
    stats: ServeStats


@dataclass
class CascadeEngine:
    """Executes a task cascade over documents with real backends."""

    backends: Dict[str, LMBackend]          # "proxy"/"oracle" -> backend
    operations: Dict[str, str]              # op id -> operation text
    n_classes: int
    batch_size: int = 8

    def _op_tokens(self, backend: LMBackend, op_id: str) -> np.ndarray:
        return np.asarray(
            backend.tokenizer.encode(self.operations[op_id]), np.int32)

    def run(self, cascade: Cascade, docs: Mapping[int, str],
            oracle_model: str = "oracle") -> EngineResult:
        """docs: doc_id -> (already reordered) document text."""
        stats = ServeStats()
        tok: Dict[str, Dict[int, np.ndarray]] = {m: {} for m in self.backends}
        full_len: Dict[int, int] = {}
        for m, be in self.backends.items():
            be.reset()
            for d, text in docs.items():
                ids = np.asarray(be.tokenizer.encode(text), np.int32)
                tok[m][d] = ids
                full_len[d] = len(ids)

        unresolved = list(docs.keys())
        pred: Dict[int, int] = {}
        conf: Dict[int, float] = {}
        exit_stage: Dict[int, int] = {}
        cost = 0.0

        stages = list(cascade.tasks) + [None]        # None = oracle task
        for si, task in enumerate(stages):
            if not unresolved:
                break
            if task is None:
                model, op_id, fraction, thr = oracle_model, "o_orig", 1.0, None
            else:
                model = task.config.model
                op_id = task.config.operation
                fraction = task.config.fraction
                thr = task.threshold_vector(self.n_classes)
            be = self.backends[model]
            batches = make_buckets(unresolved, full_len, self.batch_size)
            survivors = []
            for blen, ids in batches:
                p, c, new_t, cached_t = be.run_stage(
                    ids, tok[model], blen, fraction,
                    self._op_tokens(be, op_id), self.n_classes)
                stats.record(si, len(ids), new_t, cached_t)
                stats.batches += 1
                cost += (new_t * be.rate_per_token
                         + cached_t * be.rate_per_token * be.cached_discount)
                for i, d in enumerate(ids):
                    take = thr is None or c[i] >= thr[p[i]]
                    if take:
                        pred[d] = int(p[i])
                        conf[d] = float(c[i])
                        exit_stage[d] = si
                    else:
                        survivors.append(d)
            unresolved = survivors
        return EngineResult(pred, conf, exit_stage, cost, stats)
