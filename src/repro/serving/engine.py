"""Cascade serving: a long-lived multi-tenant ``CascadeServer`` running a
continuous-batching request loop over real JAX models (slot-arena data
plane).

This is the data-plane twin of ``core.cost_model``: the paper's API prompt
caching becomes PHYSICAL KV-prefix reuse.  Documents ride *before*
operations in the token stream, so

  * extending a document from fraction f_j to f_i > f_j runs the model's
    ``extend`` path over only the new suffix (cached doc-prefix KV reused);
  * switching operations on the same model at the same fraction re-runs
    ONLY the operation tokens against the cached document KV;
  * the engine never merges operation tokens into the cached document
    state, exactly mirroring the doc-before-op prompt layout: on the
    paged data plane op suffixes decode over the arena in place behind a
    tiny KV-window undo log, on the gather plane against a row copy that
    is dropped — either way the cached document prefix survives bitwise
    untouched.

Multi-tenant serving API
------------------------
One server owns the LM backends, their KV arenas, and the global
``scheduler.RequestQueue``; many queries (cascades) are registered and
served CONCURRENTLY over that shared substrate:

    server = CascadeServer(backends, operations, n_classes)
    handle = server.register(cascade, accuracy_target=0.9)   # QueryHandle
    fut    = handle.submit(doc_id, text)                     # DocFuture
    server.step()                            dispatch ONE launch (any query)
    handle.poll()                            this query's fresh resolutions
    handle.result() / server.stats(qid)      per-query results, stats, $
    server.drain()                           step until idle (all queries)

Every submitted document becomes a ``scheduler.DocRequest`` carrying its
owning ``query_id``; the stage cursor resolves ``(model, op, fraction)``
through the handle's stage table.  Because the launch signature
``(backend, bucket, cached_len, op, f_len)`` carries neither stage index
nor query id, ``RequestQueue.next_launch`` packs ready documents ACROSS
queries: a stage-0 prefill for query A and a stage-2 decode for query B
merge into one launch whenever their static shapes agree, and mixed-query
launches share compiled steps, op-token memos, and KV slots in one arena
pool.  Results, ``ServeStats``, and $-accounting stay partitioned per
query.  Which ready group dispatches next is a pluggable ``policy``
(default ``scheduler.oldest_head_first``; admission is fair across
queries because ``(arrival, seq)`` is server-global FIFO).

``CascadeEngine`` survives as the single-query compatibility wrapper:
``start(cascade)`` registers one query on a private session and
``submit/step/poll/drain/run`` delegate to it — ``run()`` is bit-identical
(preds, confs, per-document $) to the pre-server engine on static corpora.

Arena layout, slot lifecycle & memory control
---------------------------------------------
Per (backend, length bucket) the server keeps one persistent
``arena.BucketArena``: a batched state pytree ``[n_slots + 1, ...,
s_alloc, ...]`` (s_alloc = bucket + operation reserve; the extra row is
scratch for batch padding).  A document is assigned a slot on first touch
and keeps it until it exits its cascade — unless a backend budget binds.
Budgets are dual: ``slot_budget`` caps live slots, ``byte_budget`` caps
device bytes across the backend's arenas (projected via
``arena_nbytes()`` + the growth the pending launch would force), and
eviction triggers on whichever binds first.  Victims are chosen
fewest-cached-tokens-lost first (newest arrival breaks ties): the evicted
document re-enters the queue at its current stage with ``cached_len = 0``
and re-prefills as new tokens.  Under byte pressure a bucket emptied by
eviction is retired IMMEDIATELY (its arena freed); otherwise buckets
whose live-slot count stays zero for ``retire_after`` launches are
retired in the background, so a drifting length mix does not pin memory.
Survivor compaction is an index gather (``LM.take_states``) and a scatter
back (``LM.put_states``) inside one jitted step — no per-document pytree
stacking/slicing on the host.

Stage steps compile once per static signature ``(bucket, cached_len,
new_len, op_len, batch)`` — note: no stage index and no query id, so
interleaved stages AND interleaved queries share compiled steps.
Prefill-into-arena is the ``cached_len == 0`` case of extend, fraction
extension writes the suffix at a static offset with per-row true lengths
masking bucket PAD out of the chunk (``kernels/flash_attention.py``
scalar-prefetch ``kv_len``), and the operation suffix runs as masked
decode steps whose per-document ``kv_len`` rides through
``kernels/decode_attention.py``.

Paged data plane (default on Pallas runtimes, for models whose
serve-state is all full-attention KV caches): the stage step never
copies arena rows.  Per-sequence slot ids ride in scalar-prefetch SMEM
beside ``kv_len`` and the paged kernels
(``ops.arena_decode_attention`` / ``ops.attention_paged``) DMA
``k_arena[slot]`` blocks directly, so extend scatters only the chunk's
KV and decode reads the arena in place — per-launch copy traffic drops
from O(batch * s_alloc) (the gather/scatter of whole rows) to the
O(batch * op_len) op-suffix undo log (see ``LMBackend.paged_step``'s
comments; ``gather_bytes_per_launch`` vs ``paged_copy_bytes_per_launch``
quantify it).  Results are BITWISE identical to the gather plane —
preds, confs, per-document $, and the arena contents itself — which
``tests/test_serving.py`` asserts; the gather step survives as the
reference/CPU plane (``paged=False``, XLA/naive impls).

Token accounting (new vs cached, true unpadded counts), per-stage $ cost,
per-document latencies, evictions, and retired buckets are recorded in a
per-query ``ServeStats`` with the same rates as the analytical cost
model, so engine costs are directly comparable to ``run_cascade`` in
tests; ``server.stats()`` aggregates across queries (launches counted
once, however many queries shared them).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import (Any, Dict, List, Mapping, Optional, Sequence, Set,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tasks import Cascade
from ..data.tokenizer import PAD, HashWordTokenizer, class_token
from .arena import BucketArena
from .scheduler import (DocRequest, LaunchSpec, RequestQueue, SchedulingPolicy,
                        ServeStats, SlotAllocator, StageConfig, fraction_len)


def _pad_width(n: int) -> int:
    """Static launch width: next power of two (few compiled batch shapes)."""
    return 1 << max(n - 1, 0).bit_length()


@dataclass
class LMBackend:
    """A model + params behind the server, with a slot-based KV arena."""

    name: str
    model: Any                       # models.model.LM (or compatible)
    params: Any
    tokenizer: HashWordTokenizer
    rate_per_token: float = 1.0      # $ parity with the analytical model
    cached_discount: float = 0.5
    # NOTE: arenas size per-slot allocation as bucket + op_reserve (rounded
    # to a decode block on pallas runtimes); ``s_alloc`` is kept for seed
    # API compatibility and no longer bounds arena memory.
    s_alloc: int = 4096
    op_reserve: int = 64             # suffix headroom past the bucket length
    init_slots: int = 8              # initial arena capacity per bucket
    slot_budget: Optional[int] = None  # max live slots across buckets
    byte_budget: Optional[int] = None  # max device bytes across arenas
    retire_after: int = 64           # idle launches before bucket retirement
    # Paged data plane: None = auto (on for Pallas runtimes when the model
    # is paged-capable — every serve-state leaf a full-attention KV cache).
    # True forces it (XLA/naive impls fall back to a per-call gather inside
    # the kernels wrappers — reference semantics, not the fast path); False
    # forces the PR-1 gather/scatter stage step.
    paged: Optional[bool] = None
    _arenas: Dict[int, BucketArena] = field(default_factory=dict)
    _alloc: SlotAllocator = field(default_factory=SlotAllocator)
    _doc_slot: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    _idle: Dict[int, int] = field(default_factory=dict)
    _slot_nbytes: Dict[int, int] = field(default_factory=dict)
    _step: Optional[Any] = None      # jitted stage step (lazy)
    pressure_retired: int = 0        # buckets freed mid-eviction (byte budget)
    host_overhead_s: float = 0.0     # pack/assembly/dispatch wall-clock

    def reset(self) -> None:
        self._arenas.clear()
        self._alloc.reset()
        self._doc_slot.clear()
        self._idle.clear()
        self.pressure_retired = 0
        self.host_overhead_s = 0.0
        # the jitted step closes over model only; its compile cache survives

    # ------------------------------------------------------------ slot admin
    def cached_len(self, doc_id: int) -> int:
        """Padded cached-prefix length of ``doc_id`` (0 when uncached)."""
        bs = self._doc_slot.get(doc_id)
        if bs is None:
            return 0
        bucket, slot = bs
        return int(self._arenas[bucket].cached_len[slot])

    def true_cached_len(self, doc_id: int) -> int:
        """TRUE (unpadded) cached tokens of ``doc_id`` — what an eviction
        would actually lose (and re-bill as new tokens)."""
        bs = self._doc_slot.get(doc_id)
        if bs is None:
            return 0
        bucket, slot = bs
        return int(self._arenas[bucket].true_len[slot])

    def has_slot(self, doc_id: int) -> bool:
        return doc_id in self._doc_slot

    def live_slots(self) -> int:
        return len(self._doc_slot)

    def live_docs(self) -> List[int]:
        return list(self._doc_slot)

    def release(self, doc_id: int) -> None:
        """Free the document's slot (it exited the cascade or was evicted)."""
        bs = self._doc_slot.pop(doc_id, None)
        if bs is not None:
            self._alloc.release(bs[0], doc_id)

    # ------------------------------------------------------- memory control
    def arena_nbytes(self) -> int:
        """Total device bytes pinned by this backend's arenas."""
        return sum(ar.nbytes() for ar in self._arenas.values())

    def slot_nbytes(self, bucket: int) -> int:
        """Device bytes one arena row of ``bucket`` pins.

        Computed from state SHAPES (``jax.eval_shape`` semantics — nothing
        is materialized), so the byte budget can project the cost of a
        bucket whose arena does not exist yet.
        """
        n = self._slot_nbytes.get(bucket)
        if n is None:
            shapes = self.model.state_shapes(1, self._s_alloc_for(bucket))
            n = sum(int(math.prod(l.shape)) * np.dtype(l.dtype).itemsize
                    for l in jax.tree.leaves(shapes))
            self._slot_nbytes[bucket] = n
        return n

    def _initial_capacity(self, bucket: int) -> int:
        """Capacity a NEW arena for ``bucket`` opens with: ``init_slots``,
        shrunk to what the byte budget can host beside existing arenas
        (>= 1 — a single slot always proceeds, even over budget)."""
        cap = self.init_slots
        if self.byte_budget is not None:
            s = self.slot_nbytes(bucket)
            avail = (self.byte_budget - self.arena_nbytes()) // s - 1
            cap = min(cap, avail)
        return max(cap, 1)

    def projected_nbytes(self, bucket: int, need_new: int) -> int:
        """Arena bytes after ``bucket`` grows to host ``need_new`` more
        slots (free-list reuse, budget-capped initial capacity, and
        capacity doubling modelled exactly)."""
        total = self.arena_nbytes()
        free = self._alloc.high_water(bucket) - self._alloc.live(bucket)
        grow_to = self._alloc.high_water(bucket) + max(need_new - free, 0)
        ar = self._arenas.get(bucket)
        if ar is None:
            if need_new <= 0:
                return total
            rows_now, new_cap = 0, self._initial_capacity(bucket)
        else:
            rows_now, new_cap = ar.capacity + 1, ar.capacity
        while new_cap < grow_to:
            new_cap *= 2
        return total + ((new_cap + 1) - rows_now) * self.slot_nbytes(bucket)

    def over_budget(self, bucket: int, need_new: int) -> bool:
        """Would hosting ``need_new`` fresh slots in ``bucket`` bust either
        budget?  Slots and bytes are checked independently — eviction
        triggers on whichever binds first."""
        if (self.slot_budget is not None
                and self.live_slots() + need_new > self.slot_budget):
            return True
        if (self.byte_budget is not None
                and self.projected_nbytes(bucket, need_new) > self.byte_budget):
            return True
        return False

    def admissible_new(self, bucket: int, need: int) -> int:
        """Largest prefix of ``need`` fresh allocations both budgets can
        host (>= 1: a single document always proceeds, so launches cannot
        livelock under an impossibly small budget)."""
        k = need
        while k > 1 and self.over_budget(bucket, k):
            k -= 1
        return k

    def evict_for_room(self, bucket: int, need_new: int,
                       victims: Sequence[int]) -> List[int]:
        """Preempt slots until ``need_new`` allocations for ``bucket`` fit
        both budgets.

        ``victims`` is the caller's priority order, lowest first (the
        server passes fewest-cached-tokens-lost first, newest arrival
        breaking ties, and excludes the launch being packed).  Returns the
        evicted doc ids; the caller re-queues them with ``cached_len = 0``.
        Under byte pressure a bucket emptied by eviction is retired
        immediately (``pressure_retired`` counts them for stats) — slot
        recycling alone frees no bytes, dropping the arena does.  Stops
        early when the victim list runs out — the launch is then trimmed
        by the server rather than over-committing the arena.
        """
        evicted: List[int] = []
        if self.slot_budget is None and self.byte_budget is None:
            return evicted
        for d in victims:
            if not self.over_budget(bucket, need_new):
                break
            bs = self._doc_slot.get(d)
            if bs is None:
                continue
            vb = bs[0]
            slot_over = (self.slot_budget is not None
                         and self.live_slots() + need_new > self.slot_budget)
            if not slot_over:
                # byte pressure alone: a same-bucket victim only helps by
                # avoiding GROWTH (freed slots are recycled; releasing
                # them frees no bytes).  An arena already irreducibly
                # over budget must not thrash its residents' caches.
                grows = (self.projected_nbytes(bucket, need_new)
                         > self.arena_nbytes())
                if vb == bucket and not grows:
                    continue
            self.release(d)
            evicted.append(d)
            if (self.byte_budget is not None and vb != bucket
                    and vb in self._arenas and self._alloc.live(vb) == 0):
                self.retire(vb)
                self.pressure_retired += 1
        return evicted

    def note_launch(self) -> int:
        """Bucket retirement hook, called once per server step (on every
        backend, so one that stops receiving launches still ticks).

        A bucket whose live-slot count has been zero for ``retire_after``
        consecutive ticks has drifted out of the workload's length mix:
        its device arena is freed (``retire``).  Returns how many buckets
        were retired.
        """
        retired = 0
        for bucket in list(self._arenas):
            if self._alloc.live(bucket) == 0:
                self._idle[bucket] = self._idle.get(bucket, 0) + 1
                if self._idle[bucket] >= self.retire_after:
                    self.retire(bucket)
                    retired += 1
            else:
                self._idle[bucket] = 0
        return retired

    def retire(self, bucket: int) -> None:
        """Free an idle bucket's arena (no live slots)."""
        assert self._alloc.live(bucket) == 0, \
            f"bucket {bucket} retired with live slots"
        self._arenas.pop(bucket, None)
        self._alloc.retire_bucket(bucket)
        self._idle.pop(bucket, None)

    def _s_alloc_for(self, bucket: int) -> int:
        s_alloc = bucket + self.op_reserve
        impl = getattr(self.model.rt, "attn_impl", "")
        if impl.startswith("pallas"):
            # keep the decode kernel's cache axis a block multiple so
            # ops.decode_attention never pads K/V copies per step
            blk = getattr(self.model.rt, "block_kv", 512)
            if s_alloc > blk:           # <= blk is always a single block
                s_alloc = -(-s_alloc // blk) * blk
        return s_alloc

    def _arena(self, bucket: int) -> BucketArena:
        ar = self._arenas.get(bucket)
        if ar is None:
            ar = BucketArena(self.model, bucket, self._s_alloc_for(bucket),
                             capacity=self._initial_capacity(bucket))
            self._arenas[bucket] = ar
        return ar

    def _slot_for(self, bucket: int, doc_id: int, arena: BucketArena) -> int:
        prev = self._doc_slot.get(doc_id)
        assert prev is None or prev[0] == bucket, \
            f"doc {doc_id} already staged in bucket {prev[0]}, got {bucket}"
        slot = self._alloc.peek(bucket, doc_id)
        if slot < 0:
            slot = self._alloc.slot_of(bucket, doc_id)
            arena.ensure_capacity(self._alloc.high_water(bucket))
            arena.clear_slot(slot)
            self._doc_slot[doc_id] = (bucket, slot)
        return slot

    # --------------------------------------------------------------- compute
    def uses_paged_kv(self) -> bool:
        """Resolve the ``paged`` switch (None = auto): the paged stage step
        needs a paged-capable model and pays off when the kernels resolve
        slots in-kernel, i.e. on Pallas runtimes."""
        if self.paged is None:
            impl = getattr(getattr(self.model, "rt", None), "attn_impl", "")
            self.paged = bool(
                impl.startswith("pallas")
                and getattr(self.model, "supports_paged_kv", False))
        if self.paged:
            assert getattr(self.model, "supports_paged_kv", False), \
                "paged=True requires a model whose serve-state is all " \
                "full-attention KV caches (LM.supports_paged_kv)"
        return self.paged

    def _build_step(self):
        model = self.model

        def gather_step(params, arena_states, slots, new_tok, op_tok,
                        kv_true, ext_true, *, c_len: int, op_len: int):
            st = model.take_states(arena_states, slots)
            if new_tok.shape[1] > 0:
                # prefill (c_len == 0) / fraction-extend into the arena;
                # ext_true = per-row REAL extent of cache + chunk, so
                # bucket-PAD keys are invisible inside the chunk too
                _, st = model.extend(params, {"tokens": new_tok}, st,
                                     q_offset=c_len, kv_len=ext_true)
                arena_states = model.put_states(arena_states, slots, st)
            # operation suffix: masked decode steps over the gathered COPY
            # (kv_true = per-doc TRUE prefix length -> pad KV is invisible;
            # the doc snapshot in the arena survives untouched)
            logits = None
            pos = kv_true.astype(jnp.int32)
            B = slots.shape[0]
            for t in range(op_len):
                tok = jnp.broadcast_to(op_tok[t], (B,))
                logits, st = model.decode_step(params, tok, st, pos + t)
            return logits, arena_states

        def paged_step(params, arena_states, slots, new_tok, op_tok,
                       kv_true, ext_true, *, c_len: int, op_len: int):
            # PAGED data plane: the arena is never row-copied.  The extend
            # scatters only the chunk's KV into the addressed rows and the
            # kernels DMA arena blocks through slot ids in scalar-prefetch
            # SMEM, so per-launch HBM traffic is the attended blocks — not
            # a [B, s_alloc] gather + scatter of whole rows.
            if new_tok.shape[1] > 0:
                _, arena_states = model.extend(
                    params, {"tokens": new_tok}, arena_states,
                    q_offset=c_len, kv_len=ext_true, slots=slots)
            # operation suffix: masked decode steps run IN PLACE over the
            # arena.  The op tokens' KV lands at [kv_true, kv_true+op_len)
            # of each row — positions that may hold live document KV (the
            # true fraction can undershoot the padded cache) — so the
            # window is snapshotted first and restored after: an O(B *
            # op_len) undo log instead of an O(B * s_alloc) row copy, and
            # the arena leaves the step bitwise identical to the gather
            # path's.
            logits = None
            pos = kv_true.astype(jnp.int32)
            B = slots.shape[0]
            saved = model.take_kv_window(arena_states, slots, pos, op_len)
            for t in range(op_len):
                tok = jnp.broadcast_to(op_tok[t], (B,))
                logits, arena_states = model.decode_step(
                    params, tok, arena_states, pos + t, slots=slots)
            arena_states = model.put_kv_window(arena_states, slots, pos,
                                               op_len, saved)
            return logits, arena_states

        step = paged_step if self.uses_paged_kv() else gather_step
        kwargs: Dict[str, Any] = {"static_argnames": ("c_len", "op_len")}
        if jax.default_backend() != "cpu":      # CPU donation only warns
            kwargs["donate_argnums"] = (1,)
        return jax.jit(step, **kwargs)

    # ----------------------------------------------------- paged accounting
    def gather_bytes_per_launch(self, bucket: int, batch: int) -> int:
        """Device bytes the GATHER stage step copies per launch just to
        address the arena: ``take_states`` materializes a [batch, s_alloc]
        row copy of every state leaf (and extend scatters it back).
        Decode-only launches pay this too.  The paged step eliminates it."""
        return batch * self.slot_nbytes(bucket)

    def paged_copy_bytes_per_launch(self, bucket: int, batch: int,
                                    op_len: int) -> int:
        """Bytes the PAGED stage step copies per launch: the op-suffix
        undo log (save + restore of the ``op_len`` dirtied cache rows).
        Zero bytes scale with the cache/bucket size — the arena itself is
        read in place by the kernels."""
        s_alloc = self._s_alloc_for(bucket)
        row = self.slot_nbytes(bucket)
        return 2 * batch * op_len * (row // s_alloc)

    def class_confidences(self, logits: jnp.ndarray, n_classes: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Softmax over the class answer tokens -> (pred, conf)."""
        toks = [class_token(c) for c in range(n_classes)]
        cls_logits = np.asarray(logits, np.float64)[:, toks]
        z = cls_logits - cls_logits.max(axis=1, keepdims=True)
        probs = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
        return probs.argmax(axis=1), probs.max(axis=1)

    def run_stage(
        self,
        doc_ids: Sequence[int],
        doc_tokens: Mapping[int, np.ndarray],
        bucket: int,                             # padded full-doc length
        fraction: float,
        op_tokens: np.ndarray,
        n_classes: int,
    ) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """Run (op, fraction) over one bucket batch (stage-synchronous API).

        Documents may carry heterogeneous cached prefixes: the batch is
        split into per-``cached_len`` launches (each reusing its cache)
        rather than re-prefilling everyone.  Returns (pred [B], conf [B],
        new_tokens, cached_tokens) with TRUE (unpadded) token counts for $
        accounting.  The request loop calls ``run_group`` directly (the
        scheduler has already grouped by cached length).
        """
        B = len(doc_ids)
        f_len = fraction_len(bucket, fraction)
        pred = np.zeros(B, np.int64)
        conf = np.zeros(B, np.float64)
        pos_of = {d: i for i, d in enumerate(doc_ids)}
        new_true_total = 0
        cached_true_total = 0

        groups: Dict[int, List[int]] = {}
        for d in doc_ids:
            eff_c = min(self.cached_len(d), f_len)
            groups.setdefault(eff_c, []).append(d)

        for eff_c in sorted(groups):
            ids = groups[eff_c]
            p, c, new_d, cached_d = self.run_group(
                ids, doc_tokens, bucket, f_len, fraction, eff_c,
                op_tokens, n_classes)
            for j, d in enumerate(ids):
                pred[pos_of[d]] = p[j]
                conf[pos_of[d]] = c[j]
            new_true_total += int(new_d.sum())
            cached_true_total += int(cached_d.sum())
        return pred, conf, new_true_total, cached_true_total

    def run_group(self, ids, doc_tokens, bucket, f_len, fraction, eff_c,
                  op_tokens, n_classes):
        """One static-signature launch: all ``ids`` share ``eff_c``.

        Returns (pred [B], conf [B], new_tokens [B], cached_tokens [B])
        with PER-DOCUMENT true token counts, so the request loop can
        attribute cost to each document's own stage and query even when a
        launch mixes stages or registered queries.
        """
        assert len(op_tokens) > 0, "operations must encode to >= 1 token"
        assert len(op_tokens) <= self.op_reserve, \
            f"operation longer than op_reserve ({len(op_tokens)})"
        t0 = time.perf_counter()
        arena = self._arena(bucket)
        slots = [self._slot_for(bucket, d, arena) for d in ids]
        B = len(ids)
        Bp = _pad_width(B)
        n_new = f_len - eff_c                     # 0 => decode-only launch
        op_len = len(op_tokens)

        slots_arr = np.full(Bp, arena.scratch_slot, np.int32)
        slots_arr[:B] = slots
        new_tok = np.full((Bp, n_new), PAD, np.int32)
        kv_true = np.ones(Bp, np.int32)
        ext_true = np.ones(Bp, np.int32)
        new_d = np.zeros(B, np.int64)
        cached_d = np.zeros(B, np.int64)
        for i, d in enumerate(ids):
            toks = doc_tokens[d]
            slot = slots[i]
            if n_new > 0:
                seg = toks[min(eff_c, len(toks)): min(f_len, len(toks))]
                new_tok[i, : len(seg)] = seg
                new_d[i] = len(seg)
                cached_d[i] = min(eff_c, len(toks))
                ext_true[i] = min(eff_c, len(toks)) + len(seg)
            else:
                cached_d[i] = min(int(arena.true_len[slot]),
                                  self._true_len(toks, fraction))
            kv_true[i] = self._true_len(toks, fraction)
        self.host_overhead_s += time.perf_counter() - t0

        if self._step is None:
            self._step = self._build_step()
        t0 = time.perf_counter()
        logits, new_states = self._step(
            self.params, arena.states, jnp.asarray(slots_arr),
            jnp.asarray(new_tok), jnp.asarray(op_tokens, jnp.int32),
            jnp.asarray(kv_true), jnp.asarray(ext_true),
            c_len=eff_c, op_len=op_len)
        arena.states = new_states
        self.host_overhead_s += time.perf_counter() - t0   # async dispatch

        if n_new > 0:
            for i, d in enumerate(ids):
                slot = slots[i]
                arena.cached_len[slot] = f_len
                arena.true_len[slot] = min(f_len, len(doc_tokens[d]))
        pred, conf = self.class_confidences(
            np.asarray(logits)[:B], n_classes)
        return pred, conf, new_d + op_len, cached_d

    @staticmethod
    def _true_len(toks: np.ndarray, fraction: float) -> int:
        return max(int(math.ceil(len(toks) * fraction)), 1)


@dataclass
class EngineResult:
    pred: Dict[int, int]
    conf: Dict[int, float]
    exit_stage: Dict[int, int]
    cost: float
    stats: ServeStats
    stage_cost: List[float] = field(default_factory=list)
    doc_cost: Dict[int, float] = field(default_factory=dict)


# stage-table entry: (model, op_id, fraction, threshold_vector-or-None)
_StageEntry = Tuple[str, str, float, Optional[np.ndarray]]


@dataclass
class DocFuture:
    """Resolution handle for one submitted document.

    ``handle.submit`` returns one; it stays live until the server resolves
    the document (``done``), after which ``pred``/``conf``/``exit_stage``/
    ``cost`` are populated.  ``result()`` steps the server until this
    document resolves (other queries' work is served along the way — the
    future never bypasses the scheduler).
    """

    query_id: int
    doc_id: int                       # the CALLER's id (ext_id)
    _req: DocRequest = field(repr=False)
    _server: "CascadeServer" = field(repr=False)

    @property
    def done(self) -> bool:
        return self._req.done

    @property
    def pred(self) -> Optional[int]:
        return self._req.pred

    @property
    def conf(self) -> Optional[float]:
        return self._req.conf

    @property
    def exit_stage(self) -> Optional[int]:
        return self._req.exit_stage

    @property
    def cost(self) -> float:
        return self._req.cost

    @property
    def evictions(self) -> int:
        return self._req.evictions

    def result(self) -> Tuple[int, float, int]:
        """Block (stepping the server) until resolved: (pred, conf, stage)."""
        while not self._req.done:
            assert self._server.pending(), \
                "server idle before this document resolved"
            self._server.step()
        return self._req.pred, self._req.conf, self._req.exit_stage


@dataclass
class QueryHandle:
    """One registered query's view of a ``CascadeServer``.

    Returned by ``server.register(cascade, ...)``.  ``submit`` admits
    documents into the SHARED request queue (they may merge into launches
    with other queries' documents); ``poll``/``result``/``stats``/``cost``
    are partitioned to this query.  ``accuracy_target`` is the caller's
    declared accuracy floor (the alpha the cascade was assembled for) —
    recorded for admission/monitoring; the thresholds baked into the
    cascade are what enforce it.
    """

    query_id: int
    stages: List[_StageEntry] = field(repr=False)
    _server: "CascadeServer" = field(repr=False)
    accuracy_target: Optional[float] = None

    def stage_config(self, stage: int) -> StageConfig:
        model, op_id, fraction, _ = self.stages[stage]
        return model, op_id, fraction

    def submit(self, doc_id: int, text: str,
               arrival: Optional[float] = None, stage: int = 0,
               arrival_ts: Optional[float] = None) -> DocFuture:
        """Admit a document into this query (streaming arrival).

        ``arrival`` is the scheduling priority — any comparable float
        (logical sequence numbers are fine); lower runs first, ACROSS
        queries.  ``arrival_ts`` is an absolute ``time.perf_counter()``
        timestamp anchoring the latency measurement — streaming drivers
        pass the SCHEDULED arrival so pre-submit queueing counts; it
        defaults to submit time.  ``arrival`` defaults to ``arrival_ts``
        so priority follows real arrival order when only timestamps are
        given.  ``stage`` lets pre-screened documents enter the cascade
        mid-way (clamped to the oracle).  Document ids are scoped to the
        query: two queries may both submit a document ``7``.
        """
        return self._server._submit(self, doc_id, text, arrival=arrival,
                                    stage=stage, arrival_ts=arrival_ts)

    def pending(self) -> int:
        """This query's documents admitted but not yet resolved."""
        return self._server.pending(self.query_id)

    def poll(self) -> Dict[int, Tuple[int, float, int]]:
        """This query's results resolved since the last poll:
        doc -> (pred, conf, exit_stage)."""
        return self._server._poll_query(self.query_id)

    def result(self) -> EngineResult:
        """Everything this query has resolved so far (per-query stats/$)."""
        return self._server.result(self.query_id)

    def drain(self) -> EngineResult:
        """Step the server until THIS query is idle (other queries' work
        is served along the way), then return its result."""
        while self.pending():
            self._server.step()
        return self.result()

    @property
    def stats(self) -> ServeStats:
        return self._server.stats(self.query_id)

    @property
    def cost(self) -> float:
        return self._server.cost(self.query_id)


@dataclass
class CascadeServer:
    """Long-lived multi-tenant executor of task cascades over shared
    backends.

    ``register`` / ``handle.submit`` / ``step`` / ``poll`` / ``drain`` is
    the serving API; the server owns the backends, their KV arenas, and
    one global request queue, and serves every registered query
    concurrently.  See the module docstring for the scheduling contract.
    """

    backends: Dict[str, Any]                # "proxy"/"oracle" -> backend
    operations: Dict[str, str]              # op id -> operation text
    n_classes: int
    batch_size: int = 8
    policy: Optional[SchedulingPolicy] = None   # None = oldest_head_first
    _op_tok_cache: Dict[Tuple[str, str], np.ndarray] = field(
        default_factory=dict, repr=False)
    # ---- serving state (shared queue; per-query partitions keyed by qid)
    _handles: Dict[int, QueryHandle] = field(default_factory=dict, repr=False)
    _queue: RequestQueue = field(default_factory=RequestQueue, repr=False)
    _requests: Dict[int, DocRequest] = field(default_factory=dict, repr=False)
    _ids: Dict[Tuple[int, int], int] = field(default_factory=dict, repr=False)
    _tok: Dict[str, Dict[int, np.ndarray]] = field(
        default_factory=dict, repr=False)
    _query_stats: Dict[int, ServeStats] = field(
        default_factory=dict, repr=False)
    _departed: ServeStats = field(default_factory=ServeStats, repr=False)
    _query_cost: Dict[int, float] = field(default_factory=dict, repr=False)
    _fresh: Dict[int, List[int]] = field(default_factory=dict, repr=False)
    _pending: Dict[int, int] = field(default_factory=dict, repr=False)
    _launches: int = field(default=0, repr=False)
    _retired: int = field(default=0, repr=False)
    _seq: int = field(default=0, repr=False)
    _next_qid: int = field(default=0, repr=False)

    def __post_init__(self) -> None:
        if not self._tok:
            self._tok = {m: {} for m in self.backends}

    def _op_tokens(self, backend, op_id: str) -> np.ndarray:
        key = (backend.name, op_id)
        toks = self._op_tok_cache.get(key)
        if toks is None:
            toks = np.asarray(
                backend.tokenizer.encode(self.operations[op_id]), np.int32)
            self._op_tok_cache[key] = toks
        return toks

    # ------------------------------------------------------------- lifecycle
    def reset(self) -> None:
        """Drop every query and in-flight request; reset backends/arenas.

        Compiled stage steps and op-token memos survive (they close over
        models and operation text only).
        """
        for be in self.backends.values():
            be.reset()
        self._queue.clear()
        self._handles.clear()
        self._requests.clear()
        self._ids.clear()
        self._tok = {m: {} for m in self.backends}
        self._query_stats.clear()
        self._departed = ServeStats()
        self._query_cost.clear()
        self._fresh.clear()
        self._pending.clear()
        self._launches = 0
        self._retired = 0
        self._seq = 0
        self._next_qid = 0

    def register(self, cascade: Cascade,
                 accuracy_target: Optional[float] = None,
                 oracle_model: str = "oracle",
                 oracle_op: str = "o_orig") -> QueryHandle:
        """Register a query (cascade) for serving; returns its handle.

        Backends and arenas are NOT reset — registration is cheap and
        concurrent queries share the serving substrate.  The oracle
        fall-through (``oracle_model``, ``oracle_op``, f=1, no
        thresholds) is appended so every submitted document resolves.
        """
        qid = self._next_qid
        self._next_qid += 1
        handle = QueryHandle(
            query_id=qid,
            stages=cascade.stage_entries(self.n_classes, oracle_model,
                                         oracle_op),
            _server=self, accuracy_target=accuracy_target)
        self._handles[qid] = handle
        self._query_stats[qid] = ServeStats()
        self._query_cost[qid] = 0.0
        self._fresh[qid] = []
        self._pending[qid] = 0
        return handle

    def unregister(self, handle: QueryHandle) -> None:
        """Withdraw a query and free its bookkeeping (results included —
        read ``handle.result()`` first).  Asserts the query is idle:
        drain it before unregistering.  The query's contribution to the
        server-wide aggregate (``stats()``/``occupancy()``) is retained —
        launch history does not shrink when a tenant departs."""
        qid = handle.query_id
        assert self._pending.get(qid, 0) == 0, \
            "unregister with documents pending; drain the query first"
        gone = self._query_stats.get(qid)
        if gone is not None:
            self._merge_stats(self._departed, gone)
        self._handles.pop(qid, None)
        self._query_stats.pop(qid, None)
        self._query_cost.pop(qid, None)
        self._fresh.pop(qid, None)
        self._pending.pop(qid, None)
        for (q, d), rid in list(self._ids.items()):
            if q == qid:
                del self._ids[(q, d)]
                self._requests.pop(rid, None)
                for tok in self._tok.values():
                    tok.pop(rid, None)

    def _submit(self, handle: QueryHandle, doc_id: int, text: str,
                arrival: Optional[float] = None, stage: int = 0,
                arrival_ts: Optional[float] = None) -> DocFuture:
        qid = handle.query_id
        assert self._handles.get(qid) is handle, \
            "handle is not registered with this server"
        key = (qid, doc_id)
        assert key not in self._ids, \
            f"doc {doc_id} already submitted to query {qid}"
        if arrival_ts is None:
            arrival_ts = time.perf_counter()
        if arrival is None:
            arrival = arrival_ts
        rid = self._seq                   # server-global request id == seq
        self._seq += 1
        req = DocRequest(
            doc_id=rid, query_id=qid, ext_id=doc_id,
            stage=min(max(int(stage), 0), len(handle.stages) - 1),
            arrival=arrival, seq=rid, arrival_ts=arrival_ts)
        enc: Dict[int, np.ndarray] = {}     # backends often share a tokenizer
        for m, be in self.backends.items():
            ids = enc.get(id(be.tokenizer))
            if ids is None:
                ids = np.asarray(be.tokenizer.encode(text), np.int32)
                enc[id(be.tokenizer)] = ids
            self._tok[m][rid] = ids
            req.tok_len[m] = len(ids)
        self._requests[rid] = req
        self._ids[key] = rid
        self._pending[qid] += 1
        self._queue.push(req)
        return DocFuture(query_id=qid, doc_id=doc_id, _req=req, _server=self)

    def pending(self, query_id: Optional[int] = None) -> int:
        """Documents admitted but not yet resolved (one query, or all)."""
        if query_id is None:
            return len(self._queue)
        return self._pending.get(query_id, 0)

    # ------------------------------------------------------------ scheduling
    def _stage_of(self, req: DocRequest) -> StageConfig:
        """Resolve a request's current stage through its owning query."""
        return self._handles[req.query_id].stage_config(req.stage)

    def _victim_order(self, be, protected: Set[int]) -> List[int]:
        """Eviction priority, lowest first: fewest-cached-tokens-lost,
        newest arrival breaking ties (two stable sorts, reversed-arrival
        first)."""
        victims = sorted(
            (d for d in be.live_docs() if d not in protected),
            key=lambda d: self._requests[d].key(), reverse=True)
        victims.sort(key=be.true_cached_len)
        return victims

    def _make_room(self, be, launch: LaunchSpec) -> LaunchSpec:
        """Enforce the backend's slot/byte budgets for one launch.

        First preempts live slots outside the launch (fewest cached
        tokens lost first); if the budgets still cannot host every new
        allocation, the newest tail of the launch is deferred back to the
        queue (at least one document always proceeds).
        """
        if (getattr(be, "slot_budget", None) is None
                and getattr(be, "byte_budget", None) is None):
            return launch
        need = sum(1 for d in launch.doc_ids if not be.has_slot(d))
        if not be.over_budget(launch.bucket, need):
            return launch
        victims = self._victim_order(be, set(launch.doc_ids))
        for d in be.evict_for_room(launch.bucket, need, victims):
            req = self._requests[d]
            req.cached[be.name] = 0
            req.evictions += 1
            self._query_stats[req.query_id].evictions += 1
        retired = getattr(be, "pressure_retired", 0)
        if retired:
            be.pressure_retired = 0
            self._note_retired(retired)
        room = be.admissible_new(launch.bucket, need)
        if need <= room:
            return launch
        # trim: keep the oldest prefix whose new allocations fit (>= 1 doc)
        keep_ids: List[int] = []
        keep_stages: List[int] = []
        used = 0
        for d, s in zip(launch.doc_ids, launch.stages):
            cost = 0 if be.has_slot(d) else 1
            if keep_ids and used + cost > room:
                self._queue.push(self._requests[d])  # defer to a later launch
                continue
            keep_ids.append(d)
            keep_stages.append(s)
            used += cost
        return LaunchSpec(
            model=launch.model, op_id=launch.op_id, fraction=launch.fraction,
            bucket=launch.bucket, cached_len=launch.cached_len,
            f_len=launch.f_len, doc_ids=tuple(keep_ids),
            stages=tuple(keep_stages))

    def _note_retired(self, n: int) -> None:
        # arenas are shared: retirement is a server-wide memory event,
        # mirrored into every query's stats (aggregate counts it once)
        self._retired += n
        for st in self._query_stats.values():
            st.retired_buckets += n

    def step(self) -> List[Tuple[int, int]]:
        """Dispatch one launch from the shared ready queue.

        The launch may mix documents from several registered queries
        (same static signature).  Returns the ``(query_id, doc_id)``
        pairs resolved by this step (may be empty).  No-op when idle.
        """
        launch = self._queue.next_launch(self._stage_of, self.batch_size,
                                         policy=self.policy)
        if launch is None:
            return []
        be = self.backends[launch.model]
        launch = self._make_room(be, launch)
        ids = list(launch.doc_ids)
        p, c, new_d, cached_d = be.run_group(
            ids, self._tok[launch.model], launch.bucket, launch.f_len,
            launch.fraction, launch.cached_len,
            self._op_tokens(be, launch.op_id), self.n_classes)
        now = time.perf_counter()
        resolved: List[Tuple[int, int]] = []
        touched: Dict[int, None] = {}           # queries in this launch
        for i, rid in enumerate(ids):
            req = self._requests[rid]
            qid = req.query_id
            touched[qid] = None
            stats = self._query_stats[qid]
            thr = self._handles[qid].stages[req.stage][3]
            cost_d = (new_d[i] * be.rate_per_token
                      + cached_d[i] * be.rate_per_token * be.cached_discount)
            stats.record(req.stage, 1, int(new_d[i]), int(cached_d[i]),
                         cost_d)
            self._query_cost[qid] += cost_d
            req.cost += cost_d
            req.cached[be.name] = be.cached_len(rid)
            if thr is None or c[i] >= thr[p[i]]:
                req.done = True
                req.pred = int(p[i])
                req.conf = float(c[i])
                req.exit_stage = req.stage
                for b in self.backends.values():
                    if hasattr(b, "release"):
                        b.release(rid)
                for tok in self._tok.values():
                    tok.pop(rid, None)
                stats.latencies.append(max(now - req.arrival_ts, 0.0))
                self._fresh[qid].append(rid)
                self._pending[qid] -= 1
                resolved.append((qid, req.ext_id))
            else:
                req.stage += 1
                self._queue.push(req)
        self._launches += 1
        for qid in touched:       # a query's ``batches`` = launches it rode
            self._query_stats[qid].batches += 1
        # retirement ticks on EVERY backend: one that stops receiving
        # launches must still free arenas its drifted length mix pinned
        retired = sum(b.note_launch() for b in self.backends.values()
                      if hasattr(b, "note_launch"))
        if retired:
            self._note_retired(retired)
        return resolved

    # --------------------------------------------------------------- results
    def _poll_query(self, query_id: int) -> Dict[int, Tuple[int, float, int]]:
        out = {}
        for rid in self._fresh.get(query_id, []):
            req = self._requests[rid]
            out[req.ext_id] = (req.pred, req.conf, req.exit_stage)
        self._fresh[query_id] = []
        return out

    def poll(self) -> Dict[Tuple[int, int], Tuple[int, float, int]]:
        """Server-wide results resolved since the last poll:
        (query_id, doc_id) -> (pred, conf, exit_stage)."""
        out = {}
        for qid in list(self._fresh):
            for d, v in self._poll_query(qid).items():
                out[(qid, d)] = v
        return out

    def cost(self, query_id: int) -> float:
        """Accumulated $ of one query."""
        return self._query_cost[query_id]

    def stats(self, query_id: Optional[int] = None) -> ServeStats:
        """Per-query stats, or the server-wide aggregate (query_id=None).

        Aggregation counts each launch ONCE however many queries shared
        it (``batches`` = server launches), sums stage vectors by index,
        and concatenates latencies.  A query's own ``batches`` counts the
        launches that carried at least one of its documents, so per-query
        batches can sum to more than the aggregate — that overlap is the
        multi-tenant packing win.
        """
        if query_id is not None:
            return self._query_stats[query_id]
        agg = ServeStats()
        for st in [self._departed, *self._query_stats.values()]:
            self._merge_stats(agg, st)
        agg.batches = self._launches
        agg.retired_buckets = self._retired
        return agg

    @staticmethod
    def _merge_stats(dst: ServeStats, src: ServeStats) -> None:
        """Fold one query's stage vectors/evictions/latencies into
        ``dst`` (launch counters are NOT summed — launches are shared)."""
        for s in range(len(src.stage_docs)):
            dst.record(s, src.stage_docs[s], src.stage_new_tokens[s],
                       src.stage_cached_tokens[s], src.stage_cost[s])
        dst.evictions += src.evictions
        dst.latencies.extend(src.latencies)

    def occupancy(self) -> float:
        """Mean documents per launch across every query the server has
        served — departed queries included (the packing metric: higher
        than any single query could reach alone means cross-query
        launches are being merged)."""
        docs = sum(sum(st.stage_docs)
                   for st in [self._departed, *self._query_stats.values()])
        return docs / self._launches if self._launches else 0.0

    def result(self, query_id: int) -> EngineResult:
        """One query's resolved documents (keyed by the caller's doc ids),
        with per-query cost/stats and deterministic per-document $."""
        done = [r for r in self._requests.values()
                if r.done and r.query_id == query_id]
        stats = self._query_stats[query_id]
        return EngineResult(
            pred={r.ext_id: r.pred for r in done},
            conf={r.ext_id: r.conf for r in done},
            exit_stage={r.ext_id: r.exit_stage for r in done},
            cost=self._query_cost[query_id], stats=stats,
            stage_cost=list(stats.stage_cost),
            doc_cost={r.ext_id: r.cost for r in done})

    def drain(self) -> Dict[int, EngineResult]:
        """Step until the shared queue is idle; per-query results."""
        while self.pending():
            self.step()
        return {qid: self.result(qid) for qid in self._handles}


@dataclass
class CascadeEngine(CascadeServer):
    """Single-query compatibility wrapper over ``CascadeServer``.

    ``start(cascade)`` resets the server session and registers exactly one
    query; ``submit/step/poll/drain/result`` operate on it with the
    pre-server signatures, and ``run()`` (submit everything + drain) is
    bit-identical — preds, confs, per-document $ — to the single-tenant
    engine on static corpora: one registered query produces exactly the
    same launch sequence through the shared queue.
    """

    _handle: Optional[QueryHandle] = field(default=None, repr=False)

    # single-query views used by tests/tools (the server partitions these)
    @property
    def _reqs(self) -> Dict[int, DocRequest]:
        qid = self._handle.query_id
        return {r.ext_id: r for r in self._requests.values()
                if r.query_id == qid}

    @property
    def _stats(self) -> ServeStats:
        return self._query_stats[self._handle.query_id]

    # ------------------------------------------------------------- lifecycle
    def start(self, cascade: Cascade, oracle_model: str = "oracle") -> None:
        """Begin a single-query serving session: reset backends, clear the
        queue, register the cascade."""
        self.reset()
        self._handle = self.register(cascade, oracle_model=oracle_model)

    def submit(self, doc_id: int, text: str,
               arrival: Optional[float] = None, stage: int = 0,
               arrival_ts: Optional[float] = None) -> DocFuture:
        """Admit a document into the session (see ``QueryHandle.submit``)."""
        assert self._handle is not None, "call start(cascade) before submit()"
        return self._handle.submit(doc_id, text, arrival=arrival,
                                   stage=stage, arrival_ts=arrival_ts)

    def step(self) -> List[int]:
        """Dispatch one launch; returns the doc ids resolved by it."""
        assert self._handle is not None, "call start(cascade) before step()"
        return [d for _, d in super().step()]

    def poll(self) -> Dict[int, Tuple[int, float, int]]:
        """Results resolved since the last poll: doc -> (pred, conf, stage)."""
        return self._handle.poll()

    def result(self, query_id: Optional[int] = None) -> EngineResult:
        if query_id is None:
            query_id = self._handle.query_id
        return super().result(query_id)

    def drain(self) -> EngineResult:
        """Step until the queue is idle; result covers the whole session."""
        while self.pending():
            CascadeServer.step(self)
        return self.result()

    # -------------------------------------------------------- batch wrapper
    def run(self, cascade: Cascade, docs: Mapping[int, str],
            oracle_model: str = "oracle",
            enter_stage: Optional[Mapping[int, int]] = None) -> EngineResult:
        """docs: doc_id -> (already reordered) document text.

        Thin batch wrapper over the request loop: submit every document,
        drain the queue.  ``enter_stage`` (doc_id -> stage index) admits
        documents mid-cascade; stage indices are clamped to the oracle
        stage, so every admitted document resolves.
        """
        requested = dict(enter_stage or {})
        for d in requested:
            if d not in docs:
                raise KeyError(f"enter_stage doc {d!r} not in docs")
        self.start(cascade, oracle_model)
        for d, text in docs.items():
            self.submit(d, text, stage=requested.get(d, 0))
        return self.drain()
