"""Cascade execution engine: a continuous-batching request loop over real
JAX models (slot-arena data plane).

This is the data-plane twin of ``core.cost_model``: the paper's API prompt
caching becomes PHYSICAL KV-prefix reuse.  Documents ride *before*
operations in the token stream, so

  * extending a document from fraction f_j to f_i > f_j runs the model's
    ``extend`` path over only the new suffix (cached doc-prefix KV reused);
  * switching operations on the same model at the same fraction re-runs
    ONLY the operation tokens against the cached document KV;
  * the engine never merges operation tokens into the cached document
    state (op suffixes decode against a gathered *copy* of the slot states
    and are dropped), exactly mirroring the doc-before-op prompt layout.

Request loop
------------
The control plane is *continuous-batching*, not stage-synchronous:

    engine.start(cascade)                  begin a serving session
    engine.submit(doc_id, text, arrival)   admit a document (any time)
    engine.step()                          dispatch ONE launch
    engine.poll()                          collect newly resolved documents
    engine.drain()                         step until idle -> EngineResult

Every submitted document becomes a ``scheduler.DocRequest`` (stage cursor,
arrival time, per-backend cached lengths, resolution status) in a single
global ``scheduler.RequestQueue``.  ``step()`` pops the ready group whose
head request is oldest — grouped by the static signature ``(backend,
bucket, cached_len, op, f_len)`` across ALL stages — so a stage-0 prefill
for a fresh arrival and a stage-2 decode-only launch for a veteran
dispatch back-to-back without either cohort draining first.  Thresholds
are applied per document against its own stage; survivors re-enter the
queue with an advanced cursor.  ``run()`` is a thin batch wrapper:
submit-everything + drain, with identical ``EngineResult`` semantics and
$-accounting parity with ``core.cost_model``.

Arena layout, slot lifecycle & memory control
---------------------------------------------
Per (backend, length bucket) the engine keeps one persistent
``arena.BucketArena``: a batched state pytree ``[n_slots + 1, ...,
s_alloc, ...]`` (s_alloc = bucket + operation reserve; the extra row is
scratch for batch padding).  A document is assigned a slot on first touch
and keeps it until it exits the cascade — unless the backend's
``slot_budget`` is hit, in which case the lowest-priority (newest-arrival)
live slot is PREEMPTED: its document re-enters the queue at its current
stage with ``cached_len = 0`` and re-prefills as new tokens.  Buckets
whose live-slot count stays zero for ``retire_after`` launches are retired
(device arena freed), so a drifting length mix does not pin memory.
Survivor compaction is an index gather (``LM.take_states``) and a scatter
back (``LM.put_states``) inside one jitted step — no per-document pytree
stacking/slicing on the host.

Stage steps compile once per static signature ``(bucket, cached_len,
new_len, op_len, batch)`` — note: no stage index, so interleaved stages
share compiled steps.  Prefill-into-arena is the ``cached_len == 0`` case
of extend, fraction extension writes the suffix at a static offset with
per-row true lengths masking bucket PAD out of the chunk
(``kernels/flash_attention.py`` scalar-prefetch ``kv_len``), and the
operation suffix runs as masked decode steps whose per-document ``kv_len``
rides through ``kernels/decode_attention.py``.

Token accounting (new vs cached, true unpadded counts), per-stage $ cost,
per-document latencies, evictions, and retired buckets are recorded in
``ServeStats`` with the same rates as the analytical cost model, so engine
costs are directly comparable to ``run_cascade`` in tests.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tasks import Cascade
from ..data.tokenizer import PAD, HashWordTokenizer, class_token
from .arena import BucketArena
from .scheduler import (DocRequest, LaunchSpec, RequestQueue, ServeStats,
                        SlotAllocator, fraction_len)


def _pad_width(n: int) -> int:
    """Static launch width: next power of two (few compiled batch shapes)."""
    return 1 << max(n - 1, 0).bit_length()


@dataclass
class LMBackend:
    """A model + params behind the engine, with a slot-based KV arena."""

    name: str
    model: Any                       # models.model.LM (or compatible)
    params: Any
    tokenizer: HashWordTokenizer
    rate_per_token: float = 1.0      # $ parity with the analytical model
    cached_discount: float = 0.5
    # NOTE: arenas size per-slot allocation as bucket + op_reserve (rounded
    # to a decode block on pallas runtimes); ``s_alloc`` is kept for seed
    # API compatibility and no longer bounds arena memory.
    s_alloc: int = 4096
    op_reserve: int = 64             # suffix headroom past the bucket length
    init_slots: int = 8              # initial arena capacity per bucket
    slot_budget: Optional[int] = None  # max live slots across buckets
    retire_after: int = 64           # idle launches before bucket retirement
    _arenas: Dict[int, BucketArena] = field(default_factory=dict)
    _alloc: SlotAllocator = field(default_factory=SlotAllocator)
    _doc_slot: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    _idle: Dict[int, int] = field(default_factory=dict)
    _step: Optional[Any] = None      # jitted stage step (lazy)
    host_overhead_s: float = 0.0     # pack/assembly/dispatch wall-clock

    def reset(self) -> None:
        self._arenas.clear()
        self._alloc.reset()
        self._doc_slot.clear()
        self._idle.clear()
        self.host_overhead_s = 0.0
        # the jitted step closes over model only; its compile cache survives

    # ------------------------------------------------------------ slot admin
    def cached_len(self, doc_id: int) -> int:
        """Padded cached-prefix length of ``doc_id`` (0 when uncached)."""
        bs = self._doc_slot.get(doc_id)
        if bs is None:
            return 0
        bucket, slot = bs
        return int(self._arenas[bucket].cached_len[slot])

    def has_slot(self, doc_id: int) -> bool:
        return doc_id in self._doc_slot

    def live_slots(self) -> int:
        return len(self._doc_slot)

    def live_docs(self) -> List[int]:
        return list(self._doc_slot)

    def release(self, doc_id: int) -> None:
        """Free the document's slot (it exited the cascade or was evicted)."""
        bs = self._doc_slot.pop(doc_id, None)
        if bs is not None:
            self._alloc.release(bs[0], doc_id)

    # ------------------------------------------------------- memory control
    def arena_nbytes(self) -> int:
        """Total device bytes pinned by this backend's arenas."""
        return sum(ar.nbytes() for ar in self._arenas.values())

    def evict_for_room(self, need_new: int, victims: Sequence[int]
                       ) -> List[int]:
        """Preempt slots until ``need_new`` allocations fit in the budget.

        ``victims`` is the caller's priority order, lowest first (the
        engine passes newest-arrival-first and excludes the launch being
        packed).  Returns the evicted doc ids; the caller re-queues them
        with ``cached_len = 0``.  Stops early when the victim list runs
        out — the launch is then trimmed by the engine rather than
        over-committing the arena.
        """
        evicted: List[int] = []
        if self.slot_budget is None:
            return evicted
        for d in victims:
            if self.live_slots() + need_new <= self.slot_budget:
                break
            if d in self._doc_slot:
                self.release(d)
                evicted.append(d)
        return evicted

    def note_launch(self) -> int:
        """Bucket retirement hook, called once per engine step (on every
        backend, so one that stops receiving launches still ticks).

        A bucket whose live-slot count has been zero for ``retire_after``
        consecutive ticks has drifted out of the workload's length mix:
        its device arena is freed (``retire``).  Returns how many buckets
        were retired.
        """
        retired = 0
        for bucket in list(self._arenas):
            if self._alloc.live(bucket) == 0:
                self._idle[bucket] = self._idle.get(bucket, 0) + 1
                if self._idle[bucket] >= self.retire_after:
                    self.retire(bucket)
                    retired += 1
            else:
                self._idle[bucket] = 0
        return retired

    def retire(self, bucket: int) -> None:
        """Free an idle bucket's arena (no live slots)."""
        assert self._alloc.live(bucket) == 0, \
            f"bucket {bucket} retired with live slots"
        self._arenas.pop(bucket, None)
        self._alloc.retire_bucket(bucket)
        self._idle.pop(bucket, None)

    def _arena(self, bucket: int) -> BucketArena:
        ar = self._arenas.get(bucket)
        if ar is None:
            s_alloc = bucket + self.op_reserve
            impl = getattr(self.model.rt, "attn_impl", "")
            if impl.startswith("pallas"):
                # keep the decode kernel's cache axis a block multiple so
                # ops.decode_attention never pads K/V copies per step
                blk = getattr(self.model.rt, "block_kv", 512)
                if s_alloc > blk:       # <= blk is always a single block
                    s_alloc = -(-s_alloc // blk) * blk
            ar = BucketArena(self.model, bucket, s_alloc,
                             capacity=self.init_slots)
            self._arenas[bucket] = ar
        return ar

    def _slot_for(self, bucket: int, doc_id: int, arena: BucketArena) -> int:
        prev = self._doc_slot.get(doc_id)
        assert prev is None or prev[0] == bucket, \
            f"doc {doc_id} already staged in bucket {prev[0]}, got {bucket}"
        slot = self._alloc.peek(bucket, doc_id)
        if slot < 0:
            slot = self._alloc.slot_of(bucket, doc_id)
            arena.ensure_capacity(self._alloc.high_water(bucket))
            arena.clear_slot(slot)
            self._doc_slot[doc_id] = (bucket, slot)
        return slot

    # --------------------------------------------------------------- compute
    def _build_step(self):
        model = self.model

        def step(params, arena_states, slots, new_tok, op_tok, kv_true,
                 ext_true, *, c_len: int, op_len: int):
            st = model.take_states(arena_states, slots)
            if new_tok.shape[1] > 0:
                # prefill (c_len == 0) / fraction-extend into the arena;
                # ext_true = per-row REAL extent of cache + chunk, so
                # bucket-PAD keys are invisible inside the chunk too
                _, st = model.extend(params, {"tokens": new_tok}, st,
                                     q_offset=c_len, kv_len=ext_true)
                arena_states = model.put_states(arena_states, slots, st)
            # operation suffix: masked decode steps over the gathered COPY
            # (kv_true = per-doc TRUE prefix length -> pad KV is invisible;
            # the doc snapshot in the arena survives untouched)
            logits = None
            pos = kv_true.astype(jnp.int32)
            B = slots.shape[0]
            for t in range(op_len):
                tok = jnp.broadcast_to(op_tok[t], (B,))
                logits, st = model.decode_step(params, tok, st, pos + t)
            return logits, arena_states

        kwargs: Dict[str, Any] = {"static_argnames": ("c_len", "op_len")}
        if jax.default_backend() != "cpu":      # CPU donation only warns
            kwargs["donate_argnums"] = (1,)
        return jax.jit(step, **kwargs)

    def class_confidences(self, logits: jnp.ndarray, n_classes: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Softmax over the class answer tokens -> (pred, conf)."""
        toks = [class_token(c) for c in range(n_classes)]
        cls_logits = np.asarray(logits, np.float64)[:, toks]
        z = cls_logits - cls_logits.max(axis=1, keepdims=True)
        probs = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
        return probs.argmax(axis=1), probs.max(axis=1)

    def run_stage(
        self,
        doc_ids: Sequence[int],
        doc_tokens: Mapping[int, np.ndarray],
        bucket: int,                             # padded full-doc length
        fraction: float,
        op_tokens: np.ndarray,
        n_classes: int,
    ) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """Run (op, fraction) over one bucket batch (stage-synchronous API).

        Documents may carry heterogeneous cached prefixes: the batch is
        split into per-``cached_len`` launches (each reusing its cache)
        rather than re-prefilling everyone.  Returns (pred [B], conf [B],
        new_tokens, cached_tokens) with TRUE (unpadded) token counts for $
        accounting.  The request loop calls ``run_group`` directly (the
        scheduler has already grouped by cached length).
        """
        B = len(doc_ids)
        f_len = fraction_len(bucket, fraction)
        pred = np.zeros(B, np.int64)
        conf = np.zeros(B, np.float64)
        pos_of = {d: i for i, d in enumerate(doc_ids)}
        new_true_total = 0
        cached_true_total = 0

        groups: Dict[int, List[int]] = {}
        for d in doc_ids:
            eff_c = min(self.cached_len(d), f_len)
            groups.setdefault(eff_c, []).append(d)

        for eff_c in sorted(groups):
            ids = groups[eff_c]
            p, c, new_d, cached_d = self.run_group(
                ids, doc_tokens, bucket, f_len, fraction, eff_c,
                op_tokens, n_classes)
            for j, d in enumerate(ids):
                pred[pos_of[d]] = p[j]
                conf[pos_of[d]] = c[j]
            new_true_total += int(new_d.sum())
            cached_true_total += int(cached_d.sum())
        return pred, conf, new_true_total, cached_true_total

    def run_group(self, ids, doc_tokens, bucket, f_len, fraction, eff_c,
                  op_tokens, n_classes):
        """One static-signature launch: all ``ids`` share ``eff_c``.

        Returns (pred [B], conf [B], new_tokens [B], cached_tokens [B])
        with PER-DOCUMENT true token counts, so the request loop can
        attribute cost to each document's own stage even when a launch
        mixes stages.
        """
        assert len(op_tokens) > 0, "operations must encode to >= 1 token"
        assert len(op_tokens) <= self.op_reserve, \
            f"operation longer than op_reserve ({len(op_tokens)})"
        t0 = time.perf_counter()
        arena = self._arena(bucket)
        slots = [self._slot_for(bucket, d, arena) for d in ids]
        B = len(ids)
        Bp = _pad_width(B)
        n_new = f_len - eff_c                     # 0 => decode-only launch
        op_len = len(op_tokens)

        slots_arr = np.full(Bp, arena.scratch_slot, np.int32)
        slots_arr[:B] = slots
        new_tok = np.full((Bp, n_new), PAD, np.int32)
        kv_true = np.ones(Bp, np.int32)
        ext_true = np.ones(Bp, np.int32)
        new_d = np.zeros(B, np.int64)
        cached_d = np.zeros(B, np.int64)
        for i, d in enumerate(ids):
            toks = doc_tokens[d]
            slot = slots[i]
            if n_new > 0:
                seg = toks[min(eff_c, len(toks)): min(f_len, len(toks))]
                new_tok[i, : len(seg)] = seg
                new_d[i] = len(seg)
                cached_d[i] = min(eff_c, len(toks))
                ext_true[i] = min(eff_c, len(toks)) + len(seg)
            else:
                cached_d[i] = min(int(arena.true_len[slot]),
                                  self._true_len(toks, fraction))
            kv_true[i] = self._true_len(toks, fraction)
        self.host_overhead_s += time.perf_counter() - t0

        if self._step is None:
            self._step = self._build_step()
        t0 = time.perf_counter()
        logits, new_states = self._step(
            self.params, arena.states, jnp.asarray(slots_arr),
            jnp.asarray(new_tok), jnp.asarray(op_tokens, jnp.int32),
            jnp.asarray(kv_true), jnp.asarray(ext_true),
            c_len=eff_c, op_len=op_len)
        arena.states = new_states
        self.host_overhead_s += time.perf_counter() - t0   # async dispatch

        if n_new > 0:
            for i, d in enumerate(ids):
                slot = slots[i]
                arena.cached_len[slot] = f_len
                arena.true_len[slot] = min(f_len, len(doc_tokens[d]))
        pred, conf = self.class_confidences(
            np.asarray(logits)[:B], n_classes)
        return pred, conf, new_d + op_len, cached_d

    @staticmethod
    def _true_len(toks: np.ndarray, fraction: float) -> int:
        return max(int(math.ceil(len(toks) * fraction)), 1)


@dataclass
class EngineResult:
    pred: Dict[int, int]
    conf: Dict[int, float]
    exit_stage: Dict[int, int]
    cost: float
    stats: ServeStats
    stage_cost: List[float] = field(default_factory=list)


# stage-cursor entry: (model, op_id, fraction, threshold_vector-or-None)
_StageEntry = Tuple[str, str, float, Optional[np.ndarray]]


@dataclass
class CascadeEngine:
    """Continuous-batching executor of task cascades over real backends.

    ``start`` / ``submit`` / ``step`` / ``poll`` / ``drain`` is the
    streaming API; ``run`` is the batch wrapper (submit everything, then
    drain).  See the module docstring for the scheduling contract.
    """

    backends: Dict[str, Any]                # "proxy"/"oracle" -> backend
    operations: Dict[str, str]              # op id -> operation text
    n_classes: int
    batch_size: int = 8
    _op_tok_cache: Dict[Tuple[str, str], np.ndarray] = field(
        default_factory=dict, repr=False)
    # ---- serving-session state (valid between start() and the next start())
    _stages: List[_StageEntry] = field(default_factory=list, repr=False)
    _queue: RequestQueue = field(default_factory=RequestQueue, repr=False)
    _reqs: Dict[int, DocRequest] = field(default_factory=dict, repr=False)
    _tok: Dict[str, Dict[int, np.ndarray]] = field(
        default_factory=dict, repr=False)
    _stats: ServeStats = field(default_factory=ServeStats, repr=False)
    _cost: float = field(default=0.0, repr=False)
    _seq: int = field(default=0, repr=False)
    _fresh: List[int] = field(default_factory=list, repr=False)
    _started: bool = field(default=False, repr=False)

    def _op_tokens(self, backend, op_id: str) -> np.ndarray:
        key = (backend.name, op_id)
        toks = self._op_tok_cache.get(key)
        if toks is None:
            toks = np.asarray(
                backend.tokenizer.encode(self.operations[op_id]), np.int32)
            self._op_tok_cache[key] = toks
        return toks

    # ------------------------------------------------------------- lifecycle
    def start(self, cascade: Cascade, oracle_model: str = "oracle") -> None:
        """Begin a serving session: reset backends, clear the queue."""
        self._stages = [
            (t.config.model, t.config.operation, t.config.fraction,
             t.threshold_vector(self.n_classes))
            for t in cascade.tasks
        ] + [(oracle_model, "o_orig", 1.0, None)]   # oracle fall-through
        for be in self.backends.values():
            be.reset()
        self._queue.clear()
        self._reqs = {}
        self._tok = {m: {} for m in self.backends}
        self._stats = ServeStats()
        self._cost = 0.0
        self._seq = 0
        self._fresh = []
        self._started = True

    def _stage_config(self, stage: int) -> Tuple[str, str, float]:
        model, op_id, fraction, _ = self._stages[stage]
        return model, op_id, fraction

    def submit(self, doc_id: int, text: str,
               arrival: Optional[float] = None, stage: int = 0,
               arrival_ts: Optional[float] = None) -> DocRequest:
        """Admit a document into the serving session (streaming arrival).

        ``arrival`` is the scheduling priority — any comparable float
        (logical sequence numbers are fine); lower runs first.
        ``arrival_ts`` is an absolute ``time.perf_counter()`` timestamp
        anchoring the latency measurement — streaming drivers pass the
        SCHEDULED arrival so pre-submit queueing counts; it defaults to
        submit time.  ``arrival`` defaults to ``arrival_ts`` so priority
        follows real arrival order when only timestamps are given.
        ``stage`` lets pre-screened documents enter the cascade mid-way
        (clamped to the oracle).
        """
        assert self._started, "call start(cascade) before submit()"
        assert doc_id not in self._reqs, f"doc {doc_id} already submitted"
        if arrival_ts is None:
            arrival_ts = time.perf_counter()
        if arrival is None:
            arrival = arrival_ts
        req = DocRequest(
            doc_id=doc_id,
            stage=min(max(int(stage), 0), len(self._stages) - 1),
            arrival=arrival, seq=self._seq, arrival_ts=arrival_ts)
        self._seq += 1
        enc: Dict[int, np.ndarray] = {}     # backends often share a tokenizer
        for m, be in self.backends.items():
            ids = enc.get(id(be.tokenizer))
            if ids is None:
                ids = np.asarray(be.tokenizer.encode(text), np.int32)
                enc[id(be.tokenizer)] = ids
            self._tok[m][doc_id] = ids
            req.tok_len[m] = len(ids)
        self._reqs[doc_id] = req
        self._queue.push(req)
        return req

    def pending(self) -> int:
        """Documents admitted but not yet resolved."""
        return len(self._queue)

    # ------------------------------------------------------------ scheduling
    def _make_room(self, be, launch: LaunchSpec) -> LaunchSpec:
        """Enforce the backend's slot budget for one launch.

        First preempts the lowest-priority (newest-arrival) live slots
        outside the launch; if the budget still cannot host every new
        allocation, the newest tail of the launch is deferred back to the
        queue (at least one document always proceeds).
        """
        if getattr(be, "slot_budget", None) is None:
            return launch
        need = sum(1 for d in launch.doc_ids if not be.has_slot(d))
        if be.live_slots() + need <= be.slot_budget:
            return launch
        protected = set(launch.doc_ids)
        victims = sorted(
            (d for d in be.live_docs() if d not in protected),
            key=lambda d: self._reqs[d].key(), reverse=True)
        for d in be.evict_for_room(need, victims):
            req = self._reqs[d]
            req.cached[be.name] = 0
            req.evictions += 1
            self._stats.evictions += 1
        room = max(be.slot_budget - be.live_slots(), 0)
        if need <= room:
            return launch
        # trim: keep the oldest prefix whose new allocations fit (>= 1 doc)
        keep_ids: List[int] = []
        keep_stages: List[int] = []
        used = 0
        for d, s in zip(launch.doc_ids, launch.stages):
            cost = 0 if be.has_slot(d) else 1
            if keep_ids and used + cost > room:
                self._queue.push(self._reqs[d])     # defer to a later launch
                continue
            keep_ids.append(d)
            keep_stages.append(s)
            used += cost
        return LaunchSpec(
            model=launch.model, op_id=launch.op_id, fraction=launch.fraction,
            bucket=launch.bucket, cached_len=launch.cached_len,
            f_len=launch.f_len, doc_ids=tuple(keep_ids),
            stages=tuple(keep_stages))

    def step(self) -> List[int]:
        """Dispatch one launch from the ready queue.

        Returns the doc ids resolved by this step (may be empty).  No-op
        when the queue is idle.
        """
        assert self._started, "call start(cascade) before step()"
        launch = self._queue.next_launch(self._stage_config, self.batch_size)
        if launch is None:
            return []
        be = self.backends[launch.model]
        launch = self._make_room(be, launch)
        ids = list(launch.doc_ids)
        p, c, new_d, cached_d = be.run_group(
            ids, self._tok[launch.model], launch.bucket, launch.f_len,
            launch.fraction, launch.cached_len,
            self._op_tokens(be, launch.op_id), self.n_classes)
        now = time.perf_counter()
        resolved: List[int] = []
        for i, d in enumerate(ids):
            req = self._reqs[d]
            thr = self._stages[req.stage][3]
            cost_d = (new_d[i] * be.rate_per_token
                      + cached_d[i] * be.rate_per_token * be.cached_discount)
            self._stats.record(req.stage, 1, int(new_d[i]), int(cached_d[i]),
                               cost_d)
            self._cost += cost_d
            req.cached[be.name] = be.cached_len(d)
            if thr is None or c[i] >= thr[p[i]]:
                req.done = True
                req.pred = int(p[i])
                req.conf = float(c[i])
                req.exit_stage = req.stage
                for b in self.backends.values():
                    if hasattr(b, "release"):
                        b.release(d)
                self._stats.latencies.append(max(now - req.arrival_ts, 0.0))
                self._fresh.append(d)
                resolved.append(d)
            else:
                req.stage += 1
                self._queue.push(req)
        self._stats.batches += 1
        # retirement ticks on EVERY backend: one that stops receiving
        # launches must still free arenas its drifted length mix pinned
        for b in self.backends.values():
            if hasattr(b, "note_launch"):
                self._stats.retired_buckets += b.note_launch()
        return resolved

    def poll(self) -> Dict[int, Tuple[int, float, int]]:
        """Results resolved since the last poll: doc -> (pred, conf, stage)."""
        out = {d: (self._reqs[d].pred, self._reqs[d].conf,
                   self._reqs[d].exit_stage)
               for d in self._fresh}
        self._fresh = []
        return out

    def drain(self) -> EngineResult:
        """Step until the queue is idle; result covers the whole session."""
        while len(self._queue):
            self.step()
        return self.result()

    def result(self) -> EngineResult:
        done = [r for r in self._reqs.values() if r.done]
        return EngineResult(
            pred={r.doc_id: r.pred for r in done},
            conf={r.doc_id: r.conf for r in done},
            exit_stage={r.doc_id: r.exit_stage for r in done},
            cost=self._cost, stats=self._stats,
            stage_cost=list(self._stats.stage_cost))

    # -------------------------------------------------------- batch wrapper
    def run(self, cascade: Cascade, docs: Mapping[int, str],
            oracle_model: str = "oracle",
            enter_stage: Optional[Mapping[int, int]] = None) -> EngineResult:
        """docs: doc_id -> (already reordered) document text.

        Thin batch wrapper over the request loop: submit every document,
        drain the queue.  ``enter_stage`` (doc_id -> stage index) admits
        documents mid-cascade; stage indices are clamped to the oracle
        stage, so every admitted document resolves.
        """
        requested = dict(enter_stage or {})
        for d in requested:
            if d not in docs:
                raise KeyError(f"enter_stage doc {d!r} not in docs")
        self.start(cascade, oracle_model)
        for d, text in docs.items():
            self.submit(d, text, stage=requested.get(d, 0))
        return self.drain()
