"""Serving scheduler: request lifecycle, the cross-stage ready queue,
bucketed batching, slot allocation, batch packing.

TPU serving wants a small set of compiled shapes.  Documents are grouped
into power-of-two *length buckets*; within a bucket each document owns a
**slot** in a persistent KV arena for its lifetime (``SlotAllocator``), so
survivor compaction between launches is an index gather, not a pytree
rebuild.

Continuous batching rides on two pieces here:

``DocRequest``
    per-document lifecycle state — owning query, stage cursor, arrival
    time, per-backend cached/tokenized lengths, resolution status,
    eviction count, accumulated $ cost.  The server owns one per
    submitted document from ``submit()`` to resolution.  ``query_id``
    names the registered query whose stage table the cursor walks;
    ``ext_id`` is the caller's document id (``doc_id`` is the
    server-global request id used as the slot/token key, so documents
    from different queries never collide).

``RequestQueue``
    the global ready queue, shared by every registered query.
    ``next_launch`` packs the *entire* ready set — every stage of every
    query at once — into static-signature launches keyed by ``(backend,
    bucket, cached_len, op, f_len)``.  The signature carries neither a
    stage index nor a query id, so a stage-0 prefill for one query and a
    stage-2 decode for another merge into ONE launch whenever their
    static shapes agree (cross-query packing), and mixed-query launches
    reuse the same compiled steps.  Which ready group dispatches next is
    a pluggable ``policy``: the default ``oldest_head_first`` pops the
    group whose head document is oldest (FIFO head-of-line — admission
    is fair across queries because ``(arrival, seq)`` is server-global),
    while ``largest_ready_group`` trades per-document latency for batch
    occupancy under overload.

Failure model (fault-tolerant serving plane)
--------------------------------------------
A request is no longer guaranteed to resolve: it reaches exactly one of
three TERMINAL states — ``RESOLVED`` (a stage cleared its threshold or
the oracle fall-through ran), ``FAILED`` (a launch kept failing past
``RetryPolicy.max_retries``, or confidences stayed non-finite at the
final stage), or ``TIMED_OUT`` (its deadline elapsed before
resolution).  The scheduler's half of that contract:

  * ``RetryPolicy`` — capped exponential backoff for failed launches;
    a retried request carries ``not_before`` (the earliest wall-clock
    instant it may launch again) and ``next_launch(now=...)`` treats
    requests still in backoff as invisible;
  * launch-level isolation — a request re-enqueued after a failure or a
    non-finite-confidence quarantine is marked ``solo`` and forms a
    SINGLETON launch group, so one poisoned document in a packed
    cross-query launch can never fail its (healthy) cohort twice;
  * per-request ``deadline`` (absolute ``time.perf_counter`` instant) —
    ``pop_expired(now)`` sweeps expired requests out of the ready set
    before packing, and the server resolves them ``TIMED_OUT``;
  * ``next_eligible_in(now)`` — how long until the earliest backoff
    expires, so ``drain()`` can sleep instead of spinning (and the
    engine's no-progress watchdog can tell backoff from a true stall).

``pack_stage_batches`` (the PR-1 stage-synchronous packer) is retained for
per-stage scoring paths; it emits ``StageBatch`` launches grouped by
``(bucket, cached_len)`` within one stage.  Documents whose cached prefix
already covers the requested fraction share a single decode-only launch
per bucket (the per-document valid length rides in ``kv_len``, which is
dynamic).

A straggler policy can migrate queued work between serving shards
(distributed.fault.StragglerPolicy).
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple)

import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)

# Request lifecycle states.  PENDING is the only non-terminal state; every
# submitted document must end in exactly one of the other three (the chaos
# benchmark's all-docs-terminal invariant).
PENDING = "pending"
RESOLVED = "resolved"
FAILED = "failed"
TIMED_OUT = "timed_out"
TERMINAL_STATES = (RESOLVED, FAILED, TIMED_OUT)


@dataclass(frozen=True)
class RetryPolicy:
    """Retry budget + capped exponential backoff for failed launches.

    A launch failure (raised exception — injected or real) re-enqueues
    each member document individually; the document's ``retries`` counter
    increments and its next launch is delayed by ``backoff(retries)``
    seconds: ``backoff_base * 2**(retries - 1)`` capped at
    ``backoff_cap``.  A document whose ``retries`` exceeds
    ``max_retries`` resolves terminally as ``FAILED`` instead of
    retrying forever.  ``backoff_base = 0`` disables the delay (retries
    become immediately eligible) — deterministic chaos tests use that.
    """

    max_retries: int = 3
    backoff_base: float = 0.05
    backoff_cap: float = 1.0

    def backoff(self, retries: int) -> float:
        if self.backoff_base <= 0.0:
            return 0.0
        return min(self.backoff_base * (2.0 ** max(retries - 1, 0)),
                   self.backoff_cap)


def bucket_len(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class Bucket:
    seq_len: int
    doc_ids: List[int] = field(default_factory=list)


def make_buckets(doc_ids: Iterable[int], lengths: Dict[int, int],
                 batch_size: int,
                 buckets: Sequence[int] = DEFAULT_BUCKETS
                 ) -> List[Tuple[int, List[int]]]:
    """Group docs by length bucket, then split into <= batch_size batches.

    Returns [(bucket_seq_len, [doc_id, ...]), ...]; batches are full except
    possibly the last per bucket (compaction).
    """
    by_bucket: Dict[int, List[int]] = {}
    for d in doc_ids:
        by_bucket.setdefault(bucket_len(lengths[d], buckets), []).append(d)
    out = []
    for blen in sorted(by_bucket):
        ids = by_bucket[blen]
        for i in range(0, len(ids), batch_size):
            out.append((blen, ids[i: i + batch_size]))
    return out


# ---------------------------------------------------------------------------
# Request lifecycle (continuous batching)
# ---------------------------------------------------------------------------

@dataclass
class DocRequest:
    """Per-document lifecycle state for the continuous-batching loop.

    A request is created by a query handle's ``submit`` and lives until
    the document resolves (``done``).  ``query_id`` names the registered
    query whose stage table ``stage`` indexes (len(tasks) == the oracle
    fall-through); ``ext_id`` is the caller's document id while
    ``doc_id`` is the server-global request id used as the slot/token
    key — two queries may both submit a document "7" without colliding.
    ``cached`` mirrors each backend's padded cached-prefix length so the
    scheduler can compute launch signatures without touching arenas.
    Eviction resets the victim backend's entry to 0 — the document re-
    enters the queue at its current stage and re-prefills as new tokens.
    ``cost`` accumulates this document's own $ across its launches
    (deterministic per-doc accounting regardless of launch composition).

    Fault-tolerance state: ``status`` moves PENDING -> exactly one of
    ``RESOLVED``/``FAILED``/``TIMED_OUT`` (``done`` mirrors terminality);
    ``retries``/``quarantines`` count failed launches and non-finite
    confidence events; ``not_before`` is the backoff gate (the request is
    invisible to ``next_launch`` until then); ``deadline`` is an absolute
    ``perf_counter`` instant after which the request times out; ``solo``
    marks a retried/quarantined request that must launch alone
    (launch-level isolation); ``error`` carries the last failure message
    for terminal diagnostics.
    """

    doc_id: int
    stage: int = 0                    # stage cursor
    arrival: float = 0.0              # arrival order (scheduling priority)
    seq: int = 0                      # admission order (tie-break)
    arrival_ts: float = 0.0           # perf_counter latency anchor
    tok_len: Dict[str, int] = field(default_factory=dict)   # backend -> len
    cached: Dict[str, int] = field(default_factory=dict)    # backend -> pad len
    query_id: int = 0                 # owning registered query
    ext_id: Optional[int] = None      # caller's doc id (defaults to doc_id)
    cost: float = 0.0                 # accumulated per-document $
    pred: Optional[int] = None
    conf: Optional[float] = None
    exit_stage: Optional[int] = None
    evictions: int = 0
    done: bool = False
    # --- fault-tolerance lifecycle
    status: str = PENDING
    retries: int = 0                  # failed launches survived
    quarantines: int = 0              # non-finite confidence events
    not_before: float = 0.0           # backoff gate (perf_counter instant)
    deadline: Optional[float] = None  # absolute timeout (perf_counter)
    solo: bool = False                # launch alone (failure isolation)
    error: Optional[str] = None       # last failure diagnostic

    def __post_init__(self) -> None:
        if self.ext_id is None:
            self.ext_id = self.doc_id

    def key(self) -> Tuple[float, int]:
        return (self.arrival, self.seq)


@dataclass(frozen=True)
class LaunchSpec:
    """One dispatch of the request loop: all docs share the static step
    signature ``(model, op_id, bucket, cached_len, f_len)`` regardless of
    which cascade stage each is at (``stages`` is per-doc bookkeeping for
    thresholds/accounting, not part of the compiled shape)."""

    model: str
    op_id: str
    fraction: float
    bucket: int
    cached_len: int                   # static q_offset (== f_len: decode-only)
    f_len: int
    doc_ids: Tuple[int, ...]
    stages: Tuple[int, ...]


# (model, op_id, fraction) of a request's current stage
StageConfig = Tuple[str, str, float]
# static launch signature: (model, op_id, fraction, bucket, cached, f_len,
# isolation key).  The last element is -1 for normal requests; a ``solo``
# request contributes its own doc_id, so it always forms a singleton group
# (launch-level failure isolation).
SignatureKey = Tuple[str, str, float, int, int, int, int]
# scheduling policy: pick which ready group dispatches next
SchedulingPolicy = Callable[
    [Mapping[SignatureKey, List[DocRequest]],
     Mapping[SignatureKey, Tuple[float, int]]], SignatureKey]


def oldest_head_first(
    groups: Mapping[SignatureKey, List[DocRequest]],
    heads: Mapping[SignatureKey, Tuple[float, int]],
) -> SignatureKey:
    """Default policy: the group whose head (oldest) request has the
    smallest ``(arrival, seq)`` — head-of-line FIFO.  Veterans deep in
    the cascade are never starved by a stream of new arrivals, and
    because ``(arrival, seq)`` is server-global, admission stays fair
    across registered queries."""
    return min(heads, key=heads.get)


def largest_ready_group(
    groups: Mapping[SignatureKey, List[DocRequest]],
    heads: Mapping[SignatureKey, Tuple[float, int]],
) -> SignatureKey:
    """Throughput policy: the group with the most ready documents (oldest
    head breaks ties).  Under sustained overload this keeps launches full
    — trading head-of-line latency (p50) for batch occupancy."""
    return min(groups, key=lambda k: (-len(groups[k]), heads[k]))


class RequestQueue:
    """Global cross-stage, cross-query ready queue for the
    continuous-batching loop.

    Holds every unresolved, not-in-flight ``DocRequest`` across ALL
    registered queries.  ``next_launch`` groups the whole ready set by
    static signature and pops up to ``batch_size`` documents from the
    group a ``policy`` selects (default: ``oldest_head_first``).  The
    signature carries neither stage index nor query id, so requests from
    different queries (and different stages) merge into one launch
    whenever their compiled shapes agree.
    """

    def __init__(self) -> None:
        self._ready: Dict[int, DocRequest] = {}        # doc_id -> request

    def __len__(self) -> int:
        return len(self._ready)

    def __contains__(self, doc_id: int) -> bool:
        return doc_id in self._ready

    def push(self, req: DocRequest) -> None:
        """Admit a request (also how deferred/surviving requests return)."""
        self._ready[req.doc_id] = req

    def clear(self) -> None:
        self._ready.clear()

    def ready(self) -> List[DocRequest]:
        """Snapshot of every queued request (backoff included)."""
        return list(self._ready.values())

    def pop_expired(self, now: float) -> List[DocRequest]:
        """Remove and return requests whose deadline has elapsed.

        Deadline beats backoff: a request sitting out a retry delay still
        times out on schedule.  The caller resolves the returned requests
        as ``TIMED_OUT``.
        """
        out = [r for r in self._ready.values()
               if r.deadline is not None and r.deadline <= now]
        for r in out:
            del self._ready[r.doc_id]
        return out

    def next_eligible_in(self, now: Optional[float] = None
                         ) -> Optional[float]:
        """Seconds until the earliest queued request leaves backoff.

        ``<= 0`` means work is dispatchable right now; ``None`` means the
        queue is empty; ``inf`` means every queued request is gated
        forever (a stall, not a wait — the engine watchdog treats it so).
        """
        if not self._ready:
            return None
        if now is None:
            now = time.perf_counter()
        return min(r.not_before for r in self._ready.values()) - now

    def next_launch(
        self,
        stage_config: Callable[[DocRequest], StageConfig],
        batch_size: int,
        buckets: Sequence[int] = DEFAULT_BUCKETS,
        policy: Optional[SchedulingPolicy] = None,
        now: Optional[float] = None,
        blocked: Optional[Callable[[SignatureKey], bool]] = None,
    ) -> Optional[LaunchSpec]:
        """Pop the next launch, or None when nothing is dispatchable.

        ``stage_config(req) -> (model, op_id, fraction)`` resolves a
        request's CURRENT stage through its owning query (the oracle
        fall-through included) — multi-tenant serving passes a resolver
        that dispatches on ``req.query_id``, so two queries whose stages
        share a static signature land in the same group.  ``policy``
        picks which ready group dispatches (None = ``oldest_head_first``;
        ``largest_ready_group`` favours occupancy under overload).

        Requests still in retry backoff (``not_before > now``) are
        invisible this call; ``solo`` requests form singleton groups so a
        poisoned document retries alone (see the module docstring's
        failure model).  ``now`` defaults to ``time.perf_counter()``.

        ``blocked(key) -> bool`` vetoes whole signature groups before the
        policy picks one: overlapped ahead-of-time dispatch passes the
        server's conflict check so no launch is co-scheduled onto arena
        rows an open ticket still owns (documents in flight are already
        out of the ready set — this guards the SHARED rows, e.g. a
        first-touch prefix-row prefill against open readers).  Vetoed
        groups stay queued and become visible again once the conflicting
        tickets complete.
        """
        if not self._ready:
            return None
        if now is None:
            now = time.perf_counter()
        # one O(N) pass: bin by signature, tracking each group's head so
        # only the SELECTED group is sorted (not every group every step)
        groups: Dict[SignatureKey, List[DocRequest]] = {}
        heads: Dict[SignatureKey, Tuple[float, int]] = {}
        for req in self._ready.values():
            if req.not_before > now:          # still backing off
                continue
            model, op_id, fraction = stage_config(req)
            blen = bucket_len(req.tok_len[model], buckets)
            f_len = fraction_len(blen, fraction)
            eff_c = min(req.cached.get(model, 0), f_len)
            key = (model, op_id, fraction, blen, eff_c, f_len,
                   req.doc_id if req.solo else -1)
            groups.setdefault(key, []).append(req)
            if key not in heads or req.key() < heads[key]:
                heads[key] = req.key()
        if blocked is not None and groups:
            groups = {k: v for k, v in groups.items() if not blocked(k)}
            heads = {k: heads[k] for k in groups}
        if not groups:
            return None
        best_key = (policy or oldest_head_first)(groups, heads)
        model, op_id, fraction, blen, eff_c, f_len = best_key[:6]
        take = sorted(groups[best_key], key=DocRequest.key)[:batch_size]
        for req in take:
            del self._ready[req.doc_id]
        return LaunchSpec(
            model=model, op_id=op_id, fraction=fraction, bucket=blen,
            cached_len=eff_c, f_len=f_len,
            doc_ids=tuple(r.doc_id for r in take),
            stages=tuple(r.stage for r in take))


# ---------------------------------------------------------------------------
# Slot allocation (document -> arena slot, per bucket)
# ---------------------------------------------------------------------------

class SlotAllocator:
    """Assigns each document a per-bucket arena slot for its lifetime.

    Slots freed by resolved documents are recycled before the high-water
    mark grows, so a streaming workload's arena footprint tracks the live
    set, not the corpus.
    """

    def __init__(self) -> None:
        self._slot: Dict[int, Dict[int, int]] = {}     # bucket -> doc -> slot
        self._free: Dict[int, List[int]] = {}          # bucket -> free slots
        self._high: Dict[int, int] = {}                # bucket -> high water

    def slot_of(self, bucket: int, doc: int) -> int:
        """Slot of ``doc`` (allocating one on first touch)."""
        slots = self._slot.setdefault(bucket, {})
        if doc in slots:
            return slots[doc]
        free = self._free.setdefault(bucket, [])
        if free:
            s = free.pop()
        else:
            s = self._high.get(bucket, 0)
            self._high[bucket] = s + 1
        slots[doc] = s
        return s

    def peek(self, bucket: int, doc: int) -> int:
        """Slot of ``doc`` or -1 without allocating."""
        return self._slot.get(bucket, {}).get(doc, -1)

    def release(self, bucket: int, doc: int) -> None:
        slots = self._slot.get(bucket, {})
        s = slots.pop(doc, None)
        if s is not None:
            self._free.setdefault(bucket, []).append(s)

    def high_water(self, bucket: int) -> int:
        return self._high.get(bucket, 0)

    def live(self, bucket: int) -> int:
        return len(self._slot.get(bucket, {}))

    def live_total(self) -> int:
        return sum(len(s) for s in self._slot.values())

    def retire_bucket(self, bucket: int) -> None:
        """Drop all allocation state for an idle bucket (arena retired)."""
        assert not self._slot.get(bucket), \
            f"bucket {bucket} retired with live slots"
        self._slot.pop(bucket, None)
        self._free.pop(bucket, None)
        self._high.pop(bucket, None)

    def reset(self) -> None:
        self._slot.clear()
        self._free.clear()
        self._high.clear()


# ---------------------------------------------------------------------------
# Stage batch packing
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageBatch:
    """One launch: all docs share ``bucket`` and the static ``cached_len``.

    ``cached_len == f_len`` (the fraction slice for this bucket) marks a
    decode-only launch: every doc's cache already covers the fraction and
    only the operation suffix runs (per-doc valid lengths are dynamic).
    """
    bucket: int
    cached_len: int            # static q_offset of the extension (== f_len
                               # for decode-only launches)
    doc_ids: Tuple[int, ...]


def fraction_len(bucket: int, fraction: float) -> int:
    return max(int(math.ceil(bucket * fraction)), 1)


def pack_stage_batches(
    doc_ids: Iterable[int],
    lengths: Mapping[int, int],
    cached_len: Mapping[int, int],
    fraction: float,
    batch_size: int,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
) -> List[StageBatch]:
    """Pack one stage's documents into static-signature launches.

    Groups by (bucket, effective cached length) where the effective length
    clamps to the stage's fraction slice — caches that already cover the
    fraction collapse into one decode-only group per bucket.  Within a
    group, batches fill to ``batch_size`` (survivor compaction).
    """
    groups: Dict[Tuple[int, int], List[int]] = {}
    for d in doc_ids:
        blen = bucket_len(lengths[d], buckets)
        f_len = fraction_len(blen, fraction)
        eff_c = min(cached_len.get(d, 0), f_len)
        groups.setdefault((blen, eff_c), []).append(d)
    out = []
    for (blen, eff_c) in sorted(groups):
        ids = groups[(blen, eff_c)]
        for i in range(0, len(ids), batch_size):
            out.append(StageBatch(blen, eff_c,
                                  tuple(ids[i: i + batch_size])))
    return out


# ---------------------------------------------------------------------------
# Serving statistics ($-aware)
# ---------------------------------------------------------------------------

# ServeStats aggregation strategies, declared per-field via dataclass
# metadata so ``merge_from`` can iterate ``dataclasses.fields`` instead of
# a hand-maintained list (a new counter defaults to "sum" and can never
# silently drop out of ``server.stats()`` aggregation):
#   sum     additive per-query counter
#   max     high-water mark
#   concat  per-document sample list
#   stage   per-stage vectors, folded jointly through ``record``
#   shared  mirror of a server-wide substrate counter (launches, breaker
#           trips, retired buckets, prefix memo hits): summing would
#           double-count, so merge skips it and the server's aggregate
#           overwrites it from its own global state
MERGE_STRATEGIES = ("sum", "max", "concat", "stage", "shared")


def _stat(merge: str, **kw: Any) -> Any:
    assert merge in MERGE_STRATEGIES
    return field(metadata={"merge": merge}, **kw)


@dataclass
class ServeStats:
    stage_docs: List[int] = _stat("stage", default_factory=list)
    stage_new_tokens: List[int] = _stat("stage", default_factory=list)
    stage_cached_tokens: List[int] = _stat("stage", default_factory=list)
    stage_cost: List[float] = _stat("stage", default_factory=list)
    batches: int = _stat("shared", default=0)   # launches this query rode
    evictions: int = _stat("sum", default=0)    # slots preempted under budget
    retired_buckets: int = _stat("shared", default=0)  # idle arenas freed
    latencies: List[float] = _stat("concat",
                                   default_factory=list)  # submit->resolve s
    # fault-tolerance counters (see the module docstring's failure model)
    retries: int = _stat("sum", default=0)      # re-enqueues after failures
    quarantines: int = _stat("sum", default=0)  # non-finite confs caught
    timeouts: int = _stat("sum", default=0)     # docs resolved TIMED_OUT
    failures: int = _stat("sum", default=0)     # docs resolved FAILED
    breaker_trips: int = _stat("shared", default=0)  # circuit-breaker opens
    recovered_docs: int = _stat("sum", default=0)    # arena-loss replays +
    #                                                  journal resubmits
    # memory/prefix-sharing counters (PR-7 capacity accounting)
    arena_bytes_peak: int = _stat("max", default=0)  # max arena device bytes
    re_prefill_tokens: int = _stat("sum", default=0)  # true cached tokens
    #                                    lost to eviction or arena loss
    prefix_hits: int = _stat("shared", default=0)  # docs attached to an
    #                                    existing shared op-prefix row
    cow_copies: int = _stat("shared", default=0)   # copy-on-write partial-
    #                                    block copies (prefix -> private)
    sanitizer_checks: int = _stat("shared", default=0)  # arena-sanitizer
    #   launch brackets validated (ARENA_SANITIZE=1; 0 when off).  Mirrored
    #   from the sanitizers' PRIVATE registries — hub metrics stay inert.

    def latency_quantile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies), q))

    def merge_from(self, src: "ServeStats") -> None:
        """Fold ``src`` into ``self``, dispatching on each field's
        declared merge strategy (see ``MERGE_STRATEGIES`` above).  The
        per-stage vectors are folded jointly through ``record`` once."""
        staged = False
        for f in dataclasses.fields(self):
            kind = f.metadata.get("merge", "sum")
            if kind == "stage":
                if not staged:
                    for s in range(len(src.stage_docs)):
                        self.record(s, src.stage_docs[s],
                                    src.stage_new_tokens[s],
                                    src.stage_cached_tokens[s],
                                    src.stage_cost[s])
                    staged = True
            elif kind == "sum":
                setattr(self, f.name,
                        getattr(self, f.name) + getattr(src, f.name))
            elif kind == "max":
                setattr(self, f.name,
                        max(getattr(self, f.name), getattr(src, f.name)))
            elif kind == "concat":
                getattr(self, f.name).extend(getattr(src, f.name))
            else:
                assert kind == "shared", \
                    f"unknown merge strategy {kind!r} on " \
                    f"ServeStats.{f.name}"

    def record(self, stage: int, docs: int, new_tokens: int,
               cached_tokens: int, cost: float = 0.0) -> None:
        while len(self.stage_docs) <= stage:
            self.stage_docs.append(0)
            self.stage_new_tokens.append(0)
            self.stage_cached_tokens.append(0)
            self.stage_cost.append(0.0)
        self.stage_docs[stage] += docs
        self.stage_new_tokens[stage] += new_tokens
        self.stage_cached_tokens[stage] += cached_tokens
        self.stage_cost[stage] += cost

    def total_new_tokens(self) -> int:
        return sum(self.stage_new_tokens)

    def total_cached_tokens(self) -> int:
        return sum(self.stage_cached_tokens)

    def total_cost(self) -> float:
        return sum(self.stage_cost)

    def cache_hit_rate(self) -> float:
        tot = self.total_new_tokens() + self.total_cached_tokens()
        return self.total_cached_tokens() / tot if tot else 0.0
