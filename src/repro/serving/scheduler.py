"""Serving scheduler: bucketed batching, survivor compaction, stragglers.

TPU serving wants a small set of compiled shapes.  Documents are grouped
into power-of-two *length buckets* per cascade stage; unresolved survivors
are compacted into full batches between stages (no ragged launches); and a
straggler policy can migrate queued work between serving shards
(distributed.fault.StragglerPolicy).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_len(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class Bucket:
    seq_len: int
    doc_ids: List[int] = field(default_factory=list)


def make_buckets(doc_ids: Iterable[int], lengths: Dict[int, int],
                 batch_size: int,
                 buckets: Sequence[int] = DEFAULT_BUCKETS
                 ) -> List[Tuple[int, List[int]]]:
    """Group docs by length bucket, then split into <= batch_size batches.

    Returns [(bucket_seq_len, [doc_id, ...]), ...]; batches are full except
    possibly the last per bucket (compaction).
    """
    by_bucket: Dict[int, List[int]] = {}
    for d in doc_ids:
        by_bucket.setdefault(bucket_len(lengths[d], buckets), []).append(d)
    out = []
    for blen in sorted(by_bucket):
        ids = by_bucket[blen]
        for i in range(0, len(ids), batch_size):
            out.append((blen, ids[i: i + batch_size]))
    return out


@dataclass
class ServeStats:
    stage_docs: List[int] = field(default_factory=list)
    stage_new_tokens: List[int] = field(default_factory=list)
    stage_cached_tokens: List[int] = field(default_factory=list)
    batches: int = 0

    def record(self, stage: int, docs: int, new_tokens: int,
               cached_tokens: int) -> None:
        while len(self.stage_docs) <= stage:
            self.stage_docs.append(0)
            self.stage_new_tokens.append(0)
            self.stage_cached_tokens.append(0)
        self.stage_docs[stage] += docs
        self.stage_new_tokens[stage] += new_tokens
        self.stage_cached_tokens[stage] += cached_tokens

    def total_new_tokens(self) -> int:
        return sum(self.stage_new_tokens)

    def total_cached_tokens(self) -> int:
        return sum(self.stage_cached_tokens)

    def cache_hit_rate(self) -> float:
        tot = self.total_new_tokens() + self.total_cached_tokens()
        return self.total_cached_tokens() / tot if tot else 0.0
