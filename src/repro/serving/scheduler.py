"""Serving scheduler: bucketed batching, slot allocation, batch packing.

TPU serving wants a small set of compiled shapes.  Documents are grouped
into power-of-two *length buckets* per cascade stage; within a bucket each
document owns a **slot** in a persistent KV arena for its lifetime
(``SlotAllocator``), so survivor compaction between stages is an index
gather, not a pytree rebuild.

``pack_stage_batches`` is the cross-bucket packer: it walks every bucket in
one pass and emits ``StageBatch`` launches grouped by the static step
signature ``(bucket, cached_len)`` — documents that entered the cascade at
different stages (different cached prefixes) land in different launches of
the same bucket instead of forcing a whole-batch re-prefill.  Documents
whose cached prefix already covers the requested fraction share a single
decode-only launch per bucket regardless of how long their caches are
(the per-document valid length rides in ``kv_len``, which is dynamic).

A straggler policy can migrate queued work between serving shards
(distributed.fault.StragglerPolicy).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_len(n: int, buckets: Sequence[int] = DEFAULT_BUCKETS) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class Bucket:
    seq_len: int
    doc_ids: List[int] = field(default_factory=list)


def make_buckets(doc_ids: Iterable[int], lengths: Dict[int, int],
                 batch_size: int,
                 buckets: Sequence[int] = DEFAULT_BUCKETS
                 ) -> List[Tuple[int, List[int]]]:
    """Group docs by length bucket, then split into <= batch_size batches.

    Returns [(bucket_seq_len, [doc_id, ...]), ...]; batches are full except
    possibly the last per bucket (compaction).
    """
    by_bucket: Dict[int, List[int]] = {}
    for d in doc_ids:
        by_bucket.setdefault(bucket_len(lengths[d], buckets), []).append(d)
    out = []
    for blen in sorted(by_bucket):
        ids = by_bucket[blen]
        for i in range(0, len(ids), batch_size):
            out.append((blen, ids[i: i + batch_size]))
    return out


# ---------------------------------------------------------------------------
# Slot allocation (document -> arena slot, per bucket)
# ---------------------------------------------------------------------------

class SlotAllocator:
    """Assigns each document a per-bucket arena slot for its lifetime.

    Slots freed by resolved documents are recycled before the high-water
    mark grows, so a streaming workload's arena footprint tracks the live
    set, not the corpus.
    """

    def __init__(self) -> None:
        self._slot: Dict[int, Dict[int, int]] = {}     # bucket -> doc -> slot
        self._free: Dict[int, List[int]] = {}          # bucket -> free slots
        self._high: Dict[int, int] = {}                # bucket -> high water

    def slot_of(self, bucket: int, doc: int) -> int:
        """Slot of ``doc`` (allocating one on first touch)."""
        slots = self._slot.setdefault(bucket, {})
        if doc in slots:
            return slots[doc]
        free = self._free.setdefault(bucket, [])
        if free:
            s = free.pop()
        else:
            s = self._high.get(bucket, 0)
            self._high[bucket] = s + 1
        slots[doc] = s
        return s

    def peek(self, bucket: int, doc: int) -> int:
        """Slot of ``doc`` or -1 without allocating."""
        return self._slot.get(bucket, {}).get(doc, -1)

    def release(self, bucket: int, doc: int) -> None:
        slots = self._slot.get(bucket, {})
        s = slots.pop(doc, None)
        if s is not None:
            self._free.setdefault(bucket, []).append(s)

    def high_water(self, bucket: int) -> int:
        return self._high.get(bucket, 0)

    def live(self, bucket: int) -> int:
        return len(self._slot.get(bucket, {}))

    def reset(self) -> None:
        self._slot.clear()
        self._free.clear()
        self._high.clear()


# ---------------------------------------------------------------------------
# Stage batch packing
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageBatch:
    """One launch: all docs share ``bucket`` and the static ``cached_len``.

    ``cached_len == f_len`` (the fraction slice for this bucket) marks a
    decode-only launch: every doc's cache already covers the fraction and
    only the operation suffix runs (per-doc valid lengths are dynamic).
    """
    bucket: int
    cached_len: int            # static q_offset of the extension (== f_len
                               # for decode-only launches)
    doc_ids: Tuple[int, ...]


def fraction_len(bucket: int, fraction: float) -> int:
    return max(int(math.ceil(bucket * fraction)), 1)


def pack_stage_batches(
    doc_ids: Iterable[int],
    lengths: Mapping[int, int],
    cached_len: Mapping[int, int],
    fraction: float,
    batch_size: int,
    buckets: Sequence[int] = DEFAULT_BUCKETS,
) -> List[StageBatch]:
    """Pack one stage's documents into static-signature launches.

    Groups by (bucket, effective cached length) where the effective length
    clamps to the stage's fraction slice — caches that already cover the
    fraction collapse into one decode-only group per bucket.  Within a
    group, batches fill to ``batch_size`` (survivor compaction).
    """
    groups: Dict[Tuple[int, int], List[int]] = {}
    for d in doc_ids:
        blen = bucket_len(lengths[d], buckets)
        f_len = fraction_len(blen, fraction)
        eff_c = min(cached_len.get(d, 0), f_len)
        groups.setdefault((blen, eff_c), []).append(d)
    out = []
    for (blen, eff_c) in sorted(groups):
        ids = groups[(blen, eff_c)]
        for i in range(0, len(ids), batch_size):
            out.append(StageBatch(blen, eff_c,
                                  tuple(ids[i: i + batch_size])))
    return out


# ---------------------------------------------------------------------------
# Serving statistics ($-aware)
# ---------------------------------------------------------------------------

@dataclass
class ServeStats:
    stage_docs: List[int] = field(default_factory=list)
    stage_new_tokens: List[int] = field(default_factory=list)
    stage_cached_tokens: List[int] = field(default_factory=list)
    stage_cost: List[float] = field(default_factory=list)
    batches: int = 0

    def record(self, stage: int, docs: int, new_tokens: int,
               cached_tokens: int, cost: float = 0.0) -> None:
        while len(self.stage_docs) <= stage:
            self.stage_docs.append(0)
            self.stage_new_tokens.append(0)
            self.stage_cached_tokens.append(0)
            self.stage_cost.append(0.0)
        self.stage_docs[stage] += docs
        self.stage_new_tokens[stage] += new_tokens
        self.stage_cached_tokens[stage] += cached_tokens
        self.stage_cost[stage] += cost

    def total_new_tokens(self) -> int:
        return sum(self.stage_new_tokens)

    def total_cached_tokens(self) -> int:
        return sum(self.stage_cached_tokens)

    def total_cost(self) -> float:
        return sum(self.stage_cost)

    def cache_hit_rate(self) -> float:
        tot = self.total_new_tokens() + self.total_cached_tokens()
        return self.total_cached_tokens() / tot if tot else 0.0
