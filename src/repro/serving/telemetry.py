"""Bounded-memory tracing + metrics for the cascade serving plane.

Zero external dependencies (numpy only), zero device work: every probe is
a host-side ``time.perf_counter()`` read or a dict update around the
jitted stage steps, so the fault-free data plane stays bitwise identical
whether telemetry is off, at ``"counters"`` (the default), or at
``"trace"``.  All storage is fixed-capacity — ring buffers for events and
launch records, a capped label-set registry for metrics — so memory stays
bounded under million-document traffic.

Levels
------
``off``       every probe is a no-op.
``counters``  metric registry + per-launch timeline records (default).
``trace``     additionally records per-document span events.

Event schema (span traces, ``level="trace"``)
---------------------------------------------
Every event is a ``(ts, rid, kind, attrs)`` tuple appended to the shared
``TraceBuffer`` ring (drop-oldest; ``dropped_events`` counts overwrites).
``ts`` is a raw ``time.perf_counter()`` stamp, ``rid`` the server-global
request id of the owning ``DocRequest`` (``register_doc`` maps it to the
caller's ``(query_id, ext_id)``), ``attrs`` a small dict or None.  Kinds:

==============  =========================================================
``submit``      document admitted (attrs: ``stage``; ``restored=True``
                for journal-restored documents on warm restart)
``launch``      document rode a dispatched launch (attrs: ``sig`` —
                the static launch signature ``(model, op, bucket,
                cached_len, f_len)`` — plus ``batch``, ``stage``,
                ``launch`` index)
``escalate``    stage advance (attrs: ``to`` stage and ``reason`` —
                ``threshold`` | ``breaker`` | ``quarantine``)
``retry``       re-enqueued solo after a failed launch (attrs:
                ``retries``, ``backoff_s``)
``evict``       slot preempted (attrs: ``backend``, ``lost_tokens``,
                ``reason`` — ``budget`` | ``arena_loss``)
``quarantine``  non-finite confidence caught (attrs: ``count``)
``prefix_hit``  attached to a shared op-prefix row (attrs: ``backend``)
``cow_copy``    partial-block copy-on-write copy (attrs: ``backend``)
``fault``       injected fault touched this doc's launch (attrs:
                ``kind`` — ``launch_failure``|``nan_conf``|``spike``)
``resolved`` /  terminal states; exactly one per span, always last
``failed`` /    (attrs: ``stage`` for resolved, ``error`` otherwise).
``timed_out``
==============  =========================================================

A *well-formed* span starts with ``submit``, ends with exactly one
terminal event, and has non-decreasing timestamps — ``validate_spans``
checks all three and the smoke gate requires zero violations.

Launch timeline (``level="counters"`` and up)
---------------------------------------------
``CascadeServer.step()`` decomposes each launch's wall time into four
disjoint segments that sum to the record's wall clock:

``sched_s``     scheduler pick: deadline sweep, breaker rerouting,
                ``RequestQueue.next_launch``
``host_s``      host bookkeeping: eviction, batch assembly, billing,
                threshold routing, queue pushes (the residual of the
                other three — everything that is not dispatch/device)
``dispatch_s``  the jitted stage-step call returning (async dispatch)
``device_s``    the completion-side ``jax.block_until_ready`` wait

SEGMENT SEMANTICS UNDER OVERLAPPED DISPATCH (``CascadeServer.inflight``
> 1): timing is PER-TICKET and never forces synchronization — the
dispatch segment stamps around the non-blocking ``dispatch_group``
enqueue, the device segment stamps around ``complete_group``'s sync,
and the window in between (dispatch returned, sync not yet entered:
the launch computing on-device while the host schedules/dispatches
OTHER launches) is recorded separately as the record's ``inflight_s``.
``inflight_s`` is NOT one of the four wall-clock segments: a record's
wall spans dispatch of younger launches at K>1, so walls of
consecutive records overlap and ``host_s`` — still the residual —
absorbs the in-flight window (the four segments still sum to ``wall_s``
exactly).  The hidden window is the overlap win:
``timeline["overlap_hidden_frac"] = inflight / (inflight + device)``
(≈0 at ``inflight=1``, → 1 when sched+host work fully hides device
waits), and ``timeline["mean_launch_gap_ms"]`` measures
``max(enqueue(next) - ready(prev), 0)`` over consecutive ok records —
the device idle window between launches, which ahead-of-time dispatch
drives toward zero.  At ``inflight=1`` every stamp reduces to the
pre-overlap decomposition (``device_s`` measured immediately after
dispatch; ``inflight_s`` ~ 0).

The old ``LMBackend.host_overhead_s`` scalar survives as a derived view:
it accumulates ``host assembly + dispatch`` exactly as before, and
``snapshot()["timeline"]["host_overhead_s"]`` derives the same quantity
from the segment totals.  Each ``LaunchRecord`` also carries batch
occupancy, structural copy/undo-log bytes, and — for decode-only
launches — a ``launch/roofline.py``-derived HBM bandwidth-utilization
estimate.

Exporters
---------
``chrome_trace``/``write_chrome_trace``  Chrome trace-event JSON,
    loadable in Perfetto / chrome://tracing: one process track per
    backend (launch slices with nested segment slices), one per query
    (per-document span slices with instant events), doc spans tied to
    launches via the ``launch`` arg on their instants.
``MetricRegistry.to_prometheus``  Prometheus text exposition format.
``Telemetry.snapshot``  plain-dict summary embedded by
    ``benchmarks/serve_engine.py --smoke`` (structural counters gated by
    ``check_regression.py``, timings ungated).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

LEVEL_OFF = "off"
LEVEL_COUNTERS = "counters"
LEVEL_TRACE = "trace"
LEVELS = (LEVEL_OFF, LEVEL_COUNTERS, LEVEL_TRACE)

# span event kinds (terminals intentionally equal scheduler's status
# strings so ``_finish`` can pass ``req.status`` straight through)
EV_SUBMIT = "submit"
EV_LAUNCH = "launch"
EV_ESCALATE = "escalate"
EV_RETRY = "retry"
EV_EVICT = "evict"
EV_QUARANTINE = "quarantine"
EV_PREFIX_HIT = "prefix_hit"
EV_COW_COPY = "cow_copy"
EV_FAULT = "fault"
# Runtime arena-sanitizer violation (analysis.sanitizer): emitted per
# owning request right before ``ArenaRaceError`` aborts the run.  The
# sanitizer's per-launch *check* counters deliberately live on a private
# registry (``ArenaSanitizer.counters()``) rather than the hub, so an
# ARENA_SANITIZE=1 run stays counter-inert vs. the shared benchmark
# baseline; only violations — which abort anyway — touch hub metrics
# (``serve_sanitizer_violations_total``) and the trace buffer.
EV_SANITIZER = "sanitizer_violation"
EV_RESOLVED = "resolved"
EV_FAILED = "failed"
EV_TIMED_OUT = "timed_out"
TERMINAL_EVENTS = (EV_RESOLVED, EV_FAILED, EV_TIMED_OUT)


class TraceBuffer:
    """Fixed-capacity ring buffer, drop-oldest on overflow.

    ``append`` past capacity overwrites the oldest item and increments
    ``dropped`` (the ``dropped_events`` counter of the tentpole
    contract); ``items()`` returns the surviving tail oldest-first.
    ``total`` counts every append ever made, so ``total - len(buf)``
    is the number of items no longer inspectable.
    """

    def __init__(self, capacity: int):
        assert capacity > 0, "TraceBuffer capacity must be positive"
        self.capacity = capacity
        self._buf: List[Any] = [None] * capacity
        self._next = 0
        self._len = 0
        self.dropped = 0
        self.total = 0

    def append(self, item: Any) -> None:
        if self._len == self.capacity:
            self.dropped += 1
        else:
            self._len += 1
        self._buf[self._next] = item
        self._next = (self._next + 1) % self.capacity
        self.total += 1

    def items(self) -> List[Any]:
        if self._len < self.capacity:
            return self._buf[: self._len]
        return self._buf[self._next:] + self._buf[: self._next]

    def clear(self) -> None:
        self._buf = [None] * self.capacity
        self._next = 0
        self._len = 0
        self.dropped = 0
        self.total = 0

    def __len__(self) -> int:
        return self._len


# --------------------------------------------------------------- metrics
def _label_key(labels: Dict[str, Any]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


def default_time_buckets() -> Tuple[float, ...]:
    """Geometric 1us..~34s bucket bounds (p50/p99 within ~2x resolution
    without storing samples), plus +inf."""
    return tuple(1e-6 * 2.0 ** i for i in range(25)) + (math.inf,)


class Histogram:
    """Fixed-bucket histogram: quantiles from cumulative bucket counts
    (linear interpolation inside the bucket), no sample storage."""

    __slots__ = ("bounds", "counts", "sum", "count", "max_seen")

    def __init__(self, bounds: Optional[Iterable[float]] = None):
        self.bounds = tuple(bounds) if bounds is not None \
            else default_time_buckets()
        assert self.bounds and self.bounds[-1] == math.inf, \
            "histogram bounds must end with +inf"
        self.counts = [0] * len(self.bounds)
        self.sum = 0.0
        self.count = 0
        self.max_seen = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        self.max_seen = max(self.max_seen, v)
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        target = max(q, 0.0) * self.count
        cum = 0
        lo = 0.0
        for bound, c in zip(self.bounds, self.counts):
            if c and cum + c >= target:
                hi = bound if math.isfinite(bound) else self.max_seen
                frac = (target - cum) / c
                # clamp: interpolation inside the top bucket must not
                # report a value no observation ever reached
                return min(lo + frac * max(hi - lo, 0.0), self.max_seen)
            cum += c
            if math.isfinite(bound):
                lo = bound
        return self.max_seen

    def p50(self) -> float:
        return self.quantile(0.50)

    def p99(self) -> float:
        return self.quantile(0.99)


class MetricRegistry:
    """Labeled counters/gauges/histograms with a hard series cap.

    Per-query and per-backend labels keep cardinality small in practice;
    the cap (``max_series``) bounds memory regardless — series past it
    land in a shared ``_overflow`` sink and ``dropped_series`` counts
    them, so callers never crash and the loss is observable.
    """

    def __init__(self, max_series: int = 4096):
        self.max_series = max_series
        self._metrics: Dict[str, Tuple[str, Dict[Tuple, Any]]] = {}
        self.dropped_series = 0
        self._overflow = {"counter": Counter(), "gauge": Gauge(),
                          "histogram": Histogram()}

    def _series(self, kind: str, name: str, labels: Dict[str, Any],
                factory) -> Any:
        typ, series = self._metrics.setdefault(name, (kind, {}))
        assert typ == kind, f"metric {name!r} re-registered as {kind}"
        key = _label_key(labels)
        m = series.get(key)
        if m is None:
            if self.series_count() >= self.max_series:
                self.dropped_series += 1
                return self._overflow[kind]
            m = factory()
            series[key] = m
        return m

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._series("counter", name, labels, Counter)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._series("gauge", name, labels, Gauge)

    def histogram(self, name: str, bounds: Optional[Iterable[float]] = None,
                  **labels: Any) -> Histogram:
        return self._series("histogram", name, labels,
                            lambda: Histogram(bounds))

    def series_count(self) -> int:
        return sum(len(s) for _, s in self._metrics.values())

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict view: ``name{k=v,...}`` -> value (histograms ->
        {count, sum, p50, p99})."""
        out: Dict[str, Any] = {}
        for name, (kind, series) in sorted(self._metrics.items()):
            for key, m in sorted(series.items()):
                lbl = ",".join(f"{k}={v}" for k, v in key)
                tag = f"{name}{{{lbl}}}" if lbl else name
                if kind == "histogram":
                    out[tag] = {"count": m.count, "sum": m.sum,
                                "p50": m.p50(), "p99": m.p99()}
                else:
                    out[tag] = m.value
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (text/plain; version 0.0.4)."""
        lines: List[str] = []
        for name, (kind, series) in sorted(self._metrics.items()):
            lines.append(f"# TYPE {name} {kind}")
            for key, m in sorted(series.items()):
                lbl = ",".join(f'{k}="{v}"' for k, v in key)
                if kind == "histogram":
                    cum = 0
                    for bound, c in zip(m.bounds, m.counts):
                        cum += c
                        le = "+Inf" if math.isinf(bound) else repr(bound)
                        sep = "," if lbl else ""
                        lines.append(
                            f'{name}_bucket{{{lbl}{sep}le="{le}"}} {cum}')
                    suffix = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}_sum{suffix} {m.sum}")
                    lines.append(f"{name}_count{suffix} {m.count}")
                else:
                    suffix = f"{{{lbl}}}" if lbl else ""
                    lines.append(f"{name}{suffix} {m.value}")
        return "\n".join(lines) + "\n"


# -------------------------------------------------------- launch timeline
@dataclass
class LaunchRecord:
    """One dispatched launch: signature, occupancy, copy traffic, and the
    scheduler/host/dispatch/device wall-time decomposition (the four
    segments are disjoint and sum to ``wall_s`` by construction)."""

    index: int                     # server launch index (attempt order)
    ts_start: float                # perf_counter at step entry
    model: str = ""
    op_id: Optional[str] = None
    bucket: int = 0
    cached_len: int = 0
    f_len: int = 0
    batch: int = 0                 # true documents in the launch
    width: int = 0                 # padded static launch width
    sched_s: float = 0.0
    host_s: float = 0.0
    dispatch_s: float = 0.0
    device_s: float = 0.0
    wall_s: float = 0.0
    copy_bytes: int = 0            # gather copy / paged undo-log bytes
    hbm_bytes: Optional[float] = None   # est. device bytes moved (decode)
    bw_util: Optional[float] = None     # fraction of the HBM roof achieved
    ok: bool = True
    error: Optional[str] = None
    # per-ticket overlap stamps (0.0 when the launch never dispatched)
    ts_enqueue: float = 0.0        # perf_counter entering the jit call
    ts_ready: float = 0.0          # perf_counter after block_until_ready
    inflight_s: float = 0.0        # dispatched->sync window hidden behind
    #                                other launches' sched/host work; NOT
    #                                a wall-clock segment (see docstring)

    @property
    def occupancy(self) -> float:
        return self.batch / self.width if self.width else 0.0

    @property
    def decode_only(self) -> bool:
        return self.cached_len == self.f_len

    def segments(self) -> Dict[str, float]:
        return {"sched": self.sched_s, "host": self.host_s,
                "dispatch": self.dispatch_s, "device": self.device_s}


# --------------------------------------------------------------- telemetry
_DOC_META_FACTOR = 4     # doc-meta map capacity, in trace capacities


class Telemetry:
    """The serving plane's observability hub (see module docstring).

    One instance per ``CascadeServer``, shared with its backends and the
    fault injector.  Every method is safe to call at any level — probes
    cheaply no-op below their level.
    """

    def __init__(self, level: str = LEVEL_COUNTERS,
                 trace_capacity: int = 65536,
                 timeline_capacity: int = 8192,
                 max_series: int = 4096):
        assert level in LEVELS, f"telemetry level must be one of {LEVELS}"
        self.level = level
        self.events = TraceBuffer(trace_capacity)
        self.launches = TraceBuffer(timeline_capacity)
        self.registry = MetricRegistry(max_series=max_series)
        self.idle_wait_s = 0.0
        # running totals survive ring overwrites
        self.event_kinds: Dict[str, int] = {}
        self.launch_total = 0
        self.failed_launch_total = 0
        self.sched_total_s = 0.0
        self.host_total_s = 0.0
        self.dispatch_total_s = 0.0
        self.device_total_s = 0.0
        self.wall_total_s = 0.0
        self.inflight_total_s = 0.0
        self._prev_ready = 0.0      # last ok record's ts_ready (gap histo)
        self._doc_meta: Dict[int, Tuple[int, int]] = {}

    # -- levels ----------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self.level != LEVEL_OFF

    @property
    def tracing(self) -> bool:
        return self.level == LEVEL_TRACE

    # -- span events -----------------------------------------------------
    def register_doc(self, rid: int, query_id: int, ext_id: int) -> None:
        """Map a request id to the caller-visible (query, doc) identity
        for exporters; bounded alongside the event ring."""
        if not self.tracing:
            return
        cap = _DOC_META_FACTOR * self.events.capacity
        if len(self._doc_meta) >= cap:
            for k in list(self._doc_meta)[: cap // 4]:
                del self._doc_meta[k]
        self._doc_meta[rid] = (query_id, ext_id)

    def event(self, rid: int, kind: str, ts: float,
              attrs: Optional[Dict[str, Any]] = None) -> None:
        if not self.tracing:
            return
        self.events.append((ts, rid, kind, attrs))
        self.event_kinds[kind] = self.event_kinds.get(kind, 0) + 1

    def spans(self) -> Dict[int, List[Tuple]]:
        """Group surviving events by request id, in recorded order."""
        out: Dict[int, List[Tuple]] = {}
        for ev in self.events.items():
            out.setdefault(ev[1], []).append(ev)
        return out

    def validate_spans(self, require_terminal: bool = True
                       ) -> Dict[str, Any]:
        """Well-formedness over every surviving span: ``submit`` first,
        exactly one terminal event (last), non-decreasing timestamps.
        Spans that lost events to ring overwrites are skipped (their
        head is gone by construction); ``dropped_events`` reports that
        separately."""
        spans = self.spans()
        violations: List[str] = []
        checked = 0
        partial = self.events.dropped > 0
        for rid, evs in spans.items():
            if partial and evs[0][2] != EV_SUBMIT:
                continue                     # head lost to the ring
            checked += 1
            if evs[0][2] != EV_SUBMIT:
                violations.append(f"rid {rid}: first event {evs[0][2]!r}, "
                                  "expected submit")
            terms = [i for i, e in enumerate(evs)
                     if e[2] in TERMINAL_EVENTS]
            if require_terminal and len(terms) != 1:
                violations.append(
                    f"rid {rid}: {len(terms)} terminal events")
            elif terms and terms[-1] != len(evs) - 1:
                violations.append(f"rid {rid}: events after terminal")
            ts = [e[0] for e in evs]
            if any(b < a for a, b in zip(ts, ts[1:])):
                violations.append(f"rid {rid}: non-monotone timestamps")
        return {"spans": len(spans), "checked": checked,
                "violations": violations, "ok": not violations}

    # -- metrics ---------------------------------------------------------
    def count(self, name: str, value: float = 1.0, **labels: Any) -> None:
        if self.enabled:
            self.registry.counter(name, **labels).inc(value)

    def set_gauge(self, name: str, value: float, **labels: Any) -> None:
        if self.enabled:
            self.registry.gauge(name, **labels).set(value)

    def observe(self, name: str, value: float, **labels: Any) -> None:
        if self.enabled:
            self.registry.histogram(name, **labels).observe(value)

    def add_idle_wait(self, seconds: float) -> None:
        self.idle_wait_s += seconds
        if self.enabled:
            self.registry.counter("serve_idle_wait_seconds_total"
                                  ).inc(seconds)

    # -- launch timeline -------------------------------------------------
    def record_launch(self, rec: LaunchRecord) -> None:
        if not self.enabled:
            return
        self.launches.append(rec)
        self.launch_total += 1
        if not rec.ok:
            self.failed_launch_total += 1
        self.sched_total_s += rec.sched_s
        self.host_total_s += rec.host_s
        self.dispatch_total_s += rec.dispatch_s
        self.device_total_s += rec.device_s
        self.wall_total_s += rec.wall_s
        self.inflight_total_s += rec.inflight_s
        if rec.ok and rec.ts_enqueue > 0.0:
            # gap histogram: device idle between one launch becoming
            # ready and the next entering the queue (0 under overlap)
            if self._prev_ready > 0.0:
                self.observe("serve_launch_gap_seconds",
                             max(rec.ts_enqueue - self._prev_ready, 0.0))
            self._prev_ready = rec.ts_ready
        be = rec.model or "?"
        self.count("serve_launches_total", 1, backend=be,
                   ok=str(rec.ok).lower())
        self.observe("serve_launch_wall_seconds", rec.wall_s, backend=be)
        for seg, v in rec.segments().items():
            self.observe("serve_launch_segment_seconds", v, segment=seg)
        if rec.bw_util is not None:
            self.observe("serve_decode_bw_utilization", rec.bw_util,
                         backend=be)

    def mean_launch_gap_s(self) -> float:
        """Mean device idle window between consecutive surviving launch
        records — the gap ROADMAP item 2's async dispatch targets.

        When both records carry per-ticket stamps the gap is
        ``max(enqueue(next) - ready(prev), 0)``: zero whenever the next
        launch was enqueued before the previous one's results were
        needed (the overlap win), so zeros COUNT toward the mean.
        Stamp-less records (never dispatched) fall back to the legacy
        wall-clock formula over positive gaps."""
        recs = [r for r in self.launches.items() if r.ok]
        gaps: List[float] = []
        for a, b in zip(recs, recs[1:]):
            if a.ts_ready > 0.0 and b.ts_enqueue > 0.0:
                gaps.append(max(b.ts_enqueue - a.ts_ready, 0.0))
            elif b.ts_start >= a.ts_start + a.wall_s:
                gaps.append(b.ts_start - (a.ts_start + a.wall_s))
        return sum(gaps) / len(gaps) if gaps else 0.0

    # -- summaries -------------------------------------------------------
    def segments_sum_ok(self, rel_tol: float = 0.05) -> bool:
        """Acceptance check: per-launch segments sum to the step wall
        time within ``rel_tol`` (they are disjoint sub-intervals, so
        this should hold exactly up to float addition)."""
        for r in self.launches.items():
            if not r.ok:
                continue
            s = r.sched_s + r.host_s + r.dispatch_s + r.device_s
            if abs(s - r.wall_s) > rel_tol * max(r.wall_s, 1e-9):
                return False
        return True

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict summary: ``counters`` are structural (gateable),
        ``timeline`` are wall-clock timings (never gated)."""
        # local import: roofline depends only on stdlib, but serving
        # modules must stay importable without the launch package cycle
        from ..launch.roofline import overlap_hidden_fraction
        utils = [r.bw_util for r in self.launches.items()
                 if r.bw_util is not None]
        return {
            "level": self.level,
            "counters": {
                "events_total": self.events.total,
                "events_by_kind": dict(sorted(self.event_kinds.items())),
                "dropped_events": self.events.dropped,
                "launch_records": self.launch_total,
                "failed_launch_records": self.failed_launch_total,
                "dropped_launch_records": self.launches.dropped,
                "metric_series": self.registry.series_count(),
                "dropped_metric_series": self.registry.dropped_series,
                "segments_sum_ok": self.segments_sum_ok(),
            },
            "timeline": {
                "sched_s": self.sched_total_s,
                "host_s": self.host_total_s,
                "dispatch_s": self.dispatch_total_s,
                "device_s": self.device_total_s,
                "wall_s": self.wall_total_s,
                # derived view of the pre-telemetry lumped scalar
                "host_overhead_s": self.host_total_s + self.dispatch_total_s,
                "idle_wait_s": self.idle_wait_s,
                "inflight_s": self.inflight_total_s,
                "overlap_hidden_frac": overlap_hidden_fraction(
                    self.inflight_total_s, self.device_total_s),
                "mean_launch_gap_ms": 1e3 * self.mean_launch_gap_s(),
                "decode_bw_util_mean": (sum(utils) / len(utils)
                                        if utils else 0.0),
            },
        }

    def clear(self) -> None:
        self.events.clear()
        self.launches.clear()
        self.registry = MetricRegistry(max_series=self.registry.max_series)
        self.idle_wait_s = 0.0
        self.event_kinds.clear()
        self.launch_total = 0
        self.failed_launch_total = 0
        self.sched_total_s = 0.0
        self.host_total_s = 0.0
        self.dispatch_total_s = 0.0
        self.device_total_s = 0.0
        self.wall_total_s = 0.0
        self.inflight_total_s = 0.0
        self._prev_ready = 0.0
        self._doc_meta.clear()


# --------------------------------------------------------------- exporters
def chrome_trace(tm: Telemetry) -> Dict[str, Any]:
    """Chrome trace-event JSON (Perfetto-loadable) from a telemetry hub.

    Track layout: one process per backend — launch slices ("X" events)
    with the four wall-time segments as nested child slices — and one
    process per query with one thread per document: the document's span
    is a slice from its first to last event, every span event an instant
    on it (``launch`` instants carry the launch index that ties them to
    the backend track).
    """
    recs = list(tm.launches.items())
    spans = tm.spans()
    stamps = [r.ts_start for r in recs]
    stamps += [evs[0][0] for evs in spans.values() if evs]
    t0 = min(stamps) if stamps else 0.0

    def us(t: float) -> float:
        return round((t - t0) * 1e6, 3)

    events: List[Dict[str, Any]] = []
    pids: Dict[str, int] = {}

    def pid_for(label: str) -> int:
        pid = pids.get(label)
        if pid is None:
            pid = len(pids) + 1
            pids[label] = pid
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": label}})
        return pid

    for r in recs:
        pid = pid_for(f"backend:{r.model or '?'}")
        args = {"launch": r.index, "op": r.op_id, "bucket": r.bucket,
                "cached_len": r.cached_len, "f_len": r.f_len,
                "batch": r.batch, "width": r.width,
                "occupancy": round(r.occupancy, 4),
                "copy_bytes": r.copy_bytes, "ok": r.ok}
        if r.bw_util is not None:
            args["bw_util"] = round(r.bw_util, 6)
        if r.error:
            args["error"] = r.error
        events.append({"ph": "X", "pid": pid, "tid": 0,
                       "name": f"launch {r.index} {r.op_id or ''}"
                               f"@{r.bucket}",
                       "cat": "launch", "ts": us(r.ts_start),
                       "dur": round(r.wall_s * 1e6, 3), "args": args})
        cursor = r.ts_start
        for seg, dur in r.segments().items():
            events.append({"ph": "X", "pid": pid, "tid": 0, "name": seg,
                           "cat": "segment", "ts": us(cursor),
                           "dur": round(dur * 1e6, 3)})
            cursor += dur

    for rid, evs in sorted(spans.items()):
        qid, ext = tm._doc_meta.get(rid, (-1, rid))
        pid = pid_for(f"query:{qid}" if qid >= 0 else "query:?")
        tid = rid
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": f"doc {ext}"}})
        start, end = evs[0][0], evs[-1][0]
        events.append({"ph": "X", "pid": pid, "tid": tid,
                       "name": f"doc {ext} [{evs[-1][2]}]", "cat": "span",
                       "ts": us(start),
                       "dur": round(max(end - start, 0.0) * 1e6, 3),
                       "args": {"rid": rid, "query": qid, "doc": ext,
                                "events": len(evs)}})
        for ts, _rid, kind, attrs in evs:
            events.append({"ph": "i", "pid": pid, "tid": tid, "name": kind,
                           "cat": "span", "s": "t", "ts": us(ts),
                           "args": dict(attrs or {})})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(tm: Telemetry, path: str) -> Dict[str, Any]:
    """Serialize ``chrome_trace`` to ``path``; returns the trace dict."""
    trace = chrome_trace(tm)
    with open(path, "w") as f:
        json.dump(trace, f)
    return trace
