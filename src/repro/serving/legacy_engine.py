"""Seed (pre-arena) serving data plane, kept as the benchmark baseline.

This is the engine's original per-document dict cache: every stage
re-stacks per-doc KV pytrees into a batch (``_stack_states``), runs the
model eagerly, and re-slices the batch back into per-doc entries
(``_slice_states``).  Mixed cached lengths within a bucket force a full
re-prefill (the ``have_cache`` check below).  ``benchmarks/serve_engine.py``
measures this path against the slot-arena engine; do not use it for new
work.

``host_overhead_s`` accumulates wall-clock spent in the Python data plane
(state stacking/slicing and token-batch assembly) so the benchmark can
report dispatch overhead without profiling machinery.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tasks import Cascade
from ..data.tokenizer import PAD, HashWordTokenizer, class_token
from .scheduler import ServeStats, make_buckets


def _path_key(p) -> str:
    return str(getattr(p, "key", getattr(p, "idx", p)))


def _leaf_batch_axis(path) -> int:
    """Batch axis of a state leaf: scan-stacked 'stages' leaves carry the
    repetition dim first (R, B, ...); everything else is (B, ...)."""
    return 1 if _path_key(path[0]) == "stages" else 0


def _stack_states(states_list):
    flat0, treedef = jax.tree_util.tree_flatten_with_path(states_list[0])
    flats = [jax.tree.leaves(s) for s in states_list]
    out = []
    for li, (path, _) in enumerate(flat0):
        ax = _leaf_batch_axis(path)
        out.append(jnp.stack([f[li] for f in flats], axis=ax))
    return jax.tree_util.tree_unflatten(treedef, out)


def _slice_states(states, i: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(states)
    out = [jnp.take(leaf, i, axis=_leaf_batch_axis(path))
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclass
class DictCacheLMBackend:
    """Seed backend: model + params with a per-doc KV state cache."""

    name: str
    model: Any                       # models.model.LM (or compatible)
    params: Any
    tokenizer: HashWordTokenizer
    rate_per_token: float = 1.0      # $ parity with the analytical model
    cached_discount: float = 0.5
    s_alloc: int = 4096
    # doc_id -> (padded_cached_len, true_cached_tokens, per-doc states)
    _cache: Dict[int, Tuple[int, int, Any]] = field(default_factory=dict)
    host_overhead_s: float = 0.0     # stack/slice/assembly wall-clock

    def reset(self) -> None:
        self._cache.clear()
        self.host_overhead_s = 0.0

    def cached_len(self, doc_id: int) -> int:
        e = self._cache.get(doc_id)
        return e[0] if e is not None else 0

    def release(self, doc_id: int) -> None:
        self._cache.pop(doc_id, None)

    def class_confidences(self, logits: jnp.ndarray, n_classes: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """Softmax over the class answer tokens -> (pred, conf)."""
        toks = [class_token(c) for c in range(n_classes)]
        cls_logits = np.asarray(logits, np.float64)[:, toks]
        z = cls_logits - cls_logits.max(axis=1, keepdims=True)
        probs = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
        return probs.argmax(axis=1), probs.max(axis=1)

    def run_stage(
        self,
        doc_ids: Sequence[int],
        doc_tokens: Mapping[int, np.ndarray],
        bucket: int,                             # padded full-doc length
        fraction: float,
        op_tokens: np.ndarray,
        n_classes: int,
    ) -> Tuple[np.ndarray, np.ndarray, int, int]:
        """Run (op, fraction) over one bucket batch (seed semantics).

        Returns (pred [B], conf [B], new_tokens, cached_tokens) with TRUE
        (unpadded) token counts for $ accounting.
        """
        B = len(doc_ids)
        f_len = max(int(math.ceil(bucket * fraction)), 1)
        entries = [self._cache.get(d) for d in doc_ids]
        have_cache = all(e is not None for e in entries) and \
            len({e[0] for e in entries if e is not None}) == 1
        c_len = entries[0][0] if have_cache and entries[0] else 0
        if have_cache and c_len > f_len:
            # cached prefix already covers this fraction: reuse as-is
            t0 = time.perf_counter()
            states = _stack_states([e[2] for e in entries])
            self.host_overhead_s += time.perf_counter() - t0
            q_off = c_len
            new_true = 0
            cached_true = sum(min(e[1], self._true_len(doc_tokens[d],
                                                       fraction))
                              for e, d in zip(entries, doc_ids))
        else:
            if not have_cache:
                c_len = 0
            n_new = f_len - c_len
            t0 = time.perf_counter()
            new_tok = np.full((B, max(n_new, 1)), PAD, np.int32)
            new_true = 0
            cached_true = 0
            for i, d in enumerate(doc_ids):
                toks = doc_tokens[d]
                seg = toks[min(c_len, len(toks)): min(f_len, len(toks))]
                new_tok[i, : len(seg)] = seg
                new_true += len(seg)
                cached_true += min(c_len, len(toks)) if have_cache else 0
            self.host_overhead_s += time.perf_counter() - t0
            if have_cache and c_len > 0:
                t0 = time.perf_counter()
                states = _stack_states([e[2] for e in entries])
                self.host_overhead_s += time.perf_counter() - t0
                _, states = self.model.extend(
                    self.params, {"tokens": jnp.asarray(new_tok)},
                    states, q_offset=c_len)
            else:
                _, states = self.model.prefill(
                    self.params, {"tokens": jnp.asarray(new_tok)},
                    s_alloc=self.s_alloc)
            q_off = f_len
            t0 = time.perf_counter()
            for i, d in enumerate(doc_ids):
                toks = doc_tokens[d]
                true_cached = min(f_len, len(toks))
                self._cache[d] = (f_len, true_cached,
                                  _slice_states(states, i))
            self.host_overhead_s += time.perf_counter() - t0

        # operation extension (doc-state snapshot survives untouched)
        opb = np.broadcast_to(op_tokens[None],
                              (B, len(op_tokens))).astype(np.int32)
        logits, _ = self.model.extend(
            self.params, {"tokens": jnp.asarray(opb)}, states, q_offset=q_off)
        pred, conf = self.class_confidences(logits, n_classes)
        return pred, conf, new_true + B * len(op_tokens), cached_true

    @staticmethod
    def _true_len(toks: np.ndarray, fraction: float) -> int:
        return max(int(math.ceil(len(toks) * fraction)), 1)


@dataclass
class SeedCascadeEngine:
    """The seed control loop: length-bucket batches only (no cached-length
    grouping, no slot arena).  Benchmark baseline twin of
    ``engine.CascadeEngine``; returns (pred, cost, stats)."""

    backends: Dict[str, DictCacheLMBackend]
    operations: Dict[str, str]
    n_classes: int
    batch_size: int = 8

    def run(self, cascade: Cascade, docs: Mapping[int, str],
            oracle_model: str = "oracle"):
        stats = ServeStats()
        tok: Dict[str, Dict[int, np.ndarray]] = {m: {} for m in self.backends}
        full_len: Dict[int, int] = {}
        for m, be in self.backends.items():
            be.reset()
            for d, text in docs.items():
                ids = np.asarray(be.tokenizer.encode(text), np.int32)
                tok[m][d] = ids
                full_len[d] = len(ids)
        unresolved = list(docs.keys())
        pred: Dict[int, int] = {}
        cost = 0.0
        stages = list(cascade.tasks) + [None]
        for si, task in enumerate(stages):
            if not unresolved:
                break
            if task is None:
                model, op_id, fraction, thr = oracle_model, "o_orig", 1.0, None
            else:
                model = task.config.model
                op_id = task.config.operation
                fraction = task.config.fraction
                thr = task.threshold_vector(self.n_classes)
            be = self.backends[model]
            op_toks = np.asarray(
                be.tokenizer.encode(self.operations[op_id]), np.int32)
            survivors = []
            for blen, ids in make_buckets(unresolved, full_len,
                                          self.batch_size):
                p, c, new_t, cached_t = be.run_stage(
                    ids, tok[model], blen, fraction, op_toks, self.n_classes)
                batch_cost = (new_t * be.rate_per_token
                              + cached_t * be.rate_per_token
                              * be.cached_discount)
                stats.record(si, len(ids), new_t, cached_t, batch_cost)
                stats.batches += 1
                cost += batch_cost
                for i, d in enumerate(ids):
                    if thr is None or c[i] >= thr[p[i]]:
                        pred[d] = int(p[i])
                    else:
                        survivors.append(d)
            unresolved = survivors
        return pred, cost, stats
