"""Persistent slot-based KV arena for the cascade serving engine.

One ``BucketArena`` per (backend, length bucket): a batched state pytree of
shape ``[n_slots + 1, ..., s_alloc, ...]`` preallocated on device.  Each
live document owns one slot for its lifetime (``scheduler.SlotAllocator``);
the last row is a *scratch slot* used to pad partial batches up to the
static launch width, so every launch addresses exactly ``B`` rows and
writes from padding land harmlessly in scratch.  The scratch row index
(``n_slots`` == ``capacity``) is the one legal out-of-document sentinel
of the kernel slot contract (``kernels.ops``): slot ids must lie in
``[0, capacity]``, duplicates are allowed only for scratch, and scratch
contents are never read unmasked.

Slot lifecycle
--------------
  alloc   first time a document's bucket is touched by any launch;
  fill    ``extend`` writes the fraction slice [cached_len, f_len) into the
          slot (cached_len == 0 is prefill-into-arena);
  reuse   later launches address the slot again.  On the PAGED data plane
          (Pallas runtimes) nothing is copied: the extend scatters only
          the new chunk's KV into the row and the kernels read the arena
          in place through slot ids in scalar-prefetch SMEM; operation
          suffixes decode in place behind a tiny [B, op_len] KV-window
          undo log (save -> decode -> restore), so the document prefix
          stays bitwise pristine.  The gather plane (reference / CPU)
          instead gathers the rows, extends the copy, scatters back, and
          drops the op-suffix copy — same contract, O(B * s_alloc) copy
          traffic per launch;
  free    the document exits the cascade; the slot returns to the free
          list and may be re-issued to a new document (streaming);
  evict   under slot-budget pressure the backend preempts the lowest-
          priority live slot (``LMBackend.evict_for_room``): the slot is
          freed exactly like an exit and the document re-enters the
          request queue with ``cached_len = 0`` — its next launch
          re-prefills over the recycled slot (``clear_slot``);
  retire  a bucket whose live-slot count stays zero for ``retire_after``
          launches is dropped wholesale (``LMBackend.retire``): the arena
          pytree is released so a drifting length mix does not pin device
          memory.  ``nbytes()`` is the byte accounting used by the budget.

The arena grows by doubling (device-side zero-pad concat) when a bucket's
live set exceeds capacity; growth preserves slot contents, so it is safe
mid-cascade.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _grow_leaf(leaf: jnp.ndarray, axis: int, extra: int) -> jnp.ndarray:
    pad_shape = list(leaf.shape)
    pad_shape[axis] = extra
    return jnp.concatenate([leaf, jnp.zeros(pad_shape, leaf.dtype)],
                           axis=axis)


@dataclass
class BucketArena:
    """Preallocated per-bucket KV/state arena plus host-side slot metadata."""

    model: Any                     # models.model.LM (or compatible)
    bucket: int                    # padded full-document length
    s_alloc: int                   # per-slot sequence allocation
    capacity: int                  # usable slots (scratch row excluded)
    states: Any = None             # pytree, batch dim = capacity + 1
    # storage dtype override for KV-cache leaves (bf16 compression of f32
    # models); None keeps the model compute dtype.  ``nbytes()`` bills the
    # stored dtype automatically (leaves carry it).
    kv_dtype: Any = None
    # host metadata, indexed by slot
    cached_len: np.ndarray = field(default=None)   # padded cached prefix
    true_len: np.ndarray = field(default=None)     # true cached doc tokens
    # ---- prefix sharing (op-first layout; engine.LMBackend drives these)
    # A PREFIX ROW is an ordinary arena row holding one operation's token
    # KV at positions [0, P), prefilled once per (backend, op, bucket) and
    # then pointed at by the leading block-table columns of every attached
    # document.  Rows are pinned while referenced (eviction skips them),
    # reclaimable at refcount zero, and dropped wholesale with the arena
    # (retire / arena loss) — the memo lives here, not on the backend.
    prefix_row: Dict[str, int] = field(default_factory=dict)   # op -> row
    prefix_refs: Dict[int, int] = field(default_factory=dict)  # row -> refs
    prefix_len: Dict[int, int] = field(default_factory=dict)   # row -> P
    slot_prefix: Dict[int, int] = field(default_factory=dict)  # slot -> row
    slot_op: Dict[int, str] = field(default_factory=dict)      # slot -> op
    growths: int = 0               # capacity doublings (telemetry counter:
    #                                each one is a device-side realloc+copy)
    # Optional runtime race detector (analysis.sanitizer.ArenaSanitizer,
    # installed by LMBackend when ARENA_SANITIZE=1 / sanitize=True).  The
    # arena reports row recycling and prefix pin/unpin transitions; the
    # backend brackets launches.  None (the default) costs nothing.
    sanitizer: Any = field(default=None, repr=False)

    def __post_init__(self) -> None:
        if self.states is None:
            if self.kv_dtype is None:       # compat: models without kv_dtype
                self.states = self.model.init_states(self.capacity + 1,
                                                     self.s_alloc)
            else:
                self.states = self.model.init_states(self.capacity + 1,
                                                     self.s_alloc,
                                                     kv_dtype=self.kv_dtype)
        if self.cached_len is None:
            self.cached_len = np.zeros(self.capacity, np.int64)
        if self.true_len is None:
            self.true_len = np.zeros(self.capacity, np.int64)

    @property
    def scratch_slot(self) -> int:
        return self.capacity

    def ensure_capacity(self, n_slots: int) -> None:
        """Grow (doubling) until at least ``n_slots`` usable slots exist."""
        if n_slots <= self.capacity:
            return
        new_cap = max(self.capacity, 1)
        while new_cap < n_slots:
            new_cap *= 2
        extra = new_cap - self.capacity
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.states)
        grown = [_grow_leaf(leaf, self.model._state_batch_axis(path), extra)
                 for path, leaf in flat]
        self.states = jax.tree_util.tree_unflatten(treedef, grown)
        self.cached_len = np.concatenate(
            [self.cached_len, np.zeros(extra, np.int64)])
        self.true_len = np.concatenate(
            [self.true_len, np.zeros(extra, np.int64)])
        self.capacity = new_cap
        self.growths += 1

    def clear_slot(self, slot: int) -> None:
        """Reset metadata when a slot is re-issued to a new document.

        Device state is NOT zeroed: the new document's prefill overwrites
        [0, f_len) and every read is masked by per-slot valid lengths, so
        stale KV past the new prefix is never visible.
        """
        if self.sanitizer is not None:
            self.sanitizer.note_clear(self.bucket, slot)
        self.cached_len[slot] = 0
        self.true_len[slot] = 0
        self.slot_op.pop(slot, None)
        assert slot not in self.slot_prefix, \
            f"slot {slot} re-issued while still attached to a prefix row"

    # ------------------------------------------------------ prefix sharing
    def attach_prefix(self, slot: int, op_id: str) -> int:
        """Point a document ``slot`` at ``op_id``'s prefix row (refcounted).

        Idempotent for the same (slot, op); a slot switching ops must be
        detached first (the engine invalidates the whole cache then).
        """
        row = self.prefix_row[op_id]
        prev = self.slot_prefix.get(slot)
        if prev is not None:
            assert prev == row and self.slot_op.get(slot) == op_id, \
                f"slot {slot} attached to op {self.slot_op.get(slot)!r}, " \
                f"asked for {op_id!r} (detach first)"
            return row
        self.slot_prefix[slot] = row
        self.slot_op[slot] = op_id
        self.prefix_refs[row] = self.prefix_refs.get(row, 0) + 1
        return row

    def detach_prefix(self, slot: int) -> None:
        """Drop a slot's prefix reference (slot released or invalidated)."""
        row = self.slot_prefix.pop(slot, None)
        self.slot_op.pop(slot, None)
        if row is not None:
            self.prefix_refs[row] -= 1
            assert self.prefix_refs[row] >= 0

    def unreferenced_prefix_ops(self):
        """Ops whose prefix row is currently pinned by no document —
        reclaimable under pressure (the memo re-prefills on next use)."""
        return [op for op, row in self.prefix_row.items()
                if self.prefix_refs.get(row, 0) == 0]

    def drop_prefix(self, op_id: str) -> int:
        """Forget an (unreferenced) op's prefix row; returns the row so
        the caller can free its slot."""
        row = self.prefix_row.pop(op_id)
        assert self.prefix_refs.get(row, 0) == 0, \
            f"prefix row {row} ({op_id!r}) dropped while referenced"
        self.prefix_refs.pop(row, None)
        self.prefix_len.pop(row, None)
        if self.sanitizer is not None:
            self.sanitizer.note_unpin(self.bucket, row)
        return row

    def nbytes(self) -> int:
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.states))
