"""AdamW with ZeRO-1 sharded moments, grad clipping, warmup-cosine schedule.

Pure-functional (init/update), no optax dependency.  Moment tensors reuse
each parameter's PartitionSpec plus ZeRO-1: the first unsharded dim
divisible by the data-axis size is additionally sharded over ``data``
(``distributed.sharding.zero_tree_pspecs``), so optimizer state adds
~2x params / dp_size per chip instead of 2x params.

Optional int8 gradient compression with error feedback for the cross-pod
all-reduce hop rides in ``train_loop`` (the optimizer itself sees
full-precision gradients).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray          # scalar int32
    mu: Any                    # first moments (pytree like params)
    nu: Any                    # second moments


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(
    cfg: OptimizerConfig,
    params: Any,
    grads: Any,
    state: OptState,
) -> Tuple[Any, OptState, dict]:
    """One AdamW step (f32 math, params cast back to their dtype)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step.astype(jnp.float32))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step)
        nu_hat = nu / (1 - cfg.b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        # decoupled weight decay (skip 1-d tensors: norms/biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics
