"""Distributed training step + driver.

``make_train_step`` builds the pjit'd step for any model in the zoo:
  * loss/grad over the global batch (microbatch gradient accumulation via
    ``lax.scan`` when ``accum_steps > 1``);
  * AdamW/ZeRO-1 update (moments sharded over data — see optimizer.py);
  * optional int8+error-feedback compression of the CROSS-POD gradient hop
    (the slowest link on the 2x16x16 mesh): in-pod reduction stays full
    precision (psum over "data"), the pod hop moves int8.

``TrainDriver`` is the fault-tolerant loop: periodic async checkpoints,
restart-from-latest, and heartbeat/straggler hooks (distributed.fault).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import (batch_pspec, tree_pspecs, tree_shardings,
                                    zero_tree_pspecs)
from .optimizer import OptState, OptimizerConfig, adamw_update, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1
    compress_pod_grads: bool = False
    opt: OptimizerConfig = OptimizerConfig()


def _split_microbatches(batch: Dict[str, jnp.ndarray], n: int):
    """[B, ...] -> [n, B/n, ...] per leaf."""
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_loss_fn(model):
    def loss_fn(params, batch):
        return model.loss(params, batch)
    return loss_fn


def make_train_step(
    model,
    mesh: Optional[Mesh],
    tc: TrainConfig = TrainConfig(),
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With a mesh, wrap in jax.jit with in/out shardings from the model's
    logical specs (see launch/train.py); the function itself is
    mesh-agnostic.
    """
    loss_fn = make_loss_fn(model)

    def grads_of(params, batch):
        if tc.accum_steps <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, grads

        micro = _split_microbatches(batch, tc.accum_steps)

        def body(carry, mb):
            acc_loss, acc_grads = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads)), None

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero), micro)
        inv = 1.0 / tc.accum_steps
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, grad_sum)

    def step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if tc.compress_pod_grads and mesh is not None \
                and "pod" in mesh.axis_names and mesh.shape["pod"] > 1:
            grads = _pod_compressed_grads(grads, mesh)
        params, opt_state, metrics = adamw_update(
            tc.opt, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def _pod_compressed_grads(grads, mesh: Mesh):
    """int8 + error-feedback mean-reduction across the pod axis.

    XLA already psums gradients over data/model axes inside the backward
    pass; when the batch is additionally sharded over "pod", the partial
    sums per pod differ and must be reduced.  Under SPMD the automatic
    reduction is part of the backward; to model the compressed wire format
    explicitly we reduce the pod axis in a shard_map with int8 payloads.
    Error feedback state is carried in-tensor (stateless approximation:
    residual is re-derived per step; see DESIGN §distributed-tricks).
    """
    from ..distributed.collectives import compressed_psum

    def reduce_leaf(g):
        def body(gl):
            red, _err = compressed_psum(gl, "pod")
            return red
        spec = P(*([None] * g.ndim))
        return jax.shard_map(
            body, mesh=mesh, in_specs=spec, out_specs=spec,
            check_vma=False)(g)

    return jax.tree.map(reduce_leaf, grads)


# ---------------------------------------------------------------------------
# fault-tolerant driver
# ---------------------------------------------------------------------------

@dataclass
class TrainDriver:
    """Checkpointed training loop with restart + straggler hooks."""

    step_fn: Callable
    checkpointer: Any = None            # checkpoint.Checkpointer
    ckpt_every: int = 100
    monitor: Any = None                 # fault.HeartbeatMonitor
    log_every: int = 10
    log_fn: Callable[[str], None] = print

    def run(self, params, opt_state, data_iter, n_steps: int,
            start_step: int = 0):
        """Runs n_steps; resumable via (params, opt_state, start_step)."""
        history = []
        t0 = time.time()
        for step in range(start_step, n_steps):
            batch = next(data_iter)
            if self.monitor is not None:
                self.monitor.beat("train", step)
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch)
            if step % self.log_every == 0:
                loss = float(metrics["loss"])
                history.append((step, loss))
                self.log_fn(f"step {step} loss {loss:.4f} "
                            f"({time.time() - t0:.1f}s)")
            if self.checkpointer is not None and step > 0 \
                    and step % self.ckpt_every == 0:
                self.checkpointer.save(
                    step, {"params": params, "opt": opt_state})
        if self.checkpointer is not None:
            self.checkpointer.save(n_steps, {"params": params,
                                             "opt": opt_state})
            self.checkpointer.wait()
        return params, opt_state, history

    def restore_latest(self, params_like, opt_like):
        """Restore (params, opt_state, step) from the newest checkpoint."""
        if self.checkpointer is None:
            return None
        latest = self.checkpointer.latest_step()
        if latest is None:
            return None
        tree = self.checkpointer.restore(
            latest, {"params": params_like, "opt": opt_like})
        return tree["params"], tree["opt"], latest
