"""Per-task threshold selection and filtering (paper Algorithm 2).

For each candidate task and each class c: find the LOWEST confidence
threshold t such that predictions of class c with confidence >= t have
accuracy >= alpha on the dev set (vs the oracle).  If no t works the class
is disabled (tau_c = inf).  A task survives filtering iff the selected
thresholds let it classify at least g * |D_dev| documents.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from .tasks import Task, TaskScores

DEFAULT_G = 0.10


MIN_SUPPORT = 5   # suffix sets smaller than this are too noisy to trust


def select_class_threshold(conf: np.ndarray, correct: np.ndarray,
                           alpha: float) -> Optional[float]:
    """Lowest t with accuracy(conf >= t) >= alpha, or None.

    conf/correct restricted to documents predicted as the class in question.
    Scans unique confidences ascending (paper's loop); vectorized via suffix
    means over the sorted order.
    """
    if conf.size == 0:
        return None
    order = np.argsort(conf, kind="stable")
    cs = conf[order]
    cc = correct[order].astype(np.float64)
    # suffix accuracy starting at index i (threshold = cs[i])
    suffix_correct = np.cumsum(cc[::-1])[::-1]
    suffix_count = np.arange(len(cs), 0, -1)
    suffix_acc = suffix_correct / suffix_count
    # first index of each unique threshold value
    uniq_first = np.ones(len(cs), bool)
    uniq_first[1:] = cs[1:] != cs[:-1]
    ok = uniq_first & (suffix_acc >= alpha) & (suffix_count >= MIN_SUPPORT)
    idx = np.argmax(ok) if ok.any() else -1
    if idx < 0:
        return None
    return float(cs[idx])


def find_task_thresholds(
    scores: TaskScores,
    oracle_pred: np.ndarray,
    n_classes: int,
    alpha: float,
    g: float = DEFAULT_G,
) -> Optional[Task]:
    """Algorithm 2: thresholds for one candidate task, or None to discard."""
    thresholds: Dict[int, float] = {}
    total = 0
    correct = scores.pred == oracle_pred
    for c in range(n_classes):
        mask = scores.pred == c
        t = select_class_threshold(scores.conf[mask], correct[mask], alpha)
        if t is None:
            continue
        thresholds[c] = t
        total += int(np.sum(mask & (scores.conf >= t)))
    if total >= g * len(oracle_pred) and thresholds:
        return Task(scores.config, thresholds)
    return None


def filter_tasks(
    all_scores: Sequence[TaskScores],
    oracle_pred: np.ndarray,
    n_classes: int,
    alpha: float,
    g: float = DEFAULT_G,
):
    """Apply Algorithm 2 over the candidate set; keep survivors."""
    out = []
    for s in all_scores:
        t = find_task_thresholds(s, oracle_pred, n_classes, alpha, g)
        if t is not None:
            out.append(t)
    return out
