"""End-to-end task-cascade construction (paper Algorithm 1) + baselines.

``build_task_cascade`` wires the pieces together: initial candidate set
(o_orig x models x fractions) -> agentic loop (assemble -> failure analysis
-> propose surrogates -> extend) -> optional statistical-guarantee pass
(split D_T / D_V, re-assemble on D_T, certify thresholds on D_V).

Baselines for the evaluation tables:
  * ``oracle_only_cost``
  * ``model_cascade``            — 2-Model Cascade (LOTUS-style per-class
                                   combined-accuracy thresholds)
  * variant knobs on BuildConfig — No Surrogates / Single-Iteration /
                                   No Filtering / Restructure(top-25%) /
                                   Selectivity Ordering (see §7.1.3)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .adjust import AdjustResult, adjust_thresholds
from .assembly import greedy_assembly, selectivity_ordering
from .cost_model import CascadeCostModel
from .simulation import FRACTIONS, O_ORIG, SimSubset, SimWorkload
from .surrogate import Agent, AgentContext, SyntheticAgent
from .tasks import (ORACLE, PROXY, Cascade, Task, TaskConfig, TaskScores,
                    run_cascade)
from .thresholds import filter_tasks


@dataclass(frozen=True)
class BuildConfig:
    alpha: float = 0.90
    delta: float = 0.25
    fractions: Tuple[float, ...] = FRACTIONS
    n_s: int = 5
    n_a: int = 3
    g: float = 0.10
    s_max: int = 5
    guarantee: bool = False
    lite: bool = False                  # surrogate candidates: proxy only
    use_surrogates: bool = True
    single_iteration: bool = False      # all surrogates in one batch
    ordering: str = "greedy"            # greedy | selectivity
    seed: int = 0


@dataclass
class BuildOutput:
    cascade: Cascade
    scores: Dict[TaskConfig, TaskScores]
    candidate_configs: List[TaskConfig]
    reverted_to_oracle: bool = False
    adjust: Optional[AdjustResult] = None
    rounds_run: int = 0


def _initial_configs(fractions: Sequence[float]) -> List[TaskConfig]:
    out = []
    for m in (PROXY, ORACLE):
        for f in fractions:
            if m == ORACLE and f == 1.0:
                continue                 # that's the terminal oracle task
            out.append(TaskConfig(m, O_ORIG, f))
    return out


def _eval_all(backend, configs) -> Dict[TaskConfig, TaskScores]:
    return {c: backend.eval_config(c) for c in configs}


def _assemble(backend, configs, cost_model, bc: BuildConfig):
    scores = _eval_all(backend, configs)
    eligible = filter_tasks(list(scores.values()), backend.oracle_pred,
                            backend.n_classes, bc.alpha, bc.g)
    if bc.ordering == "selectivity":
        cascade = selectivity_ordering(
            eligible, scores, backend.oracle_pred, cost_model,
            backend.n_classes, bc.alpha)
        trace = None
    else:
        cascade, trace = greedy_assembly(
            eligible, scores, backend.oracle_pred, cost_model,
            backend.n_classes, bc.alpha)
    return cascade, scores, eligible


def build_task_cascade(
    backend,                           # SimWorkload / SimSubset / LM engine
    bc: BuildConfig = BuildConfig(),
    agent: Optional[Agent] = None,
) -> BuildOutput:
    """Algorithm 1, end to end."""
    rng = np.random.default_rng(bc.seed)
    n = len(backend.oracle_pred)

    if bc.guarantee:
        perm = rng.permutation(n)
        train_idx, val_idx = perm[: n // 2], perm[n // 2:]
        train = backend.subset(train_idx)
        val = backend.subset(val_idx)
    else:
        train, val = backend, None

    if agent is None and bc.use_surrogates:
        agent = SyntheticAgent(
            pattern_coverage=backend.spec.pattern_coverage, seed=bc.seed)

    configs = _initial_configs(bc.fractions)
    cost_model = train.cost_model()

    n_rounds = 1 if (bc.single_iteration or not bc.use_surrogates) else bc.n_a
    n_s = bc.n_s * bc.n_a if bc.single_iteration else bc.n_s

    cascade, scores, eligible = _assemble(train, configs, cost_model, bc)
    best_cost = run_cascade(cascade, scores, train.oracle_pred, cost_model,
                            train.n_classes).total_cost()
    rounds_run = 0

    if bc.use_surrogates:
        previous_ops: List[str] = []
        for r in range(n_rounds):
            rounds_run = r + 1
            res = run_cascade(cascade, scores, train.oracle_pred, cost_model,
                              train.n_classes)
            failures = train.oracle_pred[res.oracle_mask()]
            stats = []
            selected = {t.config for t in cascade.tasks}
            for cfg in configs:
                st = {"config": cfg, "selected": cfg in selected}
                op = train.surrogates.get(cfg.operation)
                if op is not None:
                    st["family"] = op.family
                stats.append(st)
            ctx = AgentContext(
                round=r, failure_labels=failures, task_stats=stats,
                previous_ops=previous_ops, n_classes=train.n_classes)
            new_specs = agent.propose(ctx, n_s)
            for spec in new_specs:
                train.register_surrogate(spec)
                previous_ops.append(spec.op_id)
                models = (PROXY,) if bc.lite else (PROXY, ORACLE)
                for m in models:
                    for f in bc.fractions:
                        configs.append(TaskConfig(m, spec.op_id, f))
            cost_model = train.cost_model()     # new op token entries
            cascade, scores, eligible = _assemble(
                train, configs, cost_model, bc)
            cost = run_cascade(cascade, scores, train.oracle_pred,
                               cost_model, train.n_classes).total_cost()
            if cost >= best_cost * 0.999:
                break
            best_cost = cost

    if not bc.guarantee:
        return BuildOutput(cascade, scores, configs, rounds_run=rounds_run)

    # ---- guarantee pass: certify on the held-out validation split --------
    val_scores = _eval_all(val, [t.config for t in cascade.tasks])
    adj = adjust_thresholds(
        cascade, scores, val_scores, val.oracle_pred, val.cost_model(),
        train.n_classes, bc.alpha, bc.delta, bc.s_max,
        rng=np.random.default_rng(bc.seed + 1))
    if adj.cascade is None:
        return BuildOutput(Cascade([]), scores, configs,
                           reverted_to_oracle=True, adjust=adj,
                           rounds_run=rounds_run)
    return BuildOutput(adj.cascade, scores, configs, adjust=adj,
                       rounds_run=rounds_run)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def model_cascade(
    backend,
    alpha: float,
    *,
    guarantee: bool = False,
    delta: float = 0.25,
    s_max: int = 5,
    seed: int = 0,
) -> BuildOutput:
    """2-Model Cascade baseline (§7.1.2): proxy on the full doc with
    per-class thresholds set so that [proxy-above-t] + [oracle-below-t]
    combined accuracy >= alpha, minimizing cost."""
    rng = np.random.default_rng(seed)
    n = len(backend.oracle_pred)
    if guarantee:
        perm = rng.permutation(n)
        train_idx, val_idx = perm[: n // 2], perm[n // 2:]
        train, val = backend.subset(train_idx), backend.subset(val_idx)
    else:
        train, val = backend, None

    cfg = TaskConfig(PROXY, O_ORIG, 1.0)
    s = train.eval_config(cfg)
    oracle_pred = train.oracle_pred
    thresholds: Dict[int, float] = {}
    for c in range(train.n_classes):
        mask = s.pred == c
        if not mask.any():
            continue
        conf = s.conf[mask]
        correct = (s.pred[mask] == oracle_pred[mask]).astype(np.float64)
        order = np.argsort(conf, kind="stable")
        cs, cc = conf[order], correct[order]
        m = len(cs)
        # combined acc at threshold cs[i]: below-i docs go to the oracle
        # (always "correct" vs itself); above: proxy correctness.
        above_correct = np.cumsum(cc[::-1])[::-1]
        combined = (np.arange(m) + above_correct) / m
        ok = combined >= alpha
        if ok.any():
            thresholds[c] = float(cs[np.argmax(ok)])
    cascade = Cascade([Task(cfg, thresholds)])

    if not guarantee:
        return BuildOutput(cascade, {cfg: s}, [cfg])

    val_scores = {cfg: val.eval_config(cfg)}
    adj = adjust_thresholds(
        cascade, {cfg: s}, val_scores, val.oracle_pred, val.cost_model(),
        train.n_classes, alpha, delta, s_max,
        rng=np.random.default_rng(seed + 1))
    if adj.cascade is None:
        return BuildOutput(Cascade([]), {cfg: s}, [cfg],
                           reverted_to_oracle=True, adjust=adj)
    return BuildOutput(adj.cascade, {cfg: s}, [cfg], adjust=adj)


def restructure_top25(backend, alpha: float) -> BuildOutput:
    """Ablation: proxy(o_orig, f=0.25) -> oracle, thresholds via Alg 2."""
    cfg = TaskConfig(PROXY, O_ORIG, 0.25)
    s = backend.eval_config(cfg)
    from .thresholds import find_task_thresholds
    t = find_task_thresholds(s, backend.oracle_pred, backend.n_classes,
                             alpha, g=0.0)
    cascade = Cascade([t]) if t is not None else Cascade([])
    return BuildOutput(cascade, {cfg: s}, [cfg])


def evaluate_on(backend, out: BuildOutput) -> Dict[str, float]:
    """Run a built cascade on a (test) backend; report accuracy + cost."""
    scores = _eval_all(backend, [t.config for t in out.cascade.tasks])
    cm = backend.cost_model()
    res = run_cascade(out.cascade, scores, backend.oracle_pred, cm,
                      backend.n_classes)
    n = len(backend.oracle_pred)
    return {
        "accuracy": res.accuracy(backend.oracle_pred),
        "total_cost": res.total_cost(),
        "cost_per_doc": res.total_cost() / n,
        "oracle_cost": cm.oracle_only_cost(),
        "oracle_frac": float(np.mean(res.oracle_mask())),
        "n_tasks": len(out.cascade.tasks),
    }
