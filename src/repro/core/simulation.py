"""Calibrated synthetic workloads + LLM behaviour simulator.

The paper's evaluation runs against OpenAI APIs on eight document
workloads.  Offline, we reproduce the *regime* with a seeded generative
model calibrated to Table 2/3: per-workload document-length distributions,
class counts, proxy/oracle accuracy gaps, pattern (surrogate) coverage, and
confidence miscalibration ("scores heavily concentrated near 1", §3.2.4).

Latent document state (per doc i):
    y_i          true class
    delta_i      difficulty in [0,1] (Beta; most docs easy)
    n_tokens_i   LogNormal around the workload's avg words x 1.3
    rel_pos_i    positions of relevant chunks (uniform; small count)
    u_i[s]       per-surrogate-family uniform (pattern presence)

Model behaviour for task (m, o, f):
    coverage     fraction of relevant chunks inside the top-f of the
                 (re)ordered document — restructuring quality moves
                 relevant chunks to the front with prob ``reorder_recall``
    p_correct    logistic in (model skill, 1 - difficulty, coverage)
    pred         y_i w.p. p_correct else a wrong class
    conf         sigmoid(logit(p_correct) + N(0, conf_noise)) — correlated
                 with correctness but miscalibrated, concentrated near 1

All randomness is a pure function of (workload seed, doc index, config),
so repeated evaluation of a config returns identical scores (the cascade
builder re-executes candidates hundreds of times) and every experiment is
reproducible.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .cost_model import CascadeCostModel
from .tasks import ORACLE, PROXY, TaskConfig, TaskScores

O_ORIG = "o_orig"
FRACTIONS = (0.1, 0.25, 0.5, 1.0)


# ---------------------------------------------------------------------------
# Surrogate operation spec (what the simulator needs to "execute" one)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SurrogateSpec:
    op_id: str
    kind: str                        # keyword | class_specific | semantic | decomposition
    target_classes: Tuple[int, ...]  # classes it can emit
    coverage: float                  # P(pattern present | doc in target class)
    strength: float                  # P(detected | present & visible); proxy skill on it
    false_fire: float                # P(fires wrongly on non-target docs)
    op_tokens: int = 24
    family: int = 0                  # latent pattern family (ties presence
                                     # across surrogates probing the same cue)


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    n_classes: int
    avg_words: float
    corpus_size: int
    proxy_skill: float               # logit-scale skill on o_orig
    oracle_skill: float
    easy_frac: float                 # fraction of "easy" docs (controls the
                                     # selective-classification keep rate)
    relevance_spread: float          # 0 = concentrated, 1 = uniform relevance
    pattern_coverage: float          # max coverage achievable by surrogates
    reorder_recall: float            # learned-restructuring front-load quality
    rag_recall: float                # naive-RAG front-load quality (lower)
    conf_noise: float = 0.50
    cov_coef: float = 3.5            # logit penalty slope for missing context
    surrogate_reliability: float = 1.0   # scales surrogate fire correctness
    op_tokens: int = 60              # |o_orig| prompt tokens
    seed: int = 0


# Table 2 + observed Table 3 behaviour, compressed into generator knobs.
# easy_frac is set so the 2-Model Cascade baseline's escalation fraction at
# alpha=0.9 lands near the paper's implied values (MC$/oracle$ - proxy rate).
WORKLOADS: Dict[str, WorkloadSpec] = {
    "agnews": WorkloadSpec("agnews", 4, 37, 128_000, proxy_skill=3.4,
                           oracle_skill=4.0, easy_frac=0.97,
                           relevance_spread=0.9, pattern_coverage=0.45,
                           reorder_recall=0.55, rag_recall=0.50,
                           cov_coef=3.5, seed=11),
    "court": WorkloadSpec("court", 2, 3_700, 36_000, proxy_skill=2.2,
                          oracle_skill=3.4, easy_frac=0.74,
                          relevance_spread=0.25, pattern_coverage=0.60,
                          reorder_recall=0.88, rag_recall=0.55,
                          cov_coef=3.0, seed=12),
    "enron": WorkloadSpec("enron", 2, 1_500, 500_000, proxy_skill=3.6,
                          oracle_skill=4.0, easy_frac=0.96,
                          relevance_spread=0.15, pattern_coverage=0.85,
                          reorder_recall=0.97, rag_recall=0.70,
                          cov_coef=1.5, seed=13),
    "fever": WorkloadSpec("fever", 2, 5_100, 185_000, proxy_skill=3.3,
                          oracle_skill=3.9, easy_frac=0.96,
                          relevance_spread=0.75, pattern_coverage=0.18,
                          reorder_recall=0.80, rag_recall=0.45,
                          cov_coef=3.0, surrogate_reliability=0.75, seed=14),
    "games": WorkloadSpec("games", 2, 1_100, 6_400_000, proxy_skill=2.4,
                          oracle_skill=3.4, easy_frac=0.80,
                          relevance_spread=0.45, pattern_coverage=0.20,
                          reorder_recall=0.80, rag_recall=0.55, conf_noise=0.8,
                          cov_coef=3.5, surrogate_reliability=0.90, seed=15),
    "legal": WorkloadSpec("legal", 2, 8_000, 510, proxy_skill=2.0,
                          oracle_skill=3.4, easy_frac=0.70,
                          relevance_spread=0.10, pattern_coverage=0.70,
                          reorder_recall=0.90, rag_recall=0.60,
                          cov_coef=2.5, seed=16),
    "pubmed": WorkloadSpec("pubmed", 6, 3_100, 133_000, proxy_skill=3.5,
                           oracle_skill=4.0, easy_frac=0.96,
                           relevance_spread=0.35, pattern_coverage=0.35,
                           reorder_recall=0.85, rag_recall=0.55,
                           cov_coef=3.0, seed=17),
    "wiki_talk": WorkloadSpec("wiki_talk", 2, 900, 125_000, proxy_skill=3.4,
                              oracle_skill=3.9, easy_frac=0.95,
                              relevance_spread=0.40, pattern_coverage=0.30,
                              reorder_recall=0.70, rag_recall=0.55,
                              cov_coef=2.5, seed=18),
}

WORDS_PER_TOKEN = 0.75
N_REL_CHUNKS = 3
N_FAMILIES = 8        # latent pattern families per workload


def _unit(seed: int, *keys) -> np.ndarray:
    """Deterministic uniforms from a hash of (seed, keys).  Last key may be
    an int n -> returns n values."""
    *tags, n = keys
    h = hashlib.blake2b(
        ("|".join(map(str, (seed,) + tuple(tags)))).encode(),
        digest_size=8).digest()
    rng = np.random.default_rng(int.from_bytes(h, "little"))
    return rng.random(n)


@dataclass
class SimWorkload:
    """A sampled document set + deterministic model simulator."""

    spec: WorkloadSpec
    n_docs: int
    reorder_mode: str = "learned"    # learned | rag | none
    _score_cache: Dict[Tuple, TaskScores] = field(default_factory=dict)
    surrogates: Dict[str, SurrogateSpec] = field(default_factory=dict)

    def __post_init__(self):
        s = self.spec
        rng = np.random.default_rng(s.seed)
        n = self.n_docs
        self.y = rng.integers(0, s.n_classes, n)
        # difficulty mixture: most docs easy, a hard tail the proxy cannot
        # confidently resolve (controls the risk-coverage curve)
        is_easy = rng.random(n) < s.easy_frac
        self.difficulty = np.where(
            is_easy, rng.beta(1.0, 20.0, n), rng.beta(6.0, 2.0, n))
        avg_tokens = s.avg_words / WORDS_PER_TOKEN
        self.doc_tokens = np.maximum(
            rng.lognormal(np.log(avg_tokens), 0.5, n), 16).astype(np.int64)
        # relevant chunk positions as quantiles in [0, 1]
        conc = max(s.relevance_spread, 0.02)
        self.rel_pos = rng.random((n, N_REL_CHUNKS)) ** (1.0 / conc) \
            if conc < 1.0 else rng.random((n, N_REL_CHUNKS))
        # pattern-family presence per doc
        self.family_u = rng.random((n, N_FAMILIES))
        # oracle full-doc predictions ARE the accuracy target
        self.oracle_pred = self._predict(
            ORACLE, O_ORIG, 1.0, force_exact=True)[0]

    # ------------------------------------------------------------- coverage
    def _recall(self) -> float:
        s = self.spec
        return {"learned": s.reorder_recall, "rag": s.rag_recall,
                "none": -1.0}[self.reorder_mode]

    def coverage(self, fraction: float) -> np.ndarray:
        """Fraction of relevant chunks visible in the top-f of the doc."""
        if fraction >= 1.0:
            return np.ones((self.n_docs,))
        recall = self._recall()
        if recall < 0:
            # no reordering: chunk visible iff its natural position < f
            vis = self.rel_pos < fraction
        else:
            # reordered: a relevant chunk lands in front w.p. recall,
            # mildly degraded at tiny fractions (front-of-front ranking
            # noise); else it stays at its natural position
            eff = recall * (fraction ** 0.05)
            u = _unit(self.spec.seed, "reorder", self.reorder_mode,
                      self.n_docs * N_REL_CHUNKS).reshape(
                self.n_docs, N_REL_CHUNKS)
            vis = (u < eff) | (self.rel_pos < fraction)
        return vis.mean(axis=1)

    # ------------------------------------------------------------- predict
    def _conf(self, p_correct: np.ndarray, tag: str) -> np.ndarray:
        s = self.spec
        z = np.log(np.maximum(p_correct, 1e-6)
                   / np.maximum(1 - p_correct, 1e-6))
        noise = np.asarray(_unit(s.seed, "confn", tag, self.n_docs))
        gauss = np.sqrt(2.0) * _erfinv(2 * noise - 1)
        conf = 1.0 / (1.0 + np.exp(-(z + s.conf_noise * gauss)))
        return np.clip(conf, 1.0 / s.n_classes, 1.0)

    def _predict(self, model: str, op: str, fraction: float,
                 force_exact: bool = False):
        s = self.spec
        skill = s.oracle_skill if model == ORACLE else s.proxy_skill
        cov = self.coverage(fraction)
        if op == O_ORIG:
            z = skill * (1.0 - 2.0 * self.difficulty) + s.cov_coef * (cov - 1.0)
            p = 1.0 / (1.0 + np.exp(-z))
            p = np.maximum(p, 1.0 / s.n_classes + 0.02)   # chance floor
            if force_exact:
                pred = np.where(
                    _unit(s.seed, "oracle_gt", self.n_docs) < p,
                    self.y, self._wrong(self.y, "oracle_gt_w"))
                return pred, np.ones((self.n_docs,))
            u = _unit(s.seed, "pred", model, op, fraction, self.n_docs)
            # "correct" = matches the oracle full-doc label
            target = self.oracle_pred
            pred = np.where(u < p, target, self._wrong(target, f"{model}{op}{fraction}"))
            conf = self._conf(p, f"{model}|{op}|{fraction}")
            return pred, conf
        # surrogate operation
        spec = self.surrogates[op]
        present = self.family_u[:, spec.family] < spec.coverage
        in_target = np.isin(self.oracle_pred, spec.target_classes)
        visible = cov > 0.45            # the pattern sits in relevant chunks
        eff = skill - s.proxy_skill if model == PROXY else 1.5
        fire_p = np.where(
            present & in_target & visible,
            spec.strength * (1.0 / (1.0 + np.exp(-(2.5 + eff)))),
            spec.false_fire)
        u = _unit(s.seed, "fire", model, op, fraction, self.n_docs)
        fires = u < fire_p
        # when it fires, it emits (mostly) the right target class
        right_p = (0.93 + 0.06 * spec.strength) \
            * (0.82 + 0.18 * s.surrogate_reliability)
        u2 = _unit(s.seed, "right", model, op, fraction, self.n_docs)
        tc = np.asarray(spec.target_classes)
        tgt_match = np.where(in_target, self.oracle_pred,
                             tc[(_unit(s.seed, "tclass", op,
                                       self.n_docs) * len(tc)).astype(int)])
        pred_fire = np.where(u2 < right_p, tgt_match,
                             self._wrong(tgt_match, f"sf{op}"))
        pred_nofire = self._wrong(self.oracle_pred, f"nf{op}{model}{fraction}")
        pred = np.where(fires, pred_fire, pred_nofire)
        p_conf = np.where(fires, np.where(u2 < right_p, 0.95, 0.70), 0.25)
        conf = self._conf(p_conf, f"{model}|{op}|{fraction}")
        return pred, conf

    def _wrong(self, target: np.ndarray, tag: str) -> np.ndarray:
        s = self.spec
        u = _unit(s.seed, "wrong", tag, self.n_docs)
        off = 1 + (u * (s.n_classes - 1)).astype(np.int64)
        return (target + off) % s.n_classes

    # ---------------------------------------------------------------- API
    def eval_config(self, cfg: TaskConfig) -> TaskScores:
        key = cfg.key() + (self.reorder_mode,)
        if key not in self._score_cache:
            pred, conf = self._predict(cfg.model, cfg.operation, cfg.fraction)
            self._score_cache[key] = TaskScores(cfg, pred, conf)
        return self._score_cache[key]

    def register_surrogate(self, spec: SurrogateSpec):
        self.surrogates[spec.op_id] = spec

    def op_token_table(self) -> Dict[str, int]:
        t = {O_ORIG: self.spec.op_tokens}
        t.update({k: v.op_tokens for k, v in self.surrogates.items()})
        return t

    def cost_model(self) -> CascadeCostModel:
        return CascadeCostModel(self.doc_tokens, self.op_token_table())

    @property
    def n_classes(self) -> int:
        return self.spec.n_classes

    def subset(self, idx: np.ndarray) -> "SimSubset":
        return SimSubset(self, idx)


@dataclass
class SimSubset:
    """A view of a SimWorkload restricted to index set ``idx`` (dev/val)."""
    base: SimWorkload
    idx: np.ndarray

    def eval_config(self, cfg: TaskConfig) -> TaskScores:
        s = self.base.eval_config(cfg)
        return TaskScores(cfg, s.pred[self.idx], s.conf[self.idx])

    @property
    def oracle_pred(self) -> np.ndarray:
        return self.base.oracle_pred[self.idx]

    @property
    def n_classes(self) -> int:
        return self.base.n_classes

    def cost_model(self) -> CascadeCostModel:
        return CascadeCostModel(self.base.doc_tokens[self.idx],
                                self.base.op_token_table())

    def register_surrogate(self, spec: SurrogateSpec):
        self.base.register_surrogate(spec)

    @property
    def surrogates(self):
        return self.base.surrogates

    @property
    def spec(self):
        return self.base.spec

    def subset(self, idx: np.ndarray) -> "SimSubset":
        return SimSubset(self.base, self.idx[idx])


def _erfinv(x: np.ndarray) -> np.ndarray:
    """Vectorized inverse error function (Winitzki approximation)."""
    a = 0.147
    ln = np.log(np.maximum(1 - x * x, 1e-12))
    t1 = 2.0 / (np.pi * a) + ln / 2.0
    return np.sign(x) * np.sqrt(np.sqrt(t1 * t1 - ln / a) - t1)


def make_workload(name: str, n_docs: int = 1000, seed_offset: int = 0,
                  reorder_mode: str = "learned") -> SimWorkload:
    spec = WORKLOADS[name]
    if seed_offset:
        spec = replace(spec, seed=spec.seed + 1000 * seed_offset)
    return SimWorkload(spec, n_docs, reorder_mode=reorder_mode)
