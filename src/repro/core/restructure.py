"""Document restructuring (paper §4): granularity search, oracle-supervised
relevance classifier, and chunk reordering.

Pipeline (faithful to §4):
  1. split documents into 80-char lines;
  2. oracle labels minimal relevant line ranges per dev document;
  3. merged ranges are checked: does the oracle's answer on the REDUCED
     document match its full-document answer on >= alpha of the dev set?
     if not, expand every range by one line each side (<= e=3 times);
  4. chunk granularity := average merged-range length;
  5. build an oracle-labeled chunk dataset (relevant = oracle-pointed
     chunks; irrelevant = non-overlapping s-line windows), upsample
     positives, embed chunks, fit a logistic regression initialized at the
     operation embedding with Adam + early stopping on held-out F1;
  6. at serving time: score chunks (fused Pallas mean-pool+logistic kernel,
     ``kernels/relevance_score``), sort descending, concatenate.

Embeddings are hashed word vectors (deterministic, offline) standing in
for text-embedding-3-small; the classifier, training loop, and kernel
path are the real thing.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.documents import SyntheticDoc
from ..kernels import ops

EMBED_DIM = 256
MAX_CHUNK_WORDS = 64


# ---------------------------------------------------------------------------
# line / range plumbing
# ---------------------------------------------------------------------------

def split_lines(text: str, width: int = 80) -> List[str]:
    out = []
    for raw in text.split("\n"):
        while len(raw) > width:
            out.append(raw[:width])
            raw = raw[width:]
        out.append(raw)
    return out


def merge_ranges(ranges: Sequence[Tuple[int, int]]) -> List[Tuple[int, int]]:
    """Merge OVERLAPPING inclusive line ranges.

    The paper's §4 worked example keeps [22,26],[27,31] separate (adjacent)
    and merges only once they overlap ([21,27],[26,32] -> [21,32]), so
    adjacency alone does not merge.
    """
    if not ranges:
        return []
    rs = sorted(ranges)
    out = [list(rs[0])]
    for s, e in rs[1:]:
        if s <= out[-1][1]:
            out[-1][1] = max(out[-1][1], e)
        else:
            out.append([s, e])
    return [tuple(r) for r in out]


def expand_ranges(ranges: Sequence[Tuple[int, int]], n_lines: int
                  ) -> List[Tuple[int, int]]:
    return merge_ranges([(max(s - 1, 0), min(e + 1, n_lines - 1))
                         for s, e in ranges])


class OracleLabeler(Protocol):
    """The oracle model's two §4 roles."""

    def relevant_ranges(self, doc: SyntheticDoc) -> List[Tuple[int, int]]:
        ...

    def answer(self, doc: SyntheticDoc,
               lines: Optional[Sequence[int]] = None) -> int:
        ...


@dataclass
class SyntheticOracle:
    """Knows the planted relevance (with optional labeling noise)."""
    noise: float = 0.0
    seed: int = 0

    def relevant_ranges(self, doc):
        rng = np.random.default_rng(self.seed + doc.doc_id)
        out = []
        for r in doc.relevant_lines:
            if rng.random() < self.noise:
                continue
            jitter = int(rng.integers(-1, 2)) if self.noise > 0 else 0
            s = int(np.clip(r + jitter, 0, len(doc.lines) - 1))
            out.append((s, s))
        return merge_ranges(out) or [(0, 0)]

    def answer(self, doc, lines=None):
        if lines is None:
            return doc.label
        has_rel = any(r in set(lines) for r in doc.relevant_lines)
        if has_rel:
            return doc.label
        rng = np.random.default_rng(self.seed + 31 * doc.doc_id)
        return int(rng.integers(0, 2)) if rng.random() < 0.8 else doc.label


# ---------------------------------------------------------------------------
# granularity search (§4 steps 1-5)
# ---------------------------------------------------------------------------

def determine_granularity(
    docs: Sequence[SyntheticDoc],
    oracle: OracleLabeler,
    alpha: float,
    max_expansions: int = 3,
) -> Tuple[int, List[List[Tuple[int, int]]]]:
    """Returns (chunk granularity s, per-doc final merged ranges)."""
    per_doc = [merge_ranges(oracle.relevant_ranges(d)) for d in docs]
    for expansion in range(max_expansions + 1):
        correct = 0
        for d, ranges in zip(docs, per_doc):
            lines = [li for s, e in ranges for li in range(s, e + 1)]
            if oracle.answer(d, lines) == oracle.answer(d):
                correct += 1
        if correct >= alpha * len(docs) or expansion == max_expansions:
            break
        per_doc = [expand_ranges(r, len(d.lines))
                   for d, r in zip(docs, per_doc)]
    lengths = [e - s + 1 for ranges in per_doc for s, e in ranges]
    gran = max(int(round(float(np.mean(lengths)))), 1) if lengths else 1
    return gran, per_doc


# ---------------------------------------------------------------------------
# hashed word embeddings (offline stand-in for text-embedding-3-small)
# ---------------------------------------------------------------------------

def _word_vec(word: str, dim: int = EMBED_DIM) -> np.ndarray:
    h = hashlib.blake2b(word.lower().encode(), digest_size=8).digest()
    rng = np.random.default_rng(int.from_bytes(h, "little"))
    return rng.standard_normal(dim).astype(np.float32) / np.sqrt(dim)


@dataclass
class HashEmbedder:
    dim: int = EMBED_DIM
    _cache: dict = field(default_factory=dict)

    def word(self, w: str) -> np.ndarray:
        if w not in self._cache:
            self._cache[w] = _word_vec(w, self.dim)
        return self._cache[w]

    def tokens(self, text: str, max_words: int = MAX_CHUNK_WORDS
               ) -> Tuple[np.ndarray, int]:
        """Per-word embeddings [max_words, dim] + true length."""
        words = text.split()[:max_words]
        out = np.zeros((max_words, self.dim), np.float32)
        for i, w in enumerate(words):
            out[i] = self.word(w)
        return out, max(len(words), 1)

    def pooled(self, text: str) -> np.ndarray:
        toks, n = self.tokens(text)
        return toks[:n].mean(axis=0)


# ---------------------------------------------------------------------------
# relevance classifier (JAX logistic regression, §4)
# ---------------------------------------------------------------------------

def _f1(pred: np.ndarray, y: np.ndarray) -> float:
    tp = float(np.sum((pred == 1) & (y == 1)))
    fp = float(np.sum((pred == 1) & (y == 0)))
    fn = float(np.sum((pred == 0) & (y == 1)))
    if tp == 0:
        return 0.0
    p, r = tp / (tp + fp), tp / (tp + fn)
    return 2 * p * r / (p + r)


def train_relevance_classifier(
    x_train: np.ndarray, y_train: np.ndarray,
    x_test: np.ndarray, y_test: np.ndarray,
    init_w: Optional[np.ndarray] = None,
    lr: float = 0.3, epochs: int = 800, patience: int = 80,
    upsample: bool = True, seed: int = 0,
) -> Tuple[np.ndarray, float, float]:
    """Binary logistic regression: Adam + early stopping on held-out F1.

    Weights initialize at the operation embedding (paper §4) so the model
    starts as "similarity to the operation" and learns corrections.
    Returns (weights [D], bias, best F1).
    """
    rng = np.random.default_rng(seed)
    if upsample and 0 < y_train.sum() < len(y_train):
        pos = np.where(y_train == 1)[0]
        neg = np.where(y_train == 0)[0]
        if len(pos) < len(neg):
            extra = rng.choice(pos, size=len(neg) - len(pos), replace=True)
            keep = np.concatenate([np.arange(len(y_train)), extra])
            x_train, y_train = x_train[keep], y_train[keep]

    x = jnp.asarray(x_train, jnp.float32)
    y = jnp.asarray(y_train, jnp.float32)
    params = (jnp.asarray(init_w if init_w is not None
                          else np.zeros(x.shape[1]), jnp.float32),
              jnp.zeros((), jnp.float32))

    def loss_fn(params):
        w, b = params
        logits = x @ w + b
        # numerically stable BCE-with-logits
        return jnp.mean(jnp.maximum(logits, 0) - logits * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def adam_step(params, m, v, t):
        _, g = grad_fn(params)

        def upd(p, g, m, v):
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * g * g
            mh = m / (1 - 0.9 ** t)
            vh = v / (1 - 0.999 ** t)
            return p - lr * mh / (jnp.sqrt(vh) + 1e-8), m, v

        (w, mw, vw), (b, mb, vb) = (
            upd(params[0], g[0], m[0], v[0]),
            upd(params[1], g[1], m[1], v[1]))
        return (w, b), (mw, mb), (vw, vb)

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    best = (np.asarray(params[0]), float(params[1]), -1.0)
    stale = 0
    for epoch in range(1, epochs + 1):
        params, m, v = adam_step(params, m, v, epoch)
        w_np, b_np = np.asarray(params[0]), float(params[1])
        pred = (x_test @ w_np + b_np > 0).astype(int)
        f1 = _f1(pred, y_test)
        if f1 > best[2]:
            best = (w_np, b_np, f1)
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
    return best


# ---------------------------------------------------------------------------
# end-to-end restructurer
# ---------------------------------------------------------------------------

@dataclass
class DocumentRestructurer:
    """Fit on D_dev with the oracle; reorder any document at serving time."""

    operation_text: str
    alpha: float = 0.9
    embedder: HashEmbedder = field(default_factory=HashEmbedder)
    granularity: int = 1
    w: Optional[np.ndarray] = None
    b: float = 0.0
    f1: float = 0.0
    impl: str = "xla"                    # relevance-score kernel impl

    def chunks_of(self, doc: SyntheticDoc) -> List[str]:
        s = self.granularity
        return [" ".join(doc.lines[i: i + s])
                for i in range(0, len(doc.lines), s)]

    def fit(self, docs: Sequence[SyntheticDoc], oracle: OracleLabeler,
            test_split: float = 0.3, seed: int = 0) -> "DocumentRestructurer":
        self.granularity, per_doc = determine_granularity(
            docs, oracle, self.alpha)
        s = self.granularity
        xs, ys, doc_of = [], [], []
        for d, ranges in zip(docs, per_doc):
            rel_starts = {max(0, st) for st, _ in ranges}
            rel_lines = {li for st, e in ranges for li in range(st, e + 1)}
            # relevant: s-line chunk at each oracle-pointed start
            for st in rel_starts:
                text = " ".join(d.lines[st: st + s])
                xs.append(self.embedder.pooled(text))
                ys.append(1)
                doc_of.append(d.doc_id)
            # irrelevant: non-overlapping windows that avoid relevant lines
            for w0 in range(0, len(d.lines) - s + 1, s):
                if any(li in rel_lines for li in range(w0, w0 + s)):
                    continue
                text = " ".join(d.lines[w0: w0 + s])
                xs.append(self.embedder.pooled(text))
                ys.append(0)
                doc_of.append(d.doc_id)
        x = np.stack(xs)
        y = np.asarray(ys)
        # split by document (the paper partitions D_dev into D_train/D_test)
        rng = np.random.default_rng(seed)
        doc_ids = np.unique(doc_of)
        test_docs = set(rng.choice(
            doc_ids, size=max(int(len(doc_ids) * test_split), 1),
            replace=False).tolist())
        is_test = np.asarray([d in test_docs for d in doc_of])
        init_w = self.embedder.pooled(self.operation_text)
        self.w, self.b, self.f1 = train_relevance_classifier(
            x[~is_test], y[~is_test], x[is_test], y[is_test],
            init_w=init_w, seed=seed)
        return self

    def score_chunks(self, doc: SyntheticDoc) -> np.ndarray:
        """Chunk relevance scores via the fused kernel path."""
        chunks = self.chunks_of(doc)
        toks, lens = zip(*(self.embedder.tokens(c) for c in chunks))
        x = np.stack(toks)                                  # [C, T, D]
        lengths = np.asarray(lens, np.int32)
        # pad chunk count so the kernel's block shape divides
        c = x.shape[0]
        pad = (-c) % 8
        if pad:
            x = np.concatenate([x, np.zeros((pad,) + x.shape[1:], x.dtype)])
            lengths = np.concatenate([lengths, np.ones(pad, np.int32)])
        scores = ops.relevance_score(
            jnp.asarray(x), jnp.asarray(lengths),
            jnp.asarray(self.w, jnp.float32),
            jnp.asarray(self.b, jnp.float32),
            impl=self.impl, block_c=8)
        return np.asarray(scores)[:c]

    def reorder(self, doc: SyntheticDoc) -> SyntheticDoc:
        """Sort chunks by predicted relevance (desc); concatenate."""
        scores = self.score_chunks(doc)
        order = np.argsort(-scores, kind="stable")
        s = self.granularity
        line_order = [li for ci in order
                      for li in range(ci * s, min((ci + 1) * s,
                                                  len(doc.lines)))]
        return doc.reordered(line_order)
