"""Threshold adjustment with statistical guarantees (paper Alg. 3 + 5).

Splits D_dev into i.i.d. halves D_T (construction) / D_V (certification).
For each (task, class) a *shift list* of candidate thresholds is built from
the confidences observed on D_T strictly above the base threshold tau_c:

    shift s = s_max  -> most conservative (s-th confidence above tau_c)
    shift s = 0      -> the original tau_c

The loop walks s from s_max down to 0, re-runs the cascade on D_V at each
shift, and applies the WSR estimator; it returns the LEAST conservative
shift whose predecessors all certified, stopping at the first failure
(Algorithm 5's early-exit).  The estimator budget is union-bounded over the
(s_max + 1) applications so total failure stays <= delta.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .estimator import wsr_certify
from .tasks import Cascade, CascadeResult, TaskConfig, TaskScores, run_cascade

S_MAX = 5


def build_shift_lists(
    cascade: Cascade,
    train_scores: Mapping[TaskConfig, TaskScores],
    n_classes: int,
    s_max: int = S_MAX,
) -> List[Dict[int, List[float]]]:
    """Per task, per class: [tau_c, p_1, ..., p_s_max] ascending.

    §3.2.3 requires the initial offset to be "large ... highly
    conservative", so the p_i are QUANTILE-spaced over the confidences
    observed above tau_c on D_T: p_{s_max} sits at the top of the observed
    distribution (almost nothing exits -> near-oracle accuracy), p_1 just
    above tau_c.  With the API-style confidences of the paper (few unique
    values concentrated near 1) this coincides with their next-k-values
    construction; with smooth confidences it preserves the intended
    conservative-to-original sweep.
    """
    out = []
    for task in cascade.tasks:
        ts = train_scores[task.config]
        lists: Dict[int, List[float]] = {}
        for c, tau in task.thresholds.items():
            above = np.sort(ts.conf[(ts.pred == c) & (ts.conf > tau)])
            if len(above) == 0:
                lists[c] = [float(tau)]
                continue
            # power-2 spacing: dense near tau (cheap shifts), coarse at the
            # conservative end — the walk-down usually stops in the dense
            # region, keeping certified cascades close to the base cost.
            qs = [float(np.quantile(above, (i / s_max) ** 2))
                  for i in range(1, s_max + 1)]
            lists[c] = [float(tau)] + qs
        out.append(lists)
    return out


def thresholds_at_shift(
    shift_lists: Sequence[Dict[int, List[float]]],
    s: int,
) -> List[Dict[int, float]]:
    """Thresholds with shift index s (s beyond list length disables class)."""
    out = []
    for lists in shift_lists:
        th: Dict[int, float] = {}
        for c, plist in lists.items():
            th[c] = plist[s] if s < len(plist) else float("inf")
        out.append(th)
    return out


@dataclass
class AdjustResult:
    cascade: Optional[Cascade]      # None -> revert to oracle-only
    shift: int                      # selected shift index
    certified: bool
    history: List[Tuple[int, bool, float]]  # (shift, certified, acc on D_V)


def adjust_thresholds(
    cascade: Cascade,
    train_scores: Mapping[TaskConfig, TaskScores],
    val_scores: Mapping[TaskConfig, TaskScores],
    val_oracle_pred: np.ndarray,
    cost_model,
    n_classes: int,
    alpha: float,
    delta: float,
    s_max: int = S_MAX,
    rng: Optional[np.random.Generator] = None,
) -> AdjustResult:
    """Algorithm 3/5: certified threshold selection on the validation split."""
    if len(cascade.tasks) == 0:
        return AdjustResult(cascade, 0, True, [])
    shift_lists = build_shift_lists(cascade, train_scores, n_classes, s_max)
    # No union bound over shifts is needed (paper Thm 3.2 proof): the loop
    # stops at the FIRST failing estimate, so a bad threshold is returned
    # only if E certifies the single first-truly-bad candidate t_{i*} —
    # one event, probability <= delta by Lemma A.1.
    delta_each = delta
    rng = rng or np.random.default_rng(0)
    # fixed random presentation order for the martingale (i.i.d. requirement)
    order = rng.permutation(len(val_oracle_pred))

    best: Optional[Cascade] = None
    best_shift = -1
    history: List[Tuple[int, bool, float]] = []
    for s in range(s_max, -1, -1):
        cand = cascade.with_thresholds(thresholds_at_shift(shift_lists, s))
        res = run_cascade(cand, val_scores, val_oracle_pred, cost_model,
                          n_classes)
        x = (res.pred == val_oracle_pred).astype(np.float64)[order]
        ok = wsr_certify(x, alpha, delta_each)
        history.append((s, ok, float(np.mean(x))))
        if ok:
            best, best_shift = cand, s
        else:
            break
    if best is None:
        return AdjustResult(None, -1, False, history)
    return AdjustResult(best, best_shift, True, history)
