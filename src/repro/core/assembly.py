"""Greedy cascade assembly (paper Algorithm 4) + the MSSC reduction (§3.1).

Starting from the empty cascade (oracle-only), greedily append the eligible
task that most reduces total dev-set inference cost, subject to EVERY task
in the candidate cascade holding per-task accuracy >= alpha on the subset
of documents it classifies.  Stops when no append reduces cost.

Also provided:
  * ``selectivity_ordering`` — the (selectivity-1)/cost predicate-ordering
    baseline from §7.1.3 (ablation: 7.5x worse in the paper).
  * ``mssc_instance_to_tasks`` / ``greedy_mssc`` — the §3.1 NP-hardness
    reduction materialized: a MIN-SUM-SET-COVER instance becomes a cascade
    assembly problem; tests verify cascade cost == MSSC objective and the
    greedy 4-approximation bound.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence, Set, Tuple

import numpy as np

from .cost_model import CascadeCostModel
from .tasks import (Cascade, CascadeResult, Task, TaskConfig, TaskScores,
                    run_cascade)


PER_TASK_MARGIN_Z = 0.25   # small-sample conservatism (paper §3.2.2 notes
                          # per-task enforcement exists to aid generalization)


def per_task_accuracy_ok(res: CascadeResult, cascade: Cascade,
                         scores, oracle_pred: np.ndarray,
                         alpha: float) -> bool:
    """Every task's accuracy on its classified subset >= alpha (with a
    z * sqrt(a(1-a)/n) one-sided buffer against dev-set optimism)."""
    for task, mask in zip(cascade.tasks, res.per_task_classified):
        n = int(mask.sum())
        if n == 0:
            continue
        ts = scores[task.config]
        acc = float(np.mean(ts.pred[mask] == oracle_pred[mask]))
        margin = PER_TASK_MARGIN_Z * np.sqrt(alpha * (1 - alpha) / n)
        if acc < alpha + margin:
            return False
    return True


@dataclass
class AssemblyTrace:
    steps: List[Tuple[str, float]]          # (task key str, cost after)
    considered: int = 0


def greedy_assembly(
    eligible: Sequence[Task],
    scores: Mapping[TaskConfig, TaskScores],
    oracle_pred: np.ndarray,
    cost_model: CascadeCostModel,
    n_classes: int,
    alpha: float,
) -> Tuple[Cascade, AssemblyTrace]:
    """Algorithm 4: greedy min-cost cascade under per-task accuracy."""
    cascade = Cascade([])
    best_cost = run_cascade(cascade, scores, oracle_pred, cost_model,
                            n_classes).total_cost()
    unused = list(eligible)
    trace = AssemblyTrace(steps=[("<oracle-only>", best_cost)])

    while unused:
        best_task: Optional[Task] = None
        best_task_cost = best_cost
        for task in unused:
            cand = cascade.with_task(task)
            res = run_cascade(cand, scores, oracle_pred, cost_model,
                              n_classes)
            trace.considered += 1
            if res.total_cost() >= best_task_cost:
                continue
            if not per_task_accuracy_ok(res, cand, scores, oracle_pred,
                                        alpha):
                continue
            best_task = task
            best_task_cost = res.total_cost()
        if best_task is None:
            break
        cascade = cascade.with_task(best_task)
        best_cost = best_task_cost
        unused = [t for t in unused if t is not best_task]
        trace.steps.append((str(best_task.config.key()), best_cost))
    return cascade, trace


def selectivity_ordering(
    eligible: Sequence[Task],
    scores: Mapping[TaskConfig, TaskScores],
    oracle_pred: np.ndarray,
    cost_model: CascadeCostModel,
    n_classes: int,
    alpha: float,
) -> Cascade:
    """Ablation baseline: order by (selectivity - 1) / cost (Hellerstein-
    style predicate ordering), keeping tasks whose standalone accuracy on
    their classified subset meets alpha."""
    ranked = []
    n = len(oracle_pred)
    for task in eligible:
        ts = scores[task.config]
        tvec = task.threshold_vector(n_classes)
        classified = ts.conf >= tvec[ts.pred]
        if classified.any():
            acc = float(np.mean(ts.pred[classified] ==
                                oracle_pred[classified]))
            if acc < alpha:
                continue
        selectivity = float(np.mean(~classified))   # fraction passed down
        cost, _ = cost_model.task_cost(
            task.config, np.zeros((n,), np.int64))
        rank = (selectivity - 1.0) / max(float(np.mean(cost)), 1e-12)
        ranked.append((rank, task))
    # paper §7.1.3: "prioritizing operations with the HIGHEST
    # (selectivity-1)/cost ratio" — note this inverts Hellerstein's
    # ascending rule and is what makes the baseline pathological (7.5x
    # worse in the paper's Table 3).
    ranked.sort(key=lambda rt: rt[0], reverse=True)
    return Cascade([t for _, t in ranked])


# ---------------------------------------------------------------------------
# §3.1 MSSC reduction
# ---------------------------------------------------------------------------

def mssc_instance_to_scores(
    universe: Sequence[int],
    sets: Sequence[Set[int]],
) -> Tuple[List[Task], Dict[TaskConfig, TaskScores], np.ndarray,
           CascadeCostModel]:
    """Materialize the §3.1 reduction: items -> documents, sets -> tasks.

    Task i predicts TRUE (class 1) with confidence 1 on d_u iff u in S_i,
    and a random answer with confidence 0 otherwise.  Document tokens cost
    0 (fully cached); each operation costs 1 token at unit rate, so running
    any task on any doc costs exactly 1 and the cascade cost of covering
    item u equals the index of the first covering set — the MSSC objective.
    """
    n = len(universe)
    idx = {u: i for i, u in enumerate(universe)}
    oracle_pred = np.ones((n,), np.int64)
    tasks: List[Task] = []
    scores: Dict[TaskConfig, TaskScores] = {}
    rng = np.random.default_rng(0)
    for si, s in enumerate(sets):
        cfg = TaskConfig("proxy", f"set_{si}", 1.0)
        pred = np.where(
            np.isin(np.arange(n), [idx[u] for u in s]),
            1, rng.integers(0, 2, n)).astype(np.int64)
        conf = np.isin(np.arange(n), [idx[u] for u in s]).astype(np.float64)
        scores[cfg] = TaskScores(cfg, pred, conf)
        tasks.append(Task(cfg, {0: 1.0, 1: 1.0}))
    cm = CascadeCostModel(
        doc_tokens=np.zeros((n,), np.int64),
        op_tokens={f"set_{si}": 1 for si in range(len(sets))} | {"o_orig": 0},
        rates={"proxy": 1.0, "oracle": 0.0},
        cached_discount=0.0,
    )
    return tasks, scores, oracle_pred, cm


def greedy_mssc(universe: Set[int], sets: Sequence[Set[int]]) -> Tuple[List[int], int]:
    """Feige et al. greedy for MSSC: pick the set covering most uncovered.

    Returns (order of set indices, total MSSC cost).  4-approximation.
    """
    uncovered = set(universe)
    order: List[int] = []
    cost = 0
    pos = 0
    remaining = list(range(len(sets)))
    while uncovered and remaining:
        pos += 1
        best = max(remaining, key=lambda i: len(sets[i] & uncovered))
        gained = sets[best] & uncovered
        if not gained:
            break
        cost += pos * len(gained)
        uncovered -= gained
        order.append(best)
        remaining.remove(best)
    return order, cost


def brute_force_mssc(universe: Set[int], sets: Sequence[Set[int]]) -> int:
    """Exact MSSC optimum by permutation search (tiny instances only)."""
    import itertools
    best = None
    for perm in itertools.permutations(range(len(sets))):
        uncovered = set(universe)
        cost = 0
        for pos, si in enumerate(perm, start=1):
            gained = sets[si] & uncovered
            cost += pos * len(gained)
            uncovered -= gained
            if not uncovered:
                break
        if uncovered:
            continue
        best = cost if best is None else min(best, cost)
    return best if best is not None else -1
