"""Cost models (paper §2.1 inference cost, §6 optimization cost).

Inference cost with prefix caching: the document rides *before* the
operation in every prompt, so two tasks on the same model share the cached
document prefix; extending the fraction from f_j to f_i > f_j pays the
cached rate on |x_{f_j}| and the full rate only on the new suffix.

    Cost(T_i, x) = |x_cached| λ_cached(m) + |x_new| λ_in(m) + |o_i| λ_in(m)

``cascade_cost`` evaluates this for every document simultaneously, walking
the cascade stage list once (cost accrues up to each document's exit
stage).  On the TPU serving plane the same arithmetic has a physical twin:
cached tokens == KV-prefix reuse (``extend`` path), and λ ratios are
replaced by measured FLOP/byte terms; see ``serving/engine.py``.

Optimization cost (§6): C_opt = C_doc + C_eval + C_agent, with the paper's
closed forms, used by the break-even benchmark (Table 4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Sequence

import numpy as np

from .tasks import ORACLE, PROXY, TaskConfig

# OpenAI pricing used in the paper (USD per token)
DEFAULT_RATES = {
    ORACLE: 2.50e-6,      # GPT-4o input
    PROXY: 0.15e-6,       # GPT-4o-mini input
}
CACHED_DISCOUNT = 0.5     # 50% prefix-cache discount
EMBED_RATE = 0.02e-6      # text-embedding-3-small
AGENT_RATES = (1.10e-6, 4.40e-6)   # o1-mini (in, out)


@dataclass
class CascadeCostModel:
    """Per-document-token cost accounting for a fixed document set."""

    doc_tokens: np.ndarray                    # [N] tokens per full document
    op_tokens: Mapping[str, int]              # operation id -> prompt tokens
    rates: Mapping[str, float] = field(default_factory=lambda: dict(DEFAULT_RATES))
    cached_discount: float = CACHED_DISCOUNT

    def frac_tokens(self, fraction: float) -> np.ndarray:
        return np.ceil(self.doc_tokens * fraction).astype(np.int64)

    def task_cost(self, cfg: TaskConfig, cached: np.ndarray) -> np.ndarray:
        """Vector cost of running ``cfg`` given per-doc cached token counts
        for cfg.model.  Returns (cost [N], new cached [N])."""
        lam = self.rates[cfg.model]
        ft = self.frac_tokens(cfg.fraction)
        cached_part = np.minimum(ft, cached)
        new_part = np.maximum(ft - cached, 0)
        cost = (cached_part * lam * self.cached_discount
                + new_part * lam
                + self.op_tokens[cfg.operation] * lam)
        return cost, np.maximum(cached, ft)

    def cascade_cost(self, configs: Sequence[TaskConfig],
                     exit_stage: np.ndarray) -> np.ndarray:
        """Per-document cost of a cascade run.

        ``exit_stage[i] == s`` means doc i exits at stage s (s == len(configs)
        -> falls through to the oracle task on the full document).
        """
        n = len(exit_stage)
        cached: Dict[str, np.ndarray] = {}
        cost = np.zeros((n,), np.float64)
        for si, cfg in enumerate(configs):
            active = exit_stage >= si
            c = cached.setdefault(cfg.model, np.zeros((n,), np.int64))
            stage_cost, new_cached = self.task_cost(cfg, c)
            cost += np.where(active, stage_cost, 0.0)
            cached[cfg.model] = np.where(active, new_cached, c)
        # oracle fallthrough on the full document
        oracle_cfg = TaskConfig(ORACLE, "o_orig", 1.0)
        active = exit_stage >= len(configs)
        c = cached.setdefault(ORACLE, np.zeros((n,), np.int64))
        stage_cost, _ = self.task_cost(oracle_cfg, c)
        cost += np.where(active, stage_cost, 0.0)
        return cost

    def oracle_only_cost(self) -> float:
        oracle_cfg = TaskConfig(ORACLE, "o_orig", 1.0)
        cost, _ = self.task_cost(oracle_cfg, np.zeros_like(self.doc_tokens))
        return float(np.sum(cost))


# ---------------------------------------------------------------------------
# Optimization (offline) cost — paper §6
# ---------------------------------------------------------------------------

@dataclass
class OptimizationCost:
    """Closed-form optimization cost C_opt = C_doc + C_eval + C_agent."""

    n_dev: int                       # N
    avg_doc_tokens: float            # L
    prompt_tokens: float             # P
    fractions: Sequence[float]       # F
    n_s: int = 5
    n_a: int = 3
    rates: Mapping[str, float] = field(default_factory=lambda: dict(DEFAULT_RATES))
    embed_rate: float = EMBED_RATE
    agent_in_tokens: float = 20_000.0
    agent_out_tokens: float = 2_000.0
    lite: bool = False               # exclude oracle from candidate evals

    def c_labels(self) -> float:
        return self.n_dev * (self.avg_doc_tokens + self.prompt_tokens) \
            * self.rates[ORACLE]

    def c_doc(self) -> float:
        return (self.n_dev * (self.avg_doc_tokens + self.prompt_tokens)
                * 2 * self.rates[ORACLE]
                + self.n_dev * self.avg_doc_tokens * self.embed_rate)

    def c_eval(self) -> float:
        s_f = float(sum(self.fractions))
        lam = self.rates[PROXY] if self.lite \
            else self.rates[ORACLE] + self.rates[PROXY]
        return self.n_dev * self.n_s * self.n_a * (
            self.avg_doc_tokens * s_f * lam
            + self.prompt_tokens * len(self.fractions) * lam)

    def c_agent(self) -> float:
        lin, lout = AGENT_RATES
        return self.n_a * (self.agent_in_tokens * lin
                           + self.agent_out_tokens * lout)

    def total(self) -> float:
        return self.c_doc() + self.c_eval() + self.c_agent()

    def model_cascade_cost(self) -> float:
        """2-Model Cascade optimization: proxy + oracle pass over dev set."""
        lam = self.rates[ORACLE] + self.rates[PROXY]
        return self.n_dev * (self.avg_doc_tokens + self.prompt_tokens) * lam


def break_even_docs(opt_cost: float, per_doc_cost: float,
                    oracle_per_doc: float) -> float:
    """Documents until opt_cost + n*c_method < n*c_oracle."""
    gain = oracle_per_doc - per_doc_cost
    return float("inf") if gain <= 0 else opt_cost / gain
