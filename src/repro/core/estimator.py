"""Waudby-Smith & Ramdas betting-martingale estimator (paper §A.2).

Certifies "cascade accuracy >= target with failure probability <= delta"
from i.i.d. Bernoulli correctness samples on the validation split.  The
wealth process

    K_i = prod_{j<=i} (1 + min(lambda_j, 3/(4T)) * (X_j - T))

is a nonnegative supermartingale under H0: E[X] <= T, so by Ville's
inequality P(sup_i K_i >= 1/delta) <= delta.  The estimator returns True
(certified) iff the wealth ever crosses 1/delta.  lambda_j adapts to the
running empirical variance, which is what makes this tighter than
Hoeffding when correctness is nearly deterministic (the common case at
alpha >= 0.9).
"""
from __future__ import annotations

import numpy as np


def wsr_wealth(x: np.ndarray, target: float, delta: float,
               lam_rule: str = "paper") -> np.ndarray:
    """The wealth process K_i. x: binary [n].

    Any PREDICTABLE lambda_i in [0, 1/target) keeps K a nonnegative
    supermartingale under H0: E[X] <= target, so Ville's inequality gives
    the delta guarantee regardless of the betting rule.  Two members of
    the Waudby-Smith-Ramdas betting family are provided:

    * ``paper``  — the variance-adaptive predictable mixture restated in
      the paper's Lemma A.1 (sqrt(2 log(2/delta) / (i log(i+1) sigma^2)),
      capped at 3/(4 target)).  At the paper's own operating point
      (target 0.9, n~100, true acc 0.92-0.96) the cap binds for the first
      ~30 samples and one wrong answer multiplies wealth by 0.25 —
      near-zero power unless an early all-correct prefix certifies.
    * ``kelly``  — the log-optimal (GRO) fraction for Bernoulli bets,
      lambda_i = (mu_hat_{i-1} - target) / (target (1 - target)), clipped
      to [0, 3/(4 target)].  Measured LESS powerful than "paper" at the
      1/delta = 4 wealth bar (the sup exploits aggressive bets), so
      "paper" stays the default; kept for lower-false-positive regimes.
    """
    x = np.asarray(x, np.float64)
    n = len(x)
    if n == 0:
        return np.zeros((0,))
    idx = np.arange(1, n + 1)
    mu_hat = (0.5 + np.cumsum(x)) / (idx + 1)
    cap = 3.0 / (4.0 * target)
    if lam_rule == "paper":
        sigma2 = (0.25 + np.cumsum((x - mu_hat) ** 2)) / (idx + 1)
        # lambda_i uses sigma^2_{i-1}; sigma^2_0 = 0.25
        sigma2_prev = np.concatenate([[0.25], sigma2[:-1]])
        lam = np.sqrt(2.0 * np.log(2.0 / delta)
                      / (idx * np.log1p(idx) * sigma2_prev))
        lam = np.minimum(lam, cap)
    else:
        mu_prev = np.concatenate([[0.5], mu_hat[:-1]])     # predictable
        lam = np.clip((mu_prev - target) / (target * (1.0 - target)),
                      0.0, cap)
    factors = 1.0 + lam * (x - target)
    # wealth must stay nonnegative; clip guards numerically tiny negatives
    return np.cumprod(np.maximum(factors, 1e-12))


def wsr_certify(x: np.ndarray, target: float, delta: float,
                lam_rule: str = "paper") -> bool:
    """E(t, D_V): True iff exists i with K_i >= 1/delta."""
    if len(x) == 0:
        return False
    return bool(np.any(wsr_wealth(x, target, delta, lam_rule)
                       >= 1.0 / delta))


def hoeffding_certify(x: np.ndarray, target: float, delta: float) -> bool:
    """Baseline estimator: mean - sqrt(log(1/delta)/(2n)) >= target."""
    n = len(x)
    if n == 0:
        return False
    return bool(np.mean(x) - np.sqrt(np.log(1.0 / delta) / (2 * n)) >= target)


def wsr_lower_bound(x: np.ndarray, delta: float,
                    grid: int = 200) -> float:
    """(1-delta) lower confidence bound on the mean via grid inversion.

    Smallest target NOT rejected: sup of targets the wealth certifies.
    Used for reporting, not in the adjustment loop.
    """
    lo, hi = 0.0, 1.0
    for t in np.linspace(1e-3, 1.0 - 1e-3, grid):
        if wsr_certify(x, float(t), delta):
            lo = float(t)
    return lo
