"""Surrogate operation generation: agent interface + agentic loop (§5, Alg 6).

The agent is an interface: production deployments plug an LLM; offline we
ship two implementations —

``SyntheticAgent``  proposes surrogate *specs* against the calibrated
    simulator.  It is deliberately imperfect: proposal quality is sampled
    (some surrogates are weak and get filtered by Algorithm 2), and
    refinement works exactly as in the paper — each round sees the current
    cascade's failure cases and per-task statistics, biases target classes
    toward what the oracle says about the failures, probes *new* pattern
    families, and sharpens strength estimates for families that tested well.

``ScriptedAgent``   replays a fixed proposal list (deterministic tests).

Both emit the paper's four surrogate types: keyword, class-specific,
semantic-pattern, and sequential-decomposition (Appendix C taxonomy).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from .simulation import N_FAMILIES, SurrogateSpec
from .tasks import Cascade, TaskConfig

KINDS = ("keyword", "class_specific", "semantic", "decomposition")


@dataclass
class AgentContext:
    """What the agent sees each round (Alg 6 lines 4-8)."""
    round: int
    failure_labels: np.ndarray              # oracle labels of unresolved docs
    task_stats: List[Dict]                  # per candidate: selected, coverage
    previous_ops: List[str]
    n_classes: int


class Agent(Protocol):
    def propose(self, ctx: AgentContext, n_s: int) -> List[SurrogateSpec]:
        ...


@dataclass
class SyntheticAgent:
    """Stochastic surrogate proposer over the simulator's latent families."""

    pattern_coverage: float                  # workload ceiling
    seed: int = 0
    _counter: int = 0
    _family_quality: Dict[int, float] = field(default_factory=dict)

    def propose(self, ctx: AgentContext, n_s: int) -> List[SurrogateSpec]:
        rng = np.random.default_rng(self.seed + 7919 * ctx.round)
        out: List[SurrogateSpec] = []
        # target the classes the cascade is failing on
        if len(ctx.failure_labels):
            counts = np.bincount(ctx.failure_labels,
                                 minlength=ctx.n_classes).astype(float)
            class_p = (counts + 0.5) / (counts + 0.5).sum()   # smoothed
        else:
            class_p = np.full(ctx.n_classes, 1.0 / ctx.n_classes)

        used_families = {
            st["family"] for st in ctx.task_stats if "family" in st}
        good_families = {
            st["family"] for st in ctx.task_stats
            if st.get("selected") and "family" in st}

        for j in range(n_s):
            self._counter += 1
            kind = KINDS[int(rng.integers(0, len(KINDS)))]
            # refinement: revisit families that tested well, else explore
            if good_families and rng.random() < 0.4:
                family = int(rng.choice(sorted(good_families)))
                strength_bonus = 0.15
            else:
                fresh = [f for f in range(N_FAMILIES)
                         if f not in used_families]
                family = int(rng.choice(fresh)) if fresh \
                    else int(rng.integers(0, N_FAMILIES))
                strength_bonus = 0.0
            if kind == "decomposition":
                targets = tuple(range(ctx.n_classes))
            elif kind == "class_specific":
                targets = (int(rng.choice(ctx.n_classes, p=class_p)),)
            else:
                k = int(rng.integers(1, max(ctx.n_classes // 2, 1) + 1))
                targets = tuple(sorted(rng.choice(
                    ctx.n_classes, size=k, replace=False,
                    p=class_p).tolist()))
            # quality is noisy: later rounds are better (test-and-refine),
            # but bad proposals still happen and must be filtered
            base_strength = rng.beta(2.5 + ctx.round + 4 * strength_bonus, 2.0)
            coverage = self.pattern_coverage * rng.beta(6.0, 2.0)
            false_fire = float(rng.beta(1.2, 28.0))
            out.append(SurrogateSpec(
                op_id=f"sur_{self._counter}_{kind}",
                kind=kind,
                target_classes=targets,
                coverage=float(coverage),
                strength=float(np.clip(base_strength, 0.3, 0.99)),
                false_fire=false_fire,
                op_tokens=int(rng.integers(16, 48)),
                family=family,
            ))
        return out


@dataclass
class ScriptedAgent:
    """Deterministic agent for tests: replays ``specs`` n_s at a time."""
    specs: List[SurrogateSpec]
    _pos: int = 0

    def propose(self, ctx: AgentContext, n_s: int) -> List[SurrogateSpec]:
        out = self.specs[self._pos:self._pos + n_s]
        self._pos += len(out)
        return out
