"""Task-cascade datatypes (paper §2.1) and the vectorized dev-set executor.

A *task config* is (model, operation, fraction); a *task* adds per-class
confidence thresholds; a *cascade* is an ordered task sequence with the
oracle task (m_oracle, o_orig, f=1, no thresholds) implicit at the end.

``TaskScores`` holds a task config's predictions + confidences on the dev
set — the interface between cascade construction (this package) and
whatever produced the scores (the LM serving engine or the calibrated
simulator).  ``run_cascade`` executes a cascade over score matrices in a
fully vectorized way (no per-document Python loop over D_dev).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

ORACLE = "oracle"
PROXY = "proxy"


@dataclass(frozen=True)
class TaskConfig:
    model: str                      # "proxy" | "oracle" (or an arch id)
    operation: str                  # operation id; "o_orig" is the original
    fraction: float                 # document fraction f in (0, 1]

    def key(self) -> Tuple[str, str, float]:
        return (self.model, self.operation, self.fraction)


@dataclass(frozen=True)
class Task:
    config: TaskConfig
    # per-class threshold; classes absent -> infinity (never exit on them)
    thresholds: Mapping[int, float]

    def threshold_vector(self, n_classes: int) -> np.ndarray:
        t = np.full((n_classes,), np.inf)
        for c, v in self.thresholds.items():
            t[c] = v
        return t


@dataclass(frozen=True)
class TaskScores:
    """A task config's behaviour on the dev set."""
    config: TaskConfig
    pred: np.ndarray                # [N] int class predictions
    conf: np.ndarray                # [N] float confidence of pred

    def __post_init__(self):
        assert self.pred.shape == self.conf.shape


@dataclass
class Cascade:
    tasks: List[Task] = field(default_factory=list)

    def configs(self) -> List[TaskConfig]:
        return [t.config for t in self.tasks]

    def stage_entries(
        self, n_classes: int, oracle_model: str = ORACLE,
        oracle_op: str = "o_orig",
    ) -> List[Tuple[str, str, float, Optional[np.ndarray]]]:
        """Serving-stage table: ``(model, op, fraction, thresholds|None)``
        per task plus the implicit oracle fall-through (no thresholds, so
        every document resolves).  This is what a serving query handle
        walks its stage cursor over — the bridge between cascade
        construction and the multi-tenant server."""
        return [
            (t.config.model, t.config.operation, t.config.fraction,
             t.threshold_vector(n_classes))
            for t in self.tasks
        ] + [(oracle_model, oracle_op, 1.0, None)]

    def with_task(self, task: Task) -> "Cascade":
        return Cascade(self.tasks + [task])

    def with_thresholds(self, new_thresholds: List[Mapping[int, float]]
                        ) -> "Cascade":
        assert len(new_thresholds) == len(self.tasks)
        return Cascade([
            Task(t.config, th) for t, th in zip(self.tasks, new_thresholds)])

    def __len__(self) -> int:
        return len(self.tasks)


@dataclass
class CascadeResult:
    """Vectorized execution record of a cascade on N documents."""
    exit_stage: np.ndarray          # [N] int; len(tasks) means oracle
    pred: np.ndarray                # [N] final prediction
    cost: np.ndarray                # [N] per-document $ cost
    per_task_classified: List[np.ndarray]   # boolean [N] mask per task

    def accuracy(self, oracle_pred: np.ndarray) -> float:
        return float(np.mean(self.pred == oracle_pred))

    def total_cost(self) -> float:
        return float(np.sum(self.cost))

    def oracle_mask(self) -> np.ndarray:
        return self.exit_stage == len(self.per_task_classified)


def run_cascade(
    cascade: Cascade,
    scores: Mapping[TaskConfig, TaskScores],
    oracle_pred: np.ndarray,
    cost_model: "CascadeCostModel",
    n_classes: int,
) -> CascadeResult:
    """Execute ``cascade`` on the dev set (vectorized).

    Documents exit at the first task whose predicted-class confidence clears
    that task's class threshold; the rest fall through to the oracle task.
    """
    n = len(oracle_pred)
    exit_stage = np.full((n,), len(cascade.tasks), np.int64)
    pred = oracle_pred.copy()
    unresolved = np.ones((n,), bool)
    per_task_classified: List[np.ndarray] = []

    for si, task in enumerate(cascade.tasks):
        ts = scores[task.config]
        tvec = task.threshold_vector(n_classes)
        passes = ts.conf >= tvec[ts.pred]
        takes = unresolved & passes
        exit_stage[takes] = si
        pred[takes] = ts.pred[takes]
        per_task_classified.append(takes)
        unresolved &= ~takes

    cost = cost_model.cascade_cost(cascade.configs(), exit_stage)
    return CascadeResult(exit_stage, pred, cost, per_task_classified)
