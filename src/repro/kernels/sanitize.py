"""Kernel-side sanitizer hook registry (host side, zero-cost when off).

The paged kernel wrappers (``ops.arena_decode_attention`` /
``ops.attention_paged`` and the Pallas host wrappers in
``decode_attention.py`` / ``flash_attention.py``) address arena rows
through slot ids and block tables.  Inside a jitted stage step those
operands are tracers and nothing can be checked here — the engine-side
:class:`repro.analysis.sanitizer.ArenaSanitizer` launch brackets are
the jit-safe layer.  But the wrappers are also called EAGERLY (kernel
parity tests, benchmarks, notebooks), and there the slot/block-table
values are concrete: ``notify_rows`` hands them to any registered
hooks (``ArenaSanitizer.kernel_hook()`` validates range membership and
— when launches are in flight — registration in an in-flight row set).

No hooks registered (the default) costs one ``if`` per wrapper call;
tracers always short-circuit, so compiled paths are untouched.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

Hook = Callable[[str, Any, int], None]      # (where, rows, n_rows)

_hooks: Dict[int, Hook] = {}
_next_id = 0


def add_row_hook(hook: Hook) -> int:
    """Register a hook; returns a handle for :func:`remove_row_hook`."""
    global _next_id
    hid = _next_id
    _next_id += 1
    _hooks[hid] = hook
    return hid


def remove_row_hook(hid: int) -> None:
    _hooks.pop(hid, None)


def clear_row_hooks() -> None:
    _hooks.clear()


def notify_rows(where: str, rows: Any, n_rows: int) -> None:
    """Report concrete arena-row operands to registered hooks.

    ``rows`` may be slot ids [B] or block tables [B, nkv]; ``n_rows``
    is the arena's row count INCLUDING the scratch row convention
    (valid ids lie in ``[0, n_rows]`` with ``n_rows`` = scratch).
    Tracers (jit/vmap abstraction) are skipped — see module docstring.
    """
    if not _hooks:
        return
    import jax

    if isinstance(rows, jax.core.Tracer):
        return
    for hook in list(_hooks.values()):
        hook(where, rows, n_rows)
