"""Pure-jnp reference oracles for every Pallas kernel.

These are deliberately naive (materialize the full score matrix, fp32
softmax) — they define correctness for small shapes; kernels are validated
against them with ``interpret=True`` sweeps in tests/.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def mha_reference(
    q: jnp.ndarray,             # [B, Sq, Hq, Dh]
    k: jnp.ndarray,             # [B, Skv, Hkv, Dh]
    v: jnp.ndarray,             # [B, Skv, Hkv, Dh]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,   # [B] valid kv length (padding mask)
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Grouped-query attention reference with prefix-extend semantics.

    Query position i (0-based within q) has absolute position q_offset + i.
    ``causal`` masks kv positions > absolute q position; ``window`` further
    restricts to kv positions > abs_q - window.
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (Dh ** 0.5)

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, g, axis=2)
    vf = jnp.repeat(vf, g, axis=2)

    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)    # [B, Hq, Sq, Skv]

    qpos = q_offset + jnp.arange(Sq)[:, None]          # [Sq, 1]
    kpos = jnp.arange(Skv)[None, :]                    # [1, Skv]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None and window > 0:
        mask &= kpos > qpos - window
    mask_b = jnp.broadcast_to(mask[None, None], scores.shape)
    if kv_len is not None:
        valid = kpos < kv_len[:, None, None, None]     # [B,1,1,Skv]
        mask_b = mask_b & valid
    scores = jnp.where(mask_b, scores, -jnp.inf)
    # rows that are fully masked produce zeros, not NaN
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


def decode_reference(
    q: jnp.ndarray,             # [B, Hq, Dh] single query token
    k: jnp.ndarray,             # [B, Skv, Hkv, Dh]
    v: jnp.ndarray,
    *,
    kv_len: Optional[jnp.ndarray] = None,   # [B] number of valid cache slots
    sm_scale: Optional[float] = None,
) -> jnp.ndarray:
    out = mha_reference(
        q[:, None], k, v,
        causal=False, window=None, q_offset=0,
        kv_len=kv_len, sm_scale=sm_scale,
    )
    return out[:, 0]


def relevance_reference(
    x: jnp.ndarray,             # [C, T, D] chunk token embeddings
    lengths: jnp.ndarray,       # [C] valid token count per chunk
    w: jnp.ndarray,             # [D]
    b: jnp.ndarray,             # [] bias
) -> jnp.ndarray:
    """sigmoid(meanpool(x) @ w + b) per chunk -> [C] relevance scores."""
    mask = (jnp.arange(x.shape[1])[None, :] < lengths[:, None]).astype(jnp.float32)
    summed = jnp.einsum("ctd,ct->cd", x.astype(jnp.float32), mask)
    denom = jnp.maximum(lengths.astype(jnp.float32), 1.0)[:, None]
    pooled = summed / denom
    logit = pooled @ w.astype(jnp.float32) + b.astype(jnp.float32)
    return jax.nn.sigmoid(logit)
