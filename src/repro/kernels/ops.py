"""Public kernel API: jit'd wrappers that dispatch between implementations.

Implementations
---------------
``pallas``            Mosaic TPU kernel (the deploy target).
``pallas_interpret``  same kernel body, Python interpretation (CPU tests).
``xla``               blocked lax.scan flash attention — used for the
                      CPU AOT dry-run (Mosaic cannot target CPU) and as the
                      large-shape oracle.  FLOP-count matches the kernel:
                      only causally/window-needed (q,kv) block pairs are
                      visited (static pair list), so ``cost_analysis`` on the
                      dry-run reflects real attention work, not a dense S^2.
``naive``             materialized-scores reference (small shapes only).

All functions take q/k/v in [B, S, H, Dh] layout (model-side convention) and
handle the transposition to the kernel layout internally.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from . import sanitize
from .flash_attention import flash_attention_pallas, paged_flash_attention_pallas
from .decode_attention import decode_attention_pallas, paged_decode_attention_pallas
from .relevance_score import relevance_score_pallas

DEFAULT_IMPL = "xla"


def _check_slots(slots, n_rows: int, where: str) -> None:
    """Validate the arena-slot contract when slot values are host-visible.

    Contract: every slot must lie in ``[0, n_rows)`` where ``n_rows`` is
    the arena's row count; the LAST row (index ``n_slots == n_rows - 1``)
    is the serving engine's scratch row and is an explicitly legal
    sentinel that may appear any number of times (batch padding).
    Anything outside that range is a caller bug: the gather fallback's
    ``jnp.take`` would silently CLIP it to the nearest edge row and the
    paged kernels would DMA an unrelated row — both produce plausible
    garbage rather than an error.  Under ``jit`` the values are traced
    and this check is a no-op (the contract still holds; debug with
    un-jitted calls or ``jax.disable_jit``), so eager callers — tests,
    the un-jitted reference path — fail loudly here instead.
    """
    if isinstance(slots, jax.core.Tracer):
        return
    s = np.asarray(slots)
    if s.size and (int(s.min()) < 0 or int(s.max()) >= n_rows):
        raise ValueError(
            f"{where}: slot ids must be in [0, {n_rows}) — the scratch "
            f"row {n_rows - 1} is the only padding sentinel — got "
            f"min={int(s.min())} max={int(s.max())}")


def _block_granularity(bt: jnp.ndarray, S: int, where: str) -> int:
    """Infer (and validate) the cache-block size a block table addresses.

    A block table is full-width by contract: ``[B, S // block]`` with
    column ``j`` naming the arena row holding positions
    ``[j * block, (j + 1) * block)``.  The granularity is therefore
    recoverable from the table's width — no extra parameter to thread
    through the jitted serving step."""
    if bt.ndim != 2 or bt.shape[1] == 0 or S % bt.shape[1] != 0:
        raise ValueError(
            f"{where}: block table must be [B, S // block] with a width "
            f"dividing the arena cache axis {S}, got shape {bt.shape}")
    return S // bt.shape[1]


def _gather_block_rows(arena: jnp.ndarray, bt: jnp.ndarray,
                       block: int) -> jnp.ndarray:
    """Assemble per-sequence caches [B, S, H, D] from a block table —
    the bitwise reference for the paged kernels' in-kernel indirection
    (block gathers move bits, never recompute them)."""
    N, S, H, D = arena.shape
    nb = S // block
    flat = arena.reshape(N * nb, block, H, D)
    idx = bt.astype(jnp.int32) * nb + jnp.arange(nb, dtype=jnp.int32)[None]
    return jnp.take(flat, idx, axis=0).reshape(bt.shape[0], S, H, D)


# ---------------------------------------------------------------------------
# XLA blocked flash attention (static pair-list scan)
# ---------------------------------------------------------------------------

def _block_pairs(
    nq: int, nk: int, block_q: int, block_kv: int,
    causal: bool, window: Optional[int], q_offset: int,
) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Static list of (q_block, kv_block) pairs that contain unmasked work."""
    qi, ki = [], []
    for i in range(nq):
        q_lo = q_offset + i * block_q
        q_hi = q_lo + block_q - 1
        for j in range(nk):
            k_lo = j * block_kv
            k_hi = k_lo + block_kv - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and window > 0 and k_hi <= q_lo - window:
                # fully left of every row's window in this q block
                continue
            qi.append(i)
            ki.append(j)
    return tuple(qi), tuple(ki)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "sm_scale", "block_q", "block_kv",
    ),
)
def xla_flash_attention(
    q: jnp.ndarray,               # [B, Sq, Hq, Dh]
    k: jnp.ndarray,               # [B, Skv, Hkv, Dh]
    v: jnp.ndarray,
    kv_len: Optional[jnp.ndarray] = None,   # [B] valid kv length (pad mask)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    sm_scale: Optional[float] = None,
    block_q: int = 512,
    block_kv: int = 1024,
) -> jnp.ndarray:
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (Dh ** 0.5)

    bq = min(block_q, Sq)
    bk = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk

    qi, ki = _block_pairs(nq, nk, bq, bk, causal, window, q_offset)
    pair_arr = jnp.stack(
        [jnp.asarray(qi, jnp.int32), jnp.asarray(ki, jnp.int32)], axis=1
    )

    qf = q.astype(jnp.float32) * scale

    acc0 = jnp.zeros((B, Sq, Hq, Dh), jnp.float32)
    m0 = jnp.full((B, Sq, Hq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hq), jnp.float32)

    def step(carry, ij):
        acc, m, l = carry
        i, j = ij[0], ij[1]
        qb = jax.lax.dynamic_slice_in_dim(qf, i * bq, bq, axis=1)   # [B,bq,Hq,Dh]
        kb = jax.lax.dynamic_slice_in_dim(k, j * bk, bk, axis=1)    # [B,bk,Hkv,Dh]
        vb = jax.lax.dynamic_slice_in_dim(v, j * bk, bk, axis=1)
        kb = jnp.repeat(kb.astype(jnp.float32), g, axis=2)
        vb = jnp.repeat(vb.astype(jnp.float32), g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bqhk", qb, kb)                   # [B,bq,Hq,bk]

        qpos = q_offset + i * bq + jnp.arange(bq)[:, None]
        kpos = j * bk + jnp.arange(bk)[None, :]
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None and window > 0:
            mask &= kpos > qpos - window
        mask = jnp.broadcast_to(mask[None], (B, bq, bk))
        if kv_len is not None:
            # per-row valid kv length: keys past kv_len[b] are padding
            mask = mask & (kpos[None] < kv_len[:, None, None])
        s = jnp.where(mask[:, :, None, :], s, -jnp.inf)

        mb = jax.lax.dynamic_slice_in_dim(m, i * bq, bq, axis=1)
        lb = jax.lax.dynamic_slice_in_dim(l, i * bq, bq, axis=1)
        ab = jax.lax.dynamic_slice_in_dim(acc, i * bq, bq, axis=1)

        m_cur = jnp.maximum(mb, jnp.max(s, axis=-1))
        # guard: rows with no valid kv yet keep -inf; exp(-inf - -inf) -> nan
        safe_m = jnp.where(jnp.isneginf(m_cur), 0.0, m_cur)
        alpha = jnp.where(jnp.isneginf(mb), 0.0, jnp.exp(mb - safe_m))
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(mask[:, :, None, :], p, 0.0)
        l_cur = lb * alpha + jnp.sum(p, axis=-1)
        a_cur = ab * alpha[..., None] + jnp.einsum("bqhk,bkhd->bqhd", p, vb)

        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_cur, i * bq, axis=1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_cur, i * bq, axis=1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_cur, i * bq, axis=1)
        return (acc, m, l), None

    (acc, _, l), _ = jax.lax.scan(step, (acc0, m0, l0), pair_arr)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Public attention entry points
# ---------------------------------------------------------------------------

def attention(
    q: jnp.ndarray,               # [B, Sq, Hq, Dh]
    k: jnp.ndarray,               # [B, Skv, Hkv, Dh]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,   # [B] valid kv length (pad mask)
    sm_scale: Optional[float] = None,
    impl: str = DEFAULT_IMPL,
    block_q: int = 512,
    block_kv: int = 512,
) -> jnp.ndarray:
    """Prefill / prefix-extend attention.

    ``kv_len`` [B] masks per-row KV padding: serving batches are bucket-
    padded, so a document shorter than its bucket carries PAD keys past its
    true length — with ``kv_len`` those keys are invisible to every query
    (the prefill twin of the decode kernel's length mask).
    """
    if impl == "stub":
        # near-zero-cost stand-in used by the dry-run to ATTRIBUTE HLO
        # flops/bytes to the attention op (delta vs the real lowering);
        # shape/dtype/grad-correct, O(B*S*H*Dh) work.
        g = q.shape[2] // k.shape[2]
        vm = jnp.repeat(jnp.mean(v, axis=1, keepdims=True), g, axis=2)
        return (q * 1e-6 + vm).astype(q.dtype)
    if impl == "naive":
        return ref.mha_reference(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_len=kv_len, sm_scale=sm_scale,
        )
    if impl == "xla":
        return xla_flash_attention(
            q, k, v, kv_len, causal=causal, window=window, q_offset=q_offset,
            sm_scale=sm_scale, block_q=block_q, block_kv=block_kv,
        )
    if impl in ("pallas", "pallas_interpret"):
        qt = jnp.swapaxes(q, 1, 2)
        kt = jnp.swapaxes(k, 1, 2)
        vt = jnp.swapaxes(v, 1, 2)
        out = flash_attention_pallas(
            qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
            kv_len=kv_len, sm_scale=sm_scale, block_q=block_q,
            block_kv=block_kv, interpret=(impl == "pallas_interpret"),
        )
        return jnp.swapaxes(out, 1, 2)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(
    q: jnp.ndarray,               # [B, Hq, Dh]
    k: jnp.ndarray,               # [B, S, Hkv, Dh]
    v: jnp.ndarray,
    kv_len: jnp.ndarray,          # [B]
    *,
    sm_scale: Optional[float] = None,
    impl: str = DEFAULT_IMPL,
    block_kv: int = 512,
) -> jnp.ndarray:
    """Single-token decode attention over a (possibly padded) KV cache."""
    if impl == "stub":
        g = q.shape[1] // k.shape[2]
        vm = jnp.repeat(jnp.mean(v, axis=1), g, axis=1)
        return (q * 1e-6 + vm).astype(q.dtype)
    if impl in ("naive", "xla"):
        return ref.decode_reference(q, k, v, kv_len=kv_len, sm_scale=sm_scale)
    if impl in ("pallas", "pallas_interpret"):
        # Arena allocations round sequence length to the serving bucket plus
        # an operation-suffix reserve, which need not divide block_kv.  Pad
        # the cache axis up to a block multiple here: padded slots sit past
        # every ``kv_len`` so the kernel's scalar-prefetch mask skips them.
        S = k.shape[1]
        bk = min(block_kv, S)
        if S % bk:
            pad = bk - S % bk
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kt = jnp.swapaxes(k, 1, 2)
        vt = jnp.swapaxes(v, 1, 2)
        return decode_attention_pallas(
            q, kt, vt, kv_len, sm_scale=sm_scale, block_kv=block_kv,
            interpret=(impl == "pallas_interpret"),
        )
    raise ValueError(f"unknown decode impl {impl!r}")


def arena_decode_attention(
    q: jnp.ndarray,               # [B, Hq, Dh]
    k_arena: jnp.ndarray,         # [N_rows, S, Hkv, Dh] persistent arena
    v_arena: jnp.ndarray,
    slots: jnp.ndarray,           # [B] int32 arena row per sequence
    kv_len: jnp.ndarray,          # [B] valid cache entries per sequence
    *,
    block_tables: Optional[jnp.ndarray] = None,   # [B, S // block] int32
    sm_scale: Optional[float] = None,
    impl: str = DEFAULT_IMPL,
    block_kv: int = 512,
) -> jnp.ndarray:
    """Decode attention reading straight from a slot arena — the real
    paged entry point.

    ``block_tables`` [B, S // block] switches the indirection from one
    arena row per sequence to one row per cache block (column ``j`` names
    the row holding positions ``[j * block, (j+1) * block)``), which is
    how many documents share a pinned operation-prefix row.  The table is
    full-width; its granularity is inferred from its shape.  When the
    granularity matches the kernel's effective kv block the table rides
    in scalar-prefetch SMEM; otherwise (and on ``xla``/``naive``) the
    blocks are gathered into dense per-sequence caches first — a pure
    bit-move, so both planes stay bitwise-identical.

    The serving engine keeps one preallocated KV arena per length bucket
    and addresses sequences by slot id.  On Pallas runtimes the slot
    indices ride in scalar-prefetch SMEM and the kernel's k/v index maps
    DMA ``k_arena[slots[b]]`` blocks in place — no [B, S] gather copy is
    materialized, so per-launch HBM traffic no longer scales with the
    gathered batch.  ``xla``/``naive`` keep the gather-then-reference
    path as the correctness oracle and CPU fallback (also used when the
    arena's cache axis is not a kv-block multiple — only possible for
    arenas built on non-Pallas runtimes).

    Slot contract: values must be in ``[0, N_rows)``; the last row
    (``n_slots`` == N_rows - 1) is the scratch row, an explicitly legal
    padding sentinel that may repeat.  Out-of-range ids raise when the
    values are concrete (see ``_check_slots``); under ``jit`` the gather
    fallback inherits ``jnp.take`` clip semantics and the paged kernel's
    behaviour is undefined — callers own the bound.
    """
    S = k_arena.shape[1]
    if block_tables is not None:
        _check_slots(block_tables, k_arena.shape[0],
                     "arena_decode_attention block_tables")
        sanitize.notify_rows("arena_decode_attention block_tables",
                             block_tables, k_arena.shape[0] - 1)
        tb = _block_granularity(block_tables, S, "arena_decode_attention")
        if impl in ("pallas", "pallas_interpret") \
                and tb == min(block_kv, S) and S % tb == 0:
            return paged_decode_attention_pallas(
                q, k_arena, v_arena, slots, kv_len,
                block_tables=block_tables, sm_scale=sm_scale,
                block_kv=block_kv, interpret=(impl == "pallas_interpret"))
        k = _gather_block_rows(k_arena, block_tables, tb)
        v = _gather_block_rows(v_arena, block_tables, tb)
        return decode_attention(q, k, v, kv_len, sm_scale=sm_scale,
                                impl=impl, block_kv=block_kv)
    _check_slots(slots, k_arena.shape[0], "arena_decode_attention")
    sanitize.notify_rows("arena_decode_attention", slots,
                         k_arena.shape[0] - 1)
    if impl in ("pallas", "pallas_interpret"):
        if S % min(block_kv, S) == 0:
            return paged_decode_attention_pallas(
                q, k_arena, v_arena, slots, kv_len, sm_scale=sm_scale,
                block_kv=block_kv, interpret=(impl == "pallas_interpret"))
    k = jnp.take(k_arena, slots, axis=0)
    v = jnp.take(v_arena, slots, axis=0)
    return decode_attention(q, k, v, kv_len, sm_scale=sm_scale, impl=impl,
                            block_kv=block_kv)


def attention_paged(
    q: jnp.ndarray,               # [B, Sq, Hq, Dh]
    k_arena: jnp.ndarray,         # [N_rows, S_alloc, Hkv, Dh] arena
    v_arena: jnp.ndarray,
    slots: jnp.ndarray,           # [B] int32 arena row per sequence
    *,
    kv_valid: int,                # static: attend keys [0, kv_valid)
    block_tables: Optional[jnp.ndarray] = None,   # [B, S_alloc // block]
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,
    sm_scale: Optional[float] = None,
    impl: str = DEFAULT_IMPL,
    block_q: int = 512,
    block_kv: int = 512,
) -> jnp.ndarray:
    """Prefix-extend attention over a slot arena (paged extend path).

    ``block_tables`` [B, S_alloc // block] is the per-block indirection
    of ``arena_decode_attention``: shared prefix rows appear in many
    documents' leading columns.  The Pallas kernel consumes the first
    ``kv_valid // block`` columns through scalar-prefetch SMEM when the
    granularities line up; any other shape (and ``xla``/``naive``)
    gathers blocks into dense caches — bitwise the same keys either way.

    The paged twin of ``attention`` for the serving engine's extend step:
    queries are the suffix at ``q_offset`` and cached keys live in
    ``k_arena[slots[b], :kv_valid]`` (the caller scatters the new chunk's
    KV into the arena first).  Pallas runtimes resolve slots inside the
    kernel when the block constraints hold (``Sq``/``kv_valid`` tile by
    the effective blocks — serving launches do, since buckets and
    fraction slices are block-aligned); ragged shapes and
    ``xla``/``naive`` gather the addressed rows and defer to the dense
    path, mirroring ``arena_decode_attention``'s fallback.  Slot contract
    as in ``arena_decode_attention``.
    """
    S_alloc = k_arena.shape[1]
    if block_tables is not None:
        _check_slots(block_tables, k_arena.shape[0],
                     "attention_paged block_tables")
        sanitize.notify_rows("attention_paged block_tables", block_tables,
                             k_arena.shape[0] - 1)
        tb = _block_granularity(block_tables, S_alloc, "attention_paged")
        Sq = q.shape[1]
        if (impl in ("pallas", "pallas_interpret")
                and Sq % min(block_q, Sq) == 0
                and kv_valid % tb == 0 and tb == min(block_kv, kv_valid)):
            qt = jnp.swapaxes(q, 1, 2)
            out = paged_flash_attention_pallas(
                qt, k_arena, v_arena, slots, kv_valid=kv_valid,
                block_tables=block_tables[:, : kv_valid // tb],
                causal=causal, window=window, q_offset=q_offset,
                kv_len=kv_len, sm_scale=sm_scale, block_q=block_q,
                block_kv=block_kv, interpret=(impl == "pallas_interpret"))
            return jnp.swapaxes(out, 1, 2)
        k = _gather_block_rows(k_arena, block_tables, tb)[:, :kv_valid]
        v = _gather_block_rows(v_arena, block_tables, tb)[:, :kv_valid]
        return attention(q, k, v, causal=causal, window=window,
                         q_offset=q_offset, kv_len=kv_len, sm_scale=sm_scale,
                         impl=impl, block_q=block_q, block_kv=block_kv)
    _check_slots(slots, k_arena.shape[0], "attention_paged")
    sanitize.notify_rows("attention_paged", slots, k_arena.shape[0] - 1)
    if impl in ("pallas", "pallas_interpret"):
        Sq = q.shape[1]
        if (Sq % min(block_q, Sq) == 0
                and kv_valid % min(block_kv, kv_valid) == 0):
            qt = jnp.swapaxes(q, 1, 2)
            out = paged_flash_attention_pallas(
                qt, k_arena, v_arena, slots, kv_valid=kv_valid,
                causal=causal, window=window, q_offset=q_offset,
                kv_len=kv_len, sm_scale=sm_scale, block_q=block_q,
                block_kv=block_kv, interpret=(impl == "pallas_interpret"))
            return jnp.swapaxes(out, 1, 2)
    k = jnp.take(k_arena, slots, axis=0)[:, :kv_valid]
    v = jnp.take(v_arena, slots, axis=0)[:, :kv_valid]
    return attention(q, k, v, causal=causal, window=window,
                     q_offset=q_offset, kv_len=kv_len, sm_scale=sm_scale,
                     impl=impl, block_q=block_q, block_kv=block_kv)


def relevance_score(
    x: jnp.ndarray,               # [C, T, D]
    lengths: jnp.ndarray,         # [C]
    w: jnp.ndarray,               # [D]
    b: jnp.ndarray,
    *,
    impl: str = DEFAULT_IMPL,
    block_c: int = 128,
) -> jnp.ndarray:
    if impl in ("naive", "xla"):
        return ref.relevance_reference(x, lengths, w, b)
    if impl in ("pallas", "pallas_interpret"):
        return relevance_score_pallas(
            x, lengths, w, b, block_c=block_c,
            interpret=(impl == "pallas_interpret"),
        )
    raise ValueError(f"unknown relevance impl {impl!r}")
