"""Fused chunk relevance scoring Pallas kernel.

Document restructuring (paper §4) scores every chunk of every incoming
document with a logistic-regression head over mean-pooled chunk embeddings.
At serving scale this runs on *every* document before the cascade, so the
mean-pool and the score are fused: the [C, D] pooled matrix is never
materialized in HBM — each grid step pools a tile of chunks in VMEM and
immediately reduces it against the classifier weights.

x: [C, T, D] chunk token embeddings, lengths: [C], w: [D], b: [1].
Output: [C] sigmoid relevance scores (f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _relevance_kernel(x_ref, len_ref, w_ref, b_ref, o_ref, *, block_c: int, t: int):
    x = x_ref[...].astype(jnp.float32)                    # [bc, T, D]
    lengths = len_ref[...].astype(jnp.float32)            # [bc, 1]
    w = w_ref[...].astype(jnp.float32)                    # [1, D]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (block_c, t), 1)
    mask = (tpos < lengths.astype(jnp.int32)).astype(jnp.float32)  # [bc, T]
    # fused: score_c = (sum_t mask*x[c,t,:] @ w) / len_c
    xw = jax.lax.dot_general(
        x.reshape(block_c * t, -1), w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(block_c, t)                                  # [bc, T]
    summed = jnp.sum(xw * mask, axis=-1)                   # [bc]
    denom = jnp.maximum(lengths[:, 0], 1.0)
    logit = summed / denom + b_ref[0, 0]
    o_ref[...] = jax.nn.sigmoid(logit)[:, None]


def relevance_score_pallas(
    x: jnp.ndarray,          # [C, T, D]
    lengths: jnp.ndarray,    # [C]
    w: jnp.ndarray,          # [D]
    b: jnp.ndarray,          # [] or [1]
    *,
    block_c: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    C, T, D = x.shape
    block_c = min(block_c, C)
    # Ragged chunk counts (real corpora rarely land on a block multiple):
    # pad the chunk axis with zero-length chunks and slice them back off.
    # Padded rows score sigmoid(b) but are masked out of the pool (length 0)
    # and dropped below, so they never reach callers.
    c_pad = (-C) % block_c
    if c_pad:
        x = jnp.pad(x, ((0, c_pad), (0, 0), (0, 0)))
        lengths = jnp.pad(lengths, (0, c_pad))
    c_full = C + c_pad
    nc = c_full // block_c

    kernel = functools.partial(_relevance_kernel, block_c=block_c, t=T)
    out = pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((block_c, T, D), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_full, 1), jnp.float32),
        interpret=interpret,
    )(x, lengths.reshape(c_full, 1).astype(jnp.int32), w.reshape(1, D),
      jnp.asarray(b, jnp.float32).reshape(1, 1))
    return out[:C, 0]
