"""Blocked flash attention Pallas TPU kernel with prefix-extend semantics.

Supports:
  * causal and bidirectional attention,
  * sliding-window masking (Gemma3 / RecurrentGemma local layers),
  * GQA (q heads grouped over kv heads),
  * ``q_offset`` — queries are the *suffix* of a longer sequence whose first
    ``q_offset`` tokens already live in the KV operand.  This is the task-
    cascade primitive: extending a document from fraction f_j to f_i > f_j
    re-uses the cached prefix KV and only computes attention for new queries.
  * ``kv_len`` [B] — per-row valid KV length (bucket-padded serving batches:
    keys at positions >= kv_len[b] are PAD and masked for every query).
    Rides in scalar-prefetch SMEM like the decode kernel's length mask.

Layout: q [B, Hq, Sq, Dh]; k/v [B, Hkv, Skv, Dh] (callers transpose from
[B, S, H, Dh]).  Grid = (B, Hq, nq, nkv) with the kv dimension innermost;
the output block index is constant over the kv dimension, so the f32
accumulator / running max / running denominator live in VMEM scratch across
kv iterations (the canonical TPU "revisiting" pattern).

``paged_flash_attention_pallas`` is the slot-addressed twin for the
serving engine's extend path: k/v come from a persistent arena
[N_rows, S_alloc, Hkv, Dh] (model layout, untransposed) and each batch row
resolves its arena row through ``slots`` [B] riding in scalar-prefetch
SMEM beside ``kv_len`` — the k/v index maps DMA ``k_arena[slots[b]]``
blocks directly, so a mid-cascade re-entry prefill appends into the arena
without first gathering a [B, S] copy.  Per-block math is identical to the
dense kernel, so paged and gather outputs agree bitwise.

Block shapes must tile the sequence lengths; ``ops.attention`` picks
hardware-aligned blocks (multiples of 8 sublanes x 128 lanes; MXU-friendly
head_dim 128/256) and asserts divisibility.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    kv_len_ref,                   # SMEM [B] scalar prefetch
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,                        # output block
    acc_ref, m_ref, l_ref,        # VMEM scratch (persist across kv steps)
    *,
    sm_scale: float,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
    paged: bool = False,
):
    b = pl.program_id(0)
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this block's first query / key
    q0 = q_offset + iq * block_q
    k0 = ik * block_kv
    kv_len = kv_len_ref[b]

    # block-level pruning: skip fully-masked blocks
    run = k0 < kv_len
    if causal:
        run &= k0 <= q0 + block_q - 1
    if window is not None and window > 0:
        run &= (k0 + block_kv - 1) > (q0 - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # [bq, dh]
        if paged:
            # arena block [1, bkv, 1, dh] (model layout, slot-addressed
            # by the BlockSpec index map) -> [bkv, dh]
            k = k_ref[0, :, 0, :].astype(jnp.float32)
            v = v_ref[0, :, 0, :].astype(jnp.float32)
        else:
            k = k_ref[0, 0].astype(jnp.float32)             # [bkv, dh]
            v = v_ref[0, 0].astype(jnp.float32)             # [bkv, dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # [bq, bkv]

        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None and window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                 # [bq]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_cur
        l_ref[:, 0] = l_cur

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,               # [B, Hq, Sq, Dh]
    k: jnp.ndarray,               # [B, Hkv, Skv, Dh]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,   # [B] valid kv length (pad mask)
    sm_scale: Optional[float] = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (Dh ** 0.5)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0, (Sq, block_q)
    assert Skv % block_kv == 0, (Skv, block_kv)
    nq = Sq // block_q
    nkv = Skv // block_kv

    if kv_len is None:
        kv_len = jnp.full((B,), Skv, jnp.int32)   # every key valid

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, i, j, *_: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh),
                         lambda b, h, i, j, *_: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh),
                         lambda b, h, i, j, *_: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, i, j, *_: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q, k, v)


def paged_flash_attention_pallas(
    q: jnp.ndarray,               # [B, Hq, Sq, Dh]
    k_arena: jnp.ndarray,         # [N_rows, S_alloc, Hkv, Dh] arena
    v_arena: jnp.ndarray,
    slots: jnp.ndarray,           # [B] int32 arena row per sequence
    *,
    kv_valid: int,                # static: attend keys [0, kv_valid)
    block_tables: Optional[jnp.ndarray] = None,   # [B, nkv] int32
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    kv_len: Optional[jnp.ndarray] = None,   # [B] valid kv length (pad mask)
    sm_scale: Optional[float] = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Prefix-extend attention reading K/V straight from a slot arena.

    The queries are the suffix [q_offset, q_offset + Sq) of each
    sequence; cached keys live in ``k_arena[slots[b], :kv_valid]``
    (chunk included — the caller scatters the new chunk's KV into the
    arena BEFORE attending, mirroring the dense extend path).  Only the
    kv blocks covering ``kv_valid`` are visited, so the arena's op-suffix
    reserve past the bucket costs nothing.  Slot contract as in
    ``paged_decode_attention_pallas``: any row in [0, N_rows) is legal,
    the scratch row (N_rows - 1) explicitly so, duplicates allowed.

    ``block_tables`` [B, ceil(kv_valid / block_kv)] switches the
    indirection to per-block granularity: kv block ``j`` of row ``b`` is
    DMA'd from ``(block_tables[b, j], j, h // g)`` — the within-row
    index stays ``j``, so shared prefix rows are read at the positions
    they were prefilled at.  When given, ``slots`` is ignored.
    """
    from . import sanitize        # deferred: keep module import DAG flat
    sanitize.notify_rows(
        "paged_flash_attention_pallas",
        slots if block_tables is None else block_tables,
        k_arena.shape[0] - 1)
    B, Hq, Sq, Dh = q.shape
    _, S_alloc, Hkv, _ = k_arena.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    assert 0 < kv_valid <= S_alloc, (kv_valid, S_alloc)
    scale = sm_scale if sm_scale is not None else 1.0 / (Dh ** 0.5)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, kv_valid)
    assert Sq % block_q == 0, (Sq, block_q)
    assert kv_valid % block_kv == 0, (kv_valid, block_kv)
    nq = Sq // block_q
    nkv = kv_valid // block_kv

    if kv_len is None:
        kv_len = jnp.full((B,), kv_valid, jnp.int32)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        paged=True,
    )

    if block_tables is None:
        def kv_map(b, h, i, j, slots_ref, kv_len_ref):
            return (slots_ref[b], j, h // g, 0)
        row_ids = slots.astype(jnp.int32)
    else:
        assert block_tables.shape == (B, nkv), (block_tables.shape, B, nkv)

        def kv_map(b, h, i, j, bt_ref, kv_len_ref):
            return (bt_ref[b, j], j, h // g, 0)
        row_ids = block_tables.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # (rows, kv_len)
        grid=(B, Hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, i, j, *_: (b, h, i, 0)),
            pl.BlockSpec((1, block_kv, 1, Dh), kv_map),
            pl.BlockSpec((1, block_kv, 1, Dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, i, j, *_: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
    )

    def paged_kernel(rows_ref, kv_len_ref, *rest):
        # row ids feed the index maps only; masking is by kv_len, exactly
        # as in the dense kernel (bitwise-equal math per block)
        return kernel(kv_len_ref, *rest)

    return pl.pallas_call(
        paged_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dh), q.dtype),
        interpret=interpret,
    )(row_ids, kv_len.astype(jnp.int32), q, k_arena, v_arena)
