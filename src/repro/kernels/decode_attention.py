"""Flash-decoding Pallas TPU kernel: one new query token over a KV cache.

Layout: q [B, Hq, Dh] (a single token per sequence); k/v [B, Hkv, S, Dh].
For GQA we process one kv head per grid step and compute all ``g = Hq/Hkv``
grouped query heads together, so the query tile is [g, Dh] (padded to the
8-sublane minimum by Mosaic automatically).

The kv-cache length can exceed the number of valid entries (bucketed cache
allocation); ``kv_len`` [B] masks out unwritten slots.  ``kv_len`` rides in
scalar-prefetch SMEM so the mask costs no extra HBM traffic.

Grid = (B, Hkv, nkv) with kv innermost; f32 accumulator in VMEM scratch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    kv_len_ref,                   # SMEM [B] scalar prefetch
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,
    acc_ref, m_ref, l_ref,
    *,
    sm_scale: float,
    block_kv: int,
    num_kv_blocks: int,
):
    b = pl.program_id(0)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = kv_len_ref[b]
    k0 = jk * block_kv

    @pl.when(k0 < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # [g, dh]
        k = k_ref[0, 0].astype(jnp.float32)                 # [bkv, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # [g, bkv]
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < kv_len
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(valid, jnp.exp(s - m_cur[:, None]), 0.0)
        l_ref[:, 0] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_cur

    @pl.when(jk == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,               # [B, Hq, Dh]
    k: jnp.ndarray,               # [B, Hkv, S, Dh]
    v: jnp.ndarray,
    kv_len: jnp.ndarray,          # [B] int32
    *,
    sm_scale: Optional[float] = None,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (Dh ** 0.5)
    block_kv = min(block_kv, S)
    assert S % block_kv == 0, (S, block_kv)
    nkv = S // block_kv

    # [B, Hkv, g, Dh] — grouped query heads per kv head
    qg = q.reshape(B, Hkv, g, Dh)

    kernel = functools.partial(
        _decode_kernel,
        sm_scale=scale,
        block_kv=block_kv,
        num_kv_blocks=nkv,
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh), lambda b, h, j, *_: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh), lambda b, h, j, *_: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, Dh), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, Dh), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, Dh)
