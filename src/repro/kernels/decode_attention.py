"""Flash-decoding Pallas TPU kernels: one new query token over a KV cache.

Two entry points share one online-softmax body:

``decode_attention_pallas``
    dense layout — q [B, Hq, Dh] (a single token per sequence) over
    k/v [B, Hkv, S, Dh]: row b of the cache belongs to sequence b.

``paged_decode_attention_pallas``
    paged layout — the cache is a persistent slot ARENA
    k/v [N_rows, S, Hkv, Dh] (the serving engine's model-layout state
    pytree, untransposed) and each sequence addresses its row through
    ``slots`` [B].  ``slots`` rides in scalar-prefetch SMEM beside
    ``kv_len`` and the k/v BlockSpec index maps resolve
    ``k_arena[slots[b]]`` *inside* the kernel's DMA schedule, so no
    [B, S] gather copy is ever materialized (vLLM-style paged
    attention).  Any row index in [0, N_rows) is legal — the serving
    arena's scratch row (index ``n_slots`` == N_rows - 1) is an
    explicit sentinel for batch padding and may appear many times.

For GQA we process one kv head per grid step and compute all ``g = Hq/Hkv``
grouped query heads together, so the query tile is [g, Dh] (padded to the
8-sublane minimum by Mosaic automatically).

The kv-cache length can exceed the number of valid entries (bucketed cache
allocation); ``kv_len`` [B] masks out unwritten slots.  ``kv_len`` rides in
scalar-prefetch SMEM so the mask costs no extra HBM traffic.

Grid = (B, Hkv, nkv) with kv innermost; f32 accumulator in VMEM scratch.
Both variants execute the identical per-block math over identical block
contents, so paged and dense outputs agree BITWISE — the serving engine
relies on this to keep paged results exactly equal to the gather path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    kv_len_ref,                   # SMEM [B] scalar prefetch
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,
    acc_ref, m_ref, l_ref,
    *,
    sm_scale: float,
    block_kv: int,
    num_kv_blocks: int,
    paged: bool = False,
):
    b = pl.program_id(0)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = kv_len_ref[b]
    k0 = jk * block_kv

    @pl.when(k0 < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale      # [g, dh]
        if paged:
            # arena block [1, bkv, 1, dh] (model layout, slot-addressed
            # by the BlockSpec index map) -> [bkv, dh]
            k = k_ref[0, :, 0, :].astype(jnp.float32)
            v = v_ref[0, :, 0, :].astype(jnp.float32)
        else:
            k = k_ref[0, 0].astype(jnp.float32)             # [bkv, dh]
            v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                    # [g, bkv]
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kpos < kv_len
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(valid, jnp.exp(s - m_cur[:, None]), 0.0)
        l_ref[:, 0] = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_cur

    @pl.when(jk == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jnp.ndarray,               # [B, Hq, Dh]
    k: jnp.ndarray,               # [B, Hkv, S, Dh]
    v: jnp.ndarray,
    kv_len: jnp.ndarray,          # [B] int32
    *,
    sm_scale: Optional[float] = None,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (Dh ** 0.5)
    block_kv = min(block_kv, S)
    assert S % block_kv == 0, (S, block_kv)
    nkv = S // block_kv

    # [B, Hkv, g, Dh] — grouped query heads per kv head
    qg = q.reshape(B, Hkv, g, Dh)

    kernel = functools.partial(
        _decode_kernel,
        sm_scale=scale,
        block_kv=block_kv,
        num_kv_blocks=nkv,
    )

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh), lambda b, h, j, *_: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, Dh), lambda b, h, j, *_: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, Dh), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, Dh), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, Dh)


def paged_decode_attention_pallas(
    q: jnp.ndarray,               # [B, Hq, Dh]
    k_arena: jnp.ndarray,         # [N_rows, S, Hkv, Dh] persistent arena
    v_arena: jnp.ndarray,
    slots: jnp.ndarray,           # [B] int32 arena row per sequence
    kv_len: jnp.ndarray,          # [B] int32 valid cache entries
    *,
    block_tables: Optional[jnp.ndarray] = None,   # [B, S // block_kv] int32
    sm_scale: Optional[float] = None,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """True paged decode: KV blocks are DMA'd straight from the arena.

    ``slots`` and ``kv_len`` both ride in scalar-prefetch SMEM; the k/v
    index maps address block ``(slots[b], j, h)`` of the UNGATHERED arena,
    so per-launch HBM traffic is the addressed blocks only — the dense
    path's [B, S] gather copy (``jnp.take``) is eliminated.  The arena
    keeps the model-side [rows, S, Hkv, Dh] layout; only the tiny query
    is reshaped.  ``S`` must be a multiple of the effective kv block (the
    serving arena rounds its per-slot allocation up on Pallas runtimes);
    callers with ragged arenas use the gather fallback in ``ops``.

    Slot contract: every value must lie in [0, N_rows); the last arena
    row (``n_slots`` == N_rows - 1) is the serving scratch row and is a
    LEGAL sentinel that may appear repeatedly (batch padding).  Bounds
    are validated host-side in ``ops.arena_decode_attention`` when the
    slot values are concrete.

    ``block_tables`` [B, S // block_kv] generalizes the indirection from
    one row per sequence to one row per CACHE BLOCK: block ``j`` of
    sequence ``b`` is DMA'd from ``(block_tables[b, j], j, h)``.  The
    within-row block index stays ``j`` — a shared prefix row stores its
    KV at the same positions every consumer reads it at — which is what
    lets many documents' leading blocks point at one pinned prefix row
    (copy-on-write happens at the serving layer by editing the table).
    When given, ``slots`` is ignored by the index maps.
    """
    from . import sanitize        # deferred: keep module import DAG flat
    sanitize.notify_rows(
        "paged_decode_attention_pallas",
        slots if block_tables is None else block_tables,
        k_arena.shape[0] - 1)
    B, Hq, Dh = q.shape
    _, S, Hkv, _ = k_arena.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / (Dh ** 0.5)
    block_kv = min(block_kv, S)
    assert S % block_kv == 0, (S, block_kv)
    nkv = S // block_kv

    qg = q.reshape(B, Hkv, g, Dh)

    kernel = functools.partial(
        _decode_kernel,
        sm_scale=scale,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        paged=True,
    )

    if block_tables is None:
        def kv_map(b, h, j, slots_ref, kv_len_ref):
            return (slots_ref[b], j, h, 0)
        row_ids = slots.astype(jnp.int32)
    else:
        assert block_tables.shape == (B, nkv), (block_tables.shape, B, nkv)

        def kv_map(b, h, j, bt_ref, kv_len_ref):
            return (bt_ref[b, j], j, h, 0)
        row_ids = block_tables.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # (rows, kv_len) — kv_len first in
        grid=(B, Hkv, nkv),           # kernel args is the dense kernel's
        in_specs=[                    # order; see call below
            pl.BlockSpec((1, 1, g, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, Dh), kv_map),
            pl.BlockSpec((1, block_kv, 1, Dh), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dh), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, Dh), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
        ],
    )

    def paged_kernel(rows_ref, kv_len_ref, *rest):
        # row ids are consumed by the index maps only; the body masks by
        # kv_len exactly like the dense kernel (bitwise-equal math)
        return kernel(kv_len_ref, *rest)

    out = pl.pallas_call(
        paged_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, Dh), q.dtype),
        interpret=interpret,
    )(row_ids, kv_len.astype(jnp.int32), qg, k_arena, v_arena)
    return out.reshape(B, Hq, Dh)
